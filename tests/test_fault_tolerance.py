"""Fault-tolerance tests: watchdog, heartbeat, trainer restore-and-replay,
elastic rescale policy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep: requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed import elastic
from repro.distributed.fault_tolerance import (
    HeartbeatFile, StragglerWatchdog, failure_injector, StepFailure,
)
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, make_train_step
from repro.training.trainer import Trainer, TrainerConfig


def test_watchdog_flags_stragglers():
    w = StragglerWatchdog(deadline_factor=2.0, warmup_steps=2)
    for i in range(5):
        assert not w.observe(i, 1.0)
    assert w.observe(5, 5.0)       # 5x the EMA
    assert w.straggler_count == 1
    # the breach did not poison the EMA
    assert abs(w.ema - 1.0) < 1e-6
    assert not w.observe(6, 1.1)


def test_watchdog_hook_called():
    events = []
    w = StragglerWatchdog(deadline_factor=2.0, warmup_steps=1,
                          on_straggler=events.append)
    w.observe(0, 1.0)
    w.observe(1, 1.0)
    w.observe(2, 10.0)
    assert len(events) == 1 and events[0].step == 2


def test_heartbeat(tmp_path):
    hb = HeartbeatFile(str(tmp_path / "hb.json"), rank=3)
    assert hb.is_stale(0.1)
    hb.beat(step=12)
    assert not hb.is_stale(10.0)
    assert hb.age() < 5.0


def test_trainer_recovers_from_injected_failures(tmp_path):
    cfg = registry.get_smoke_config("llama3-8b")
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3, warmup_steps=2,
                                             total_steps=20))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    pipe = make_pipeline(DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab))
    tr = Trainer(
        step_fn, state, pipe,
        TrainerConfig(total_steps=12, ckpt_dir=str(tmp_path), ckpt_every=4,
                      ckpt_async=False, log_every=1000),
        put_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )
    tr.run(inject_failure=failure_injector({5, 9}))
    assert tr.step == 12
    # And a fresh trainer resumes from the persisted checkpoint:
    tr2 = Trainer(
        step_fn, init_train_state(jax.random.PRNGKey(1), cfg, tcfg), pipe,
        TrainerConfig(total_steps=12, ckpt_dir=str(tmp_path)),
        put_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )
    assert tr2.try_resume()
    assert tr2.step == 12


def test_trainer_gives_up_after_max_retries(tmp_path):
    cfg = registry.get_smoke_config("llama3-8b")
    tcfg = TrainConfig(optimizer=AdamWConfig())
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    pipe = make_pipeline(DataConfig(seq_len=16, global_batch=2, vocab=cfg.vocab))
    tr = Trainer(
        step_fn, state, pipe,
        TrainerConfig(total_steps=3, max_retries=2, log_every=1000),
        put_batch=lambda b: {k: jnp.asarray(v) for k, v in b.items()},
    )

    def always_fail(step):
        raise StepFailure("permanent")

    with pytest.raises(StepFailure):
        tr.run(inject_failure=always_fail)


# --- Elastic rescaling policy ------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(num=st.sampled_from([8, 16, 32, 64, 128, 256, 512, 1024]))
def test_choose_mesh_shape_consistent(num):
    cfg = registry.get_config("llama3-8b")
    shape, axes = elastic.choose_mesh_shape(num, cfg)
    assert int(np.prod(shape)) == num
    assert len(shape) == len(axes)
    model = shape[axes.index("model")]
    # ACC alignment: model axis divides kv heads or vice versa.
    assert cfg.n_kv_heads % model == 0 or model % cfg.n_kv_heads == 0


def test_rescale_plan_batch_divisibility():
    cfg = registry.get_config("llama3-8b")
    plan = elastic.rescale_plan((16, 16), 128, cfg, global_batch=256)
    assert plan.per_shard_batch * np.prod(
        [n for n, a in zip(plan.new_shape, plan.axis_names) if a in ("pod", "data")]
    ) == 256
    with pytest.raises(ValueError):
        elastic.rescale_plan((16, 16), 96, cfg, global_batch=25)
