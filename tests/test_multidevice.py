"""Multi-device compile tests (subprocess: forces 8 host platform devices).

Validates in CI what the full dry-run validates at production scale:
  * a smoke config lowers + compiles on a (data=2, model=4) mesh,
  * sharded-state training step executes and the loss is finite,
  * the paper's mesh-level technique: ACC-aligned head placement compiles
    to FEWER collective bytes than the naive striped baseline.
"""

import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding

from repro import compat
from repro.configs import registry
from repro.distributed import sharding as shlib
from repro.launch import hlo_analysis
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, make_train_step

mesh = compat.make_mesh((2, 4), ("data", "model"),
                        axis_types=(compat.AXIS_AUTO, compat.AXIS_AUTO))
base = registry.get_smoke_config("llama3-8b")
# 8 q heads / 4 kv heads so the 4-way model axis has real head structure.
cfg0 = dataclasses.replace(base, n_heads=8, n_kv_heads=4, head_dim=16,
                           d_model=128, d_ff=256, placement_shards=4)
tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3), microbatches=2)

out = {}
for placement in ("acc_aligned", "striped"):
    cfg = dataclasses.replace(cfg0, head_placement=placement)
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    sh = shlib.param_shardings(mesh, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    state = jax.tree.map(jax.device_put, state, sh)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, 1),
             "mask": jnp.ones((8, 64), jnp.float32)}
    bspec = shlib.batch_spec(mesh, 8)
    batch = {k: jax.device_put(v, NamedSharding(mesh, shlib.fix_spec(
        jax.sharding.PartitionSpec(bspec[0] if len(bspec) else None,
                                   *([None]*(v.ndim-1))), v.shape, mesh)))
        for k, v in batch.items()}
    with mesh:
        fn = jax.jit(make_train_step(
            cfg, tcfg, shard_moe=shlib.shard_moe_buffers(mesh)))
        lowered = fn.lower(state, batch)
        compiled = lowered.compile()
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        new_state, metrics = fn(state, batch)
        loss = float(metrics["loss"])
    out[placement] = {"collective_bytes": coll["total"], "loss": loss}
print("RESULT " + json.dumps(out))
"""


@pytest.mark.slow
def test_mesh_compile_and_placement_ab(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env,
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = next(l for l in proc.stdout.splitlines() if l.startswith("RESULT "))
    res = json.loads(line[len("RESULT "):])
    for placement, r in res.items():
        assert r["loss"] > 0 and r["loss"] < 100, (placement, r)
    # The paper's claim at mesh level: ACC-aligned placement moves less data.
    assert (res["acc_aligned"]["collective_bytes"]
            < res["striped"]["collective_bytes"]), res
