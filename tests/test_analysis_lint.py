"""Rule coverage for the NUMA-contract linter (repro.analysis.lint).

Two halves per the PR-6 acceptance bar:
  * every registered rule demonstrably *fires* on a known-bad fixture
    snippet (linted via ``lint_source`` at a virtual path, so no bad file
    ever exists in the tree), and
  * the live tree is *clean*: ``python -m repro.analysis --strict``
    exits 0.
"""

import pytest

from repro.analysis import RULES, lint_source, run_rules
from repro.analysis.lint import main


def _fires(source, path, rule):
    vs = lint_source(source, path, rules=[rule])
    assert vs, f"rule {rule} did not fire on its bad fixture"
    assert all(v.rule == rule for v in vs)
    return vs


# --- each rule fires on its bad fixture --------------------------------------


def test_versioned_jax_rule_fires():
    bad = "from jax.experimental.pallas import tpu\np = tpu.TPUCompilerParams()\n"
    vs = _fires(bad, "src/repro/kernels/evil.py", "compat-only-versioned-jax")
    assert "TPUCompilerParams" in vs[0].message


def test_versioned_jax_rule_ignores_strings_and_compat():
    # The old text grep would have flagged the docstring; the AST rule
    # only sees real identifiers.
    doc = '"""mentions TPUCompilerParams in prose only"""\nx = 1\n'
    assert lint_source(doc, "src/repro/kernels/doc.py",
                       rules=["compat-only-versioned-jax"]) == []
    inside = "import jax\np = jax.AxisType\n"
    assert lint_source(inside, "src/repro/compat.py",
                       rules=["compat-only-versioned-jax"]) == []


def test_plan_dispatch_rule_fires():
    bad = "from repro.kernels.ops import resolve_mapping\n" \
          "mc = resolve_mapping((1, 8, 8, 128, 128, 64))\n"
    _fires(bad, "src/repro/serving/engine.py", "plan-dispatch-only")
    # the same source at a non-dispatch path is fine
    assert lint_source(bad, "src/repro/kernels/plan.py",
                       rules=["plan-dispatch-only"]) == []


def test_plan_dispatch_rule_catches_keywords():
    bad = "def f(attn):\n    return attn(x, q_offset=3)\n"
    _fires(bad, "src/repro/models/attention.py", "plan-dispatch-only")


def test_legacy_engine_rule_fires():
    bad = "from repro.serving import ServingEngine\n" \
          "e = ServingEngine(cfg, params)\n"
    _fires(bad, "examples/quickstart.py", "no-legacy-engine-construction")
    # construction inside serving/ (the shims' own home) is allowed
    assert lint_source(bad, "src/repro/serving/engine.py",
                       rules=["no-legacy-engine-construction"]) == []
    # naming the class without calling it (e.g. isinstance) is allowed
    ref = "from repro.serving import ServingEngine\n" \
          "ok = isinstance(x, ServingEngine)\n"
    assert lint_source(ref, "examples/quickstart.py",
                       rules=["no-legacy-engine-construction"]) == []


def test_decode_relevance_rule_fires_on_missing_predicate():
    bad = "def kernel(length, window):\n" \
          "    lo = length - window\n" \
          "    return lo\n"
    vs = _fires(bad, "src/repro/kernels/decode_attention.py",
                "decode-relevance-shared")
    kinds = "\n".join(v.message for v in vs)
    assert "chunk_relevant" in kinds
    assert "combine_split_states" in kinds
    assert "window-edge" in kinds


def test_decode_relevance_rule_ignores_other_files():
    bad = "lo = length - window\n"
    assert lint_source(bad, "src/repro/kernels/decode_common.py",
                       rules=["decode-relevance-shared"]) == []


def test_pallas_compat_rule_fires_outside_kernels():
    bad = "import jax.experimental.pallas as pl\n" \
          "fn = pl.pallas_call(k, out_shape=o)\n"
    vs = _fires(bad, "src/repro/serving/backends.py",
                "pallas-call-via-compat")
    assert "outside src/repro/kernels/" in vs[0].message


def test_pallas_compat_rule_fires_on_missing_compiler_params():
    bad = "import jax.experimental.pallas as pl\n" \
          "fn = pl.pallas_call(k, out_shape=o)\n"
    _fires(bad, "src/repro/kernels/newkernel.py", "pallas-call-via-compat")
    good = (
        "import jax.experimental.pallas as pl\n"
        "from repro import compat\n"
        "fn = pl.pallas_call(k, out_shape=o,\n"
        "    compiler_params=compat.tpu_compiler_params())\n"
    )
    assert lint_source(good, "src/repro/kernels/newkernel.py",
                       rules=["pallas-call-via-compat"]) == []


def test_host_sync_rule_fires():
    bad = (
        "import numpy as np\n"
        "class B:\n"
        "    def decode(self, tok):\n"
        "        x = np.asarray(tok)\n"
        "        n = self.lengths.item()\n"
        "        self.caches.block_until_ready()\n"
        "        return x, n\n"
    )
    vs = _fires(bad, "src/repro/serving/backends.py",
                "no-host-sync-in-decode-hot-loop")
    assert len(vs) == 3  # asarray + item + block_until_ready


def test_host_sync_rule_scoped_to_hot_loop():
    # _advance is the sanctioned sync point: same calls, no violation.
    ok = (
        "import numpy as np\n"
        "class E:\n"
        "    def _advance(self, tok, logits):\n"
        "        return np.asarray(logits).item()\n"
    )
    assert lint_source(ok, "src/repro/serving/engine.py",
                       rules=["no-host-sync-in-decode-hot-loop"]) == []
    # and jnp.asarray in the hot loop is fine (device-side, no sync)
    ok2 = (
        "import jax.numpy as jnp\n"
        "class B:\n"
        "    def decode(self, tok):\n"
        "        return jnp.asarray(tok)\n"
    )
    assert lint_source(ok2, "src/repro/serving/backends.py",
                       rules=["no-host-sync-in-decode-hot-loop"]) == []


def test_obs_hot_loop_allocs_rule_fires():
    bad = (
        "class E:\n"
        "    def step(self):\n"
        "        c = self.telemetry.metrics.counter('steps')\n"
        "        c.inc()\n"
        "    def _decode_tick(self):\n"
        "        self.registry.histogram('decode_s').observe(0.1)\n"
    )
    vs = _fires(bad, "src/repro/serving/engine.py", "obs-no-hot-loop-allocs")
    assert len(vs) == 2  # counter in step + histogram in _decode_tick
    assert "pre-bind at construction" in vs[0].message


def test_obs_hot_loop_allocs_rule_allows_prebound_use():
    # Registration in __init__ and .inc()/.observe() on the bound
    # instrument in the hot loop are exactly the sanctioned pattern.
    ok = (
        "class E:\n"
        "    def __init__(self, m):\n"
        "        self._m_steps = m.counter('steps')\n"
        "        self._h_step = m.histogram('step_s')\n"
        "    def step(self):\n"
        "        self._m_steps.inc()\n"
        "        self._h_step.observe(0.1)\n"
    )
    assert lint_source(ok, "src/repro/serving/engine.py",
                       rules=["obs-no-hot-loop-allocs"]) == []
    # the same registration outside serving/ is out of scope
    bad_path = (
        "class E:\n"
        "    def step(self):\n"
        "        self.m.counter('steps').inc()\n"
    )
    assert lint_source(bad_path, "src/repro/launch/loadgen.py",
                       rules=["obs-no-hot-loop-allocs"]) == []


def test_collectives_rule_fires():
    bad = (
        "import jax\n"
        "def kernel(x):\n"
        "    return jax.lax.psum(x, 'model')\n"
    )
    vs = _fires(bad, "src/repro/kernels/paged_decode_attention.py",
                "collectives-only-in-combine")
    assert "psum" in vs[0].message
    # the scheduler and the page pool must stay device-pure too
    _fires(bad, "src/repro/serving/scheduler.py",
           "collectives-only-in-combine")
    _fires(bad, "src/repro/cache/pool.py",
           "collectives-only-in-combine")


def test_collectives_rule_allows_sanctioned_modules():
    src = (
        "import jax\n"
        "def combine(parts):\n"
        "    return jax.lax.psum(parts, 'model')\n"
        "def gather(x):\n"
        "    return jax.lax.all_gather(x, 'model')\n"
    )
    assert lint_source(src, "src/repro/kernels/decode_common.py",
                       rules=["collectives-only-in-combine"]) == []
    assert lint_source(src, "src/repro/serving/sampling.py",
                       rules=["collectives-only-in-combine"]) == []
    # outside the scoped dirs (e.g. optim's gradient allreduce) the rule
    # does not apply
    assert lint_source(src, "src/repro/optim/grad_compress.py",
                       rules=["collectives-only-in-combine"]) == []


def test_kv_scales_rule_fires_on_indexing_and_arithmetic():
    bad_index = (
        "def peek(cache, pid):\n"
        "    k_scales = cache['k_scales']\n"
        "    return k_scales[:, pid]\n"
    )
    vs = _fires(bad_index, "src/repro/serving/backends.py",
                "kv-scales-ride-page-table")
    assert "k_scales" in vs[0].message
    bad_math = (
        "def dequant(codes, v_scales):\n"
        "    return codes * v_scales\n"
    )
    _fires(bad_math, "src/repro/serving/engine.py",
           "kv-scales-ride-page-table")
    _fires(bad_math, "examples/serve_longctx.py",
           "kv-scales-ride-page-table")


def test_kv_scales_rule_allows_opaque_passthrough_and_kernel_math():
    # Dict-key plumbing (how serving hands scales to the kernel call) and
    # keyword threading never touch the array's values — allowed anywhere.
    ok = (
        "def pack(cache):\n"
        "    return {'k_scales': cache['k_scales'],\n"
        "            'v_scales': cache.get('v_scales')}\n"
        "def call(op, cache):\n"
        "    return op(k_scales=cache['k_scales'])\n"
    )
    assert lint_source(ok, "src/repro/serving/backends.py",
                       rules=["kv-scales-ride-page-table"]) == []
    # Inside the kernel / quantization layers the math is the point.
    math = (
        "def dequant(codes, k_scales, pid):\n"
        "    return codes * k_scales[:, pid]\n"
    )
    assert lint_source(math, "src/repro/kernels/paged_decode_attention.py",
                       rules=["kv-scales-ride-page-table"]) == []
    assert lint_source(math, "src/repro/cache/quant.py",
                       rules=["kv-scales-ride-page-table"]) == []


# --- registry / CLI / live tree ----------------------------------------------


def test_every_registered_rule_has_a_bad_fixture_test():
    """Adding a rule without a firing fixture above must fail loudly."""
    covered = {
        "compat-only-versioned-jax", "plan-dispatch-only",
        "no-legacy-engine-construction", "decode-relevance-shared",
        "pallas-call-via-compat", "no-host-sync-in-decode-hot-loop",
        "obs-no-hot-loop-allocs", "collectives-only-in-combine",
        "kv-scales-ride-page-table",
    }
    assert set(RULES) == covered


def test_live_tree_is_clean():
    assert run_rules() == []


def test_cli_strict_exits_zero(capsys):
    assert main(["--strict"]) == 0
    assert "clean" in capsys.readouterr().out


def test_cli_rejects_unknown_rule():
    with pytest.raises(KeyError):
        run_rules(rules=["no-such-rule"])
