"""Shared fixtures: page-pool sanitizer instrumentation.

Every :class:`PagePool` constructed inside the scheduler / serving /
paged-cache suites gets a :class:`repro.analysis.pool_sanitizer.ShadowPool`
attached at construction, so the whole serving surface runs with
double-free / use-after-release / COW / desync checking on — the pool
misuse classes that are invisible to output-comparison tests. Teardown
re-verifies shadow/pool agreement (a desync there means some code path
mutated refcounts around the instrumented primitives).

``test_pool_sanitizer`` is deliberately *not* in the list: it constructs
pools with intentional violations and manages its own shadows.
"""

import pytest

SANITIZED_MODULES = {
    "test_scheduler",
    "test_serving",
    "test_paged_cache",
    "test_fused_decode",
    "sharded_engine_cases",
}


@pytest.fixture(autouse=True)
def _page_pool_sanitizer(request, monkeypatch):
    module = getattr(request.node, "module", None)
    name = getattr(module, "__name__", "").rpartition(".")[2]
    if name not in SANITIZED_MODULES:
        yield
        return

    from repro.analysis.pool_sanitizer import attach
    from repro.cache.pool import PagePool

    shadows = []
    orig_init = PagePool.__init__

    def instrumented_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        shadows.append(attach(self))

    monkeypatch.setattr(PagePool, "__init__", instrumented_init)
    yield
    # Live engines at test end legitimately still hold pages, so this is
    # a consistency check, not a zero-leak check — tests that want the
    # leak proof call engine.close() / backend.check_leaks() themselves.
    for shadow in shadows:
        shadow.assert_sync()
        shadow.detach()
