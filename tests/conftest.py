"""Shared fixtures: page-pool sanitizer instrumentation.

Every :class:`PagePool` constructed inside the scheduler / serving /
paged-cache suites gets a :class:`repro.analysis.pool_sanitizer.ShadowPool`
attached at construction, so the whole serving surface runs with
double-free / use-after-release / COW / desync checking on — the pool
misuse classes that are invisible to output-comparison tests. Teardown
re-verifies shadow/pool agreement (a desync there means some code path
mutated refcounts around the instrumented primitives).

``test_pool_sanitizer`` is deliberately *not* in the list: it constructs
pools with intentional violations and manages its own shadows.
"""

import pytest

SANITIZED_MODULES = {
    "test_scheduler",
    "test_serving",
    "test_paged_cache",
    "test_fused_decode",
    "test_tiering",
    "sharded_engine_cases",
}

#: Modules whose PagedBackends additionally run with the ShadowTier
#: residency sanitizer attached (host store + device prefix cache):
#: double-demote / promote-after-free / stale-device-read checking on
#: every tiering test, for free.
TIER_SANITIZED_MODULES = {
    "test_tiering",
    "test_serving",
}


@pytest.fixture(autouse=True)
def _page_pool_sanitizer(request, monkeypatch):
    module = getattr(request.node, "module", None)
    name = getattr(module, "__name__", "").rpartition(".")[2]
    if name not in SANITIZED_MODULES:
        yield
        return

    from repro.analysis.pool_sanitizer import attach
    from repro.cache.pool import PagePool

    shadows = []
    orig_init = PagePool.__init__

    def instrumented_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        shadows.append(attach(self))

    monkeypatch.setattr(PagePool, "__init__", instrumented_init)
    yield
    # Live engines at test end legitimately still hold pages, so this is
    # a consistency check, not a zero-leak check — tests that want the
    # leak proof call engine.close() / backend.check_leaks() themselves.
    for shadow in shadows:
        shadow.assert_sync()
        shadow.detach()


@pytest.fixture(autouse=True)
def _tier_sanitizer(request, monkeypatch):
    """Attach a ShadowTier to every tiered PagedBackend constructed in
    the tiering suites: host-store residency transitions (and the device
    prefix cache's reads/inserts) are validated on every operation."""
    module = getattr(request.node, "module", None)
    name = getattr(module, "__name__", "").rpartition(".")[2]
    if name not in TIER_SANITIZED_MODULES:
        yield
        return

    from repro.analysis.pool_sanitizer import attach_tier
    from repro.serving.backends import PagedBackend

    shadows = []
    orig_init = PagedBackend.__init__

    def instrumented_init(self, *args, **kwargs):
        orig_init(self, *args, **kwargs)
        if self.host is not None:
            shadows.append(attach_tier(self.host, self.prefix))

    monkeypatch.setattr(PagedBackend, "__init__", instrumented_init)
    yield
    for shadow in shadows:
        shadow.detach()
