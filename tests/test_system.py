"""End-to-end system tests: training convergence, sharded execution on the
host mesh, dry-run machinery on a reduced mesh, optimizer behaviour."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.models import layers, transformer
from repro.optim import adamw
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, make_train_step


def test_loss_decreases_on_learnable_data():
    cfg = registry.get_smoke_config("llama3-8b")
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=40),
        microbatches=2,
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    pipe = make_pipeline(DataConfig(seq_len=64, global_batch=8,
                                    vocab=cfg.vocab, ngram_vocab=32))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert all(np.isfinite(losses))


def test_sharded_train_step_matches_unsharded():
    """The same step under a (N,1) host mesh with sharded state produces the
    same loss as the single-device run — sharding never changes semantics."""
    cfg = registry.get_smoke_config("llama3-8b")
    tcfg = TrainConfig(optimizer=AdamWConfig(lr=1e-3))
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    pipe = make_pipeline(DataConfig(seq_len=32, global_batch=4, vocab=cfg.vocab))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}

    _, m1 = jax.jit(make_train_step(cfg, tcfg))(state, batch)

    mesh = make_host_mesh()
    sh = shlib.param_shardings(mesh, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    state2 = jax.tree.map(jax.device_put, state, sh)
    with mesh:
        step = jax.jit(make_train_step(
            cfg, tcfg, shard_moe=shlib.shard_moe_buffers(mesh)))
        _, m2 = step(state2, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


def test_grad_compression_trains():
    cfg = registry.get_smoke_config("llama3-8b")
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=30),
        grad_compression="int8_ef",
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    assert "ef" in state
    step = jax.jit(make_train_step(cfg, tcfg))
    pipe = make_pipeline(DataConfig(seq_len=32, global_batch=4,
                                    vocab=cfg.vocab, ngram_vocab=16))
    losses = []
    for i in range(25):
        batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3  # converges despite int8 wire format


def test_adamw_schedule_and_clip():
    acfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, grad_clip=1.0)
    assert float(adamw.schedule(acfg, jnp.asarray(0))) == 0.0
    assert float(adamw.schedule(acfg, jnp.asarray(10))) == pytest.approx(1.0)
    assert float(adamw.schedule(acfg, jnp.asarray(100))) == pytest.approx(0.1)
    params = {"w_dm": jnp.ones((4, 4))}
    grads = {"w_dm": jnp.full((4, 4), 100.0)}
    st = adamw.init(params)
    _, _, metrics = adamw.update(acfg, params, grads, st)
    assert float(metrics["grad_norm"]) == pytest.approx(400.0)


def test_no_weight_decay_on_norms():
    acfg = AdamWConfig(lr=1e-2, weight_decay=1.0, warmup_steps=0, total_steps=10)
    params = {"ln1": {"scale_r": jnp.ones((4,))}, "w_dm": jnp.ones((4, 4))}
    grads = jax.tree.map(jnp.zeros_like, params)
    st = adamw.init(params)
    new, _, _ = adamw.update(acfg, params, grads, st)
    # zero grad + decay: w shrinks, norm scale must not
    assert float(jnp.max(jnp.abs(new["ln1"]["scale_r"] - 1.0))) < 1e-6
    assert float(jnp.max(new["w_dm"])) < 1.0


def test_cross_entropy_oracle():
    logits = jnp.asarray([[[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]]])
    targets = jnp.asarray([[0, 1]])
    loss, metrics = layers.softmax_cross_entropy(logits, targets)
    expect = -np.log(np.exp(2) / (np.exp(2) + 1 + np.exp(-1)))
    expect = (expect + -np.log(np.exp(3) / (np.exp(3) + 2))) / 2
    assert float(loss) == pytest.approx(expect, rel=1e-5)
    assert float(metrics["accuracy"]) == 1.0


def test_input_specs_cover_all_cells():
    """Every assigned (arch x shape) cell has well-formed abstract inputs."""
    from repro.launch.dryrun import input_specs
    for arch, shape in registry.all_cells():
        specs = input_specs(arch, shape)
        assert "tokens" in specs or "token" in specs
        for s in jax.tree.leaves(specs):
            assert all(d > 0 for d in s.shape)
