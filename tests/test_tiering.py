"""Million-token KV (PR 10): quantized pages + device↔host KV tiering.

Three layers of acceptance:

  * **kernel parity** — int8/fp8 pools through the Pallas decode/prefill
    kernels (interpret mode) match the quantized oracle to float tolerance
    and the fp32 oracle to quantization tolerance, across GQA/MQA shapes,
    non-page-multiple lengths, length-0 rows, and split-K;
  * **serving bit-match** — a tiered engine (device pool too small, host
    tier behind it) produces byte-identical greedy tokens to the untiered
    engine, through demote→promote round trips and preemption/resume, and
    closes leak-free. Every engine in this module runs under BOTH shadow
    sanitizers (``tests/conftest.py``): page-pool lifecycle + tier
    residency checking on every operation;
  * **accounting** — pool bytes are exact (int8 ≤ 0.55x fp32 with scale
    metadata included), the host store's LRU/counters behave, the tiered
    cache simulator and perf-model link pricing agree on structure.
"""

import asyncio

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.pool_sanitizer import (
    DoubleDemoteError,
    PromoteAfterFreeError,
    StaleDeviceReadError,
    attach_tier,
)
from repro.cache import quant
from repro.cache.tier import HostPageStore
from repro.configs import registry
from repro.core import cache_sim, numa, perf_model
from repro.kernels import ref
from repro.kernels.paged_decode_attention import paged_flash_decode
from repro.kernels.paged_prefill_attention import paged_flash_prefill
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams
from repro.serving.scheduler import SchedulerStats

#: Worst-case |dequant(quant(x)) - x| through attention, per format.
QTOL = {"int8": 0.03, "fp8": 0.12}


@pytest.fixture(scope="module", autouse=True)
def _fresh_compile_caches():
    # The pinned jaxlib's CPU JIT segfaults in backend_compile once a
    # single process accumulates a full tier-1 suite's worth of compiled
    # executables; this module (last alphabetically, compile-heavy: many
    # short-lived engines) is where it lands. Dropping the executable
    # caches up front restores the standalone-run compile budget.
    jax.clear_caches()
    yield


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


# --- kernel parity: quantized pools vs oracles --------------------------------


def mk_paged(b, hq, hkv, d, ps, max_pages, seed=0, shared_pages=0):
    rng = np.random.default_rng(seed)
    num_pages = 1 + shared_pages + b * max_pages
    keys = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(keys[0], (b, hq, d), jnp.float32)
    k_pages = jax.random.normal(keys[1], (hkv, num_pages, ps, d))
    v_pages = jax.random.normal(keys[2], (hkv, num_pages, ps, d))
    avail = list(rng.permutation(np.arange(1 + shared_pages, num_pages)))
    pt = np.zeros((b, max_pages), np.int32)
    lengths = np.zeros((b,), np.int32)
    for i in range(b):
        # Deliberately non-page-multiple lengths (never aligned unless
        # the draw happens to be).
        lengths[i] = rng.integers(max(shared_pages * ps, 1),
                                  max_pages * ps + 1)
        live = -(-int(lengths[i]) // ps)
        row = list(range(1, 1 + min(shared_pages, live)))
        row += [avail.pop() for _ in range(live - len(row))]
        pt[i, :live] = row
    return q, k_pages, v_pages, jnp.asarray(pt), jnp.asarray(lengths)


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 64),       # GQA
    (2, 4, 1, 64),       # MQA
    (1, 25, 5, 64),      # odd group
])
@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_decode_parity(b, hq, hkv, d, kv_dtype):
    q, kp, vp, pt, lengths = mk_paged(b, hq, hkv, d, ps=16, max_pages=6,
                                      shared_pages=2)
    kq, ksc = quant.quantize_pages(kp, kv_dtype)
    vq, vsc = quant.quantize_pages(vp, kv_dtype)
    o = paged_flash_decode(q, kq, vq, pt, lengths,
                           k_scales=ksc, v_scales=vsc, interpret=True)
    # Kernel in-VMEM dequant == oracle gather-then-dequant, to float eps.
    o_qref = ref.paged_decode_attention(q, kq, vq, pt, lengths,
                                        k_scales=ksc, v_scales=vsc)
    assert jnp.max(jnp.abs(o - o_qref)) < 2e-5
    # And the whole quantized path tracks the fp32 oracle within the
    # format's quantization budget.
    o_fp32 = ref.paged_decode_attention(q, kp, vp, pt, lengths)
    assert jnp.max(jnp.abs(o - o_fp32)) < QTOL[kv_dtype]


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_decode_split_k_parity(kv_dtype):
    q, kp, vp, pt, lengths = mk_paged(2, 8, 2, 64, ps=16, max_pages=8,
                                      seed=5)
    kq, ksc = quant.quantize_pages(kp, kv_dtype)
    vq, vsc = quant.quantize_pages(vp, kv_dtype)
    o1 = paged_flash_decode(q, kq, vq, pt, lengths,
                            k_scales=ksc, v_scales=vsc, interpret=True)
    o4 = paged_flash_decode(q, kq, vq, pt, lengths,
                            k_scales=ksc, v_scales=vsc, num_splits=4,
                            interpret=True)
    assert jnp.max(jnp.abs(o1 - o4)) < 2e-5


def test_scales_both_or_neither_everywhere():
    """One-sided scales would silently attend over raw codes — every
    dispatch target (kernel AND oracle, decode AND prefill) must refuse."""
    q, kp, vp, pt, lengths = mk_paged(1, 4, 2, 32, ps=8, max_pages=2)
    kq, ksc = quant.quantize_pages(kp, "int8")
    with pytest.raises(ValueError, match="together"):
        paged_flash_decode(q, kq, kq, pt, lengths, k_scales=ksc,
                           interpret=True)
    with pytest.raises(ValueError, match="together"):
        ref.paged_decode_attention(q, kq, kq, pt, lengths, v_scales=ksc)
    tail = jnp.zeros((1, 2, 8, 32), jnp.float32)
    qp = jnp.zeros((1, 4, 8, 32), jnp.float32)
    with pytest.raises(ValueError, match="together"):
        paged_flash_prefill(qp, kq, kq, pt, tail, tail,
                            jnp.asarray([8]), jnp.asarray([8]),
                            k_scales=ksc, interpret=True)
    with pytest.raises(ValueError, match="together"):
        ref.paged_prefill_attention(qp, kq, kq, pt, tail, tail,
                                    jnp.asarray([8]), jnp.asarray([8]),
                                    v_scales=ksc)


def test_quantized_decode_length_zero_row():
    q, kp, vp, pt, lengths = mk_paged(3, 8, 2, 64, ps=16, max_pages=4,
                                      seed=3)
    lengths = lengths.at[1].set(0)
    kq, ksc = quant.quantize_pages(kp, "int8")
    vq, vsc = quant.quantize_pages(vp, "int8")
    o = paged_flash_decode(q, kq, vq, pt, lengths,
                           k_scales=ksc, v_scales=vsc, interpret=True)
    assert jnp.max(jnp.abs(o[1])) == 0.0
    o_fp32 = ref.paged_decode_attention(q, kp, vp, pt, lengths)
    assert jnp.max(jnp.abs(o - o_fp32)) < QTOL["int8"]


@pytest.mark.parametrize("kv_dtype", ["int8", "fp8"])
def test_quantized_prefill_parity(kv_dtype):
    """Extend prefill over quantized prefix pages + fp32 dense tail:
    kernel vs quantized oracle (float eps) vs fp32 oracle (format
    budget); non-page-multiple prefixes; rows past tail_len exact zero."""
    b, hq, hkv, d, ps, mp, st = 2, 8, 2, 64, 16, 4, 24
    keys = jax.random.split(jax.random.PRNGKey(7), 5)
    q = jax.random.normal(keys[0], (b, hq, st, d), jnp.float32)
    kp = jax.random.normal(keys[1], (hkv, 1 + b * mp, ps, d))
    vp = jax.random.normal(keys[2], (hkv, 1 + b * mp, ps, d))
    k_tail = jax.random.normal(keys[3], (b, hkv, st, d), jnp.float32)
    v_tail = jax.random.normal(keys[4], (b, hkv, st, d), jnp.float32)
    pt = jnp.asarray(
        1 + np.arange(b * mp).reshape(b, mp), jnp.int32)
    prefix_len = jnp.asarray([37, 64], jnp.int32)   # non-multiple + full
    tail_len = jnp.asarray([st, st - 5], jnp.int32)  # one short row
    kq, ksc = quant.quantize_pages(kp, kv_dtype)
    vq, vsc = quant.quantize_pages(vp, kv_dtype)
    o = paged_flash_prefill(q, kq, vq, pt, k_tail, v_tail,
                            prefix_len, tail_len,
                            k_scales=ksc, v_scales=vsc, interpret=True)
    o_qref = ref.paged_prefill_attention(q, kq, vq, pt, k_tail, v_tail,
                                         prefix_len, tail_len,
                                         k_scales=ksc, v_scales=vsc)
    assert jnp.max(jnp.abs(o - o_qref)) < 2e-5
    o_fp32 = ref.paged_prefill_attention(q, kp, vp, pt, k_tail, v_tail,
                                         prefix_len, tail_len)
    assert jnp.max(jnp.abs(o - o_fp32)) < QTOL[kv_dtype]
    assert jnp.max(jnp.abs(o[1, :, st - 5:])) == 0.0


def test_append_rows_rescale_keeps_history():
    """Rescale-on-append: a loud new token widens its page's scale and
    shrinks the existing codes — history dequantizes to the same values
    within one extra quantization step."""
    hkv, P, ps, d = 2, 3, 8, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (hkv, P, ps, d))
    pages, scales = quant.quantize_pages(x, "int8")
    before = quant.dequantize_pages(pages, scales)
    loud = 50.0 * jax.random.normal(jax.random.PRNGKey(1), (hkv, 1, d))
    pages, scales = quant.append_rows(
        pages, scales, loud, jnp.asarray([1], jnp.int32),
        jnp.asarray([3], jnp.int32), "int8")
    after = quant.dequantize_pages(pages, scales)
    # The appended row round-trips at the widened scale...
    err_new = jnp.max(jnp.abs(after[:, 1, 3] - loud[:, 0]))
    assert err_new < float(jnp.max(jnp.abs(loud))) / 127.0 * 1.01
    # ...untouched pages are bit-identical...
    assert jnp.array_equal(after[:, 0], before[:, 0])
    assert jnp.array_equal(after[:, 2], before[:, 2])
    # ...and the rescaled page's other rows stay within the new step.
    step = float(jnp.max(scales[:, 1]))
    rest = jnp.delete(jnp.arange(ps), 3)
    assert float(jnp.max(jnp.abs(
        after[:, 1, rest] - before[:, 1, rest]))) <= step * 1.01


# --- accounting ---------------------------------------------------------------


def test_int8_pool_bytes_under_055x_fp32(llama):
    cfg, params = llama
    engines = {}
    for kv_dtype in ("fp32", "int8"):
        e = LLMEngine(cfg, params, kv_layout="paged", num_pages=32,
                      page_size=8, kv_dtype=kv_dtype)
        engines[kv_dtype] = e.backend.kv_pool_bytes()
        # Accounting must be exact: 2 pools x layers x heads x
        # (page payload + one fp32 scale per (head, page)).
        itemsize = quant.kv_itemsize(kv_dtype)
        scale = 4 if kv_dtype != "fp32" else 0
        expect = (2 * cfg.n_layers * cfg.n_kv_heads
                  * (8 * cfg.head_dim * itemsize + scale) * 32)
        assert engines[kv_dtype] == expect, kv_dtype
        e.close()
    ratio = engines["int8"] / engines["fp32"]
    assert ratio <= 0.55, ratio


def test_host_store_lru_and_counters():
    store = HostPageStore(capacity_bytes=4 * 100, page_nbytes=100)
    assert store.capacity_pages == 4
    keys = [bytes([i]) for i in range(5)]
    for h in keys[:4]:
        assert store.admit(h, {"page": h})
    assert store.bytes_resident == 400 and store.free_slots == 0
    # Chain lookup stops at the first miss and MRU-refreshes hits.
    assert store.lookup_chain(keys[:3] + [b"missing"]) == keys[:3]
    # Admitting a 5th evicts the LRU (keys[3]: the lookup refreshed 0-2).
    assert store.admit(keys[4], {})
    assert keys[3] not in store and keys[0] in store
    assert store.evictions == 1
    # take consumes; discard drops without a promotion count.
    store.take(keys[0])
    assert keys[0] not in store and store.promotions == 1
    assert store.discard(keys[1]) and not store.discard(keys[1])
    assert store.promotions == 1
    with pytest.raises(KeyError):
        store.take(keys[3])
    c = store.counters()
    assert c["demotions"] == 5.0 and c["hits"] == 3.0
    assert store.drain() == len(store._lru) or store.drain() == 0
    assert store.bytes_resident == 0


def test_host_store_zero_capacity_disables():
    store = HostPageStore(capacity_bytes=10, page_nbytes=100)
    assert not store.admit(b"h", {})
    assert store.bytes_resident == 0


def test_estimate_tier_transfer_pricing():
    t0 = perf_model.estimate_tier_transfer(0)
    assert t0 == pytest.approx(perf_model.HOST_SYNC_OVERHEAD_S)
    t1 = perf_model.estimate_tier_transfer(1 << 20)
    assert t1 > t0
    assert t1 == pytest.approx(
        perf_model.HOST_SYNC_OVERHEAD_S + (1 << 20) / perf_model.HOST_LINK_BW)
    # A page transfer beats re-prefilling anything non-trivial, but not a
    # recompute cheaper than the sync overhead itself.
    assert perf_model.tier_transfer_beats_recompute(1 << 16, 5e-3)
    assert not perf_model.tier_transfer_beats_recompute(1 << 16, 1e-6)


def test_simulate_tiered_decode_accounting():
    # 2-page device LRU, 2-page host: read 3 pages round-robin twice.
    # First pass: 3 recomputes + 1 demotion (A evicted when C fills).
    # Second pass: A promotes from host, B/C churn likewise.
    trace = ["A", "B", "C", "A", "B", "C"]
    r = cache_sim.simulate_tiered_decode(
        trace, page_bytes=1000, device_pages=2, host_pages=2,
        topo=numa.MI300X, recompute_s_per_page=1e-3)
    assert r.device_hits == 0
    assert r.recomputes == 3 and r.promotions == 3
    assert r.demotions == 4  # every device eviction before the last two
    assert r.link_bytes == (r.promotions + r.demotions) * 1000
    assert r.hbm_bytes == 6 * 1000
    assert r.rescue_rate == pytest.approx(0.5)
    assert r.elapsed > 0
    # A device pool that fits the working set: all hits after cold start.
    r2 = cache_sim.simulate_tiered_decode(
        trace, page_bytes=1000, device_pages=3, host_pages=2,
        topo=numa.MI300X, recompute_s_per_page=1e-3)
    assert r2.device_hits == 3 and r2.demotions == 0
    assert r2.elapsed < r.elapsed


def test_scales_shard_with_their_pages():
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:2]), ("model",))
    from repro.distributed import sharding as sharding_lib

    caches = {
        "scanned": ({"attn": {
            "k_pages": jax.ShapeDtypeStruct((3, 4, 16, 8, 16), jnp.int8),
            "k_scales": jax.ShapeDtypeStruct((3, 4, 16), jnp.float32),
        }},),
        "rem": ({"attn": {
            "v_pages": jax.ShapeDtypeStruct((4, 16, 8, 16), jnp.int8),
            "v_scales": jax.ShapeDtypeStruct((4, 16), jnp.float32),
        }},),
    }
    specs = sharding_lib.paged_cache_specs(mesh, caches)

    def axes(spec, rank):
        # normalize: PartitionSpec trims trailing Nones, == doesn't.
        t = tuple(spec) + (None,) * (rank - len(tuple(spec)))
        return t

    sc = specs["scanned"][0]["attn"]
    assert axes(sc["k_pages"], 5) == (None, "model", None, None, None)
    assert axes(sc["k_scales"], 3) == (None, "model", None)
    rm = specs["rem"][0]["attn"]
    assert axes(rm["v_pages"], 4) == ("model", None, None, None)
    assert axes(rm["v_scales"], 2) == ("model", None)


def test_scheduler_stats_summary_includes_tier_line():
    s = SchedulerStats(kv_layout="paged", kv_dtype="int8",
                       demoted_pages=7, promoted_pages=3,
                       host_bytes_resident=4096)
    text = s.summary()
    assert "int8" in text and "7 demoted" in text and "3 promoted" in text
    assert "tier" not in SchedulerStats().summary()


# --- residency sanitizer ------------------------------------------------------


def test_shadow_tier_catches_residency_violations():
    store = HostPageStore(capacity_bytes=10 * 64, page_nbytes=64)
    shadow = attach_tier(store)
    try:
        store.admit(b"a", {"k": 1})
        with pytest.raises(DoubleDemoteError):
            store.admit(b"a", {"k": 1})
        store.take(b"a")
        with pytest.raises(PromoteAfterFreeError):
            store.take(b"a")
        # LRU overflow is a legal transition: the shadow mirrors it.
        tiny = HostPageStore(capacity_bytes=64, page_nbytes=64)
        sh2 = attach_tier(tiny)
        try:
            tiny.admit(b"x", {})
            tiny.admit(b"y", {})       # evicts x host-side
            tiny.admit(b"x", {})       # NOT a double demote: x was evicted
        finally:
            sh2.detach()
    finally:
        shadow.detach()


def test_shadow_tier_catches_stale_device_read():
    from repro.cache.pool import PagePool
    from repro.cache.prefix import PrefixCache

    pool = PagePool(num_pages=8, page_size=4)
    prefix = PrefixCache(pool)
    store = HostPageStore(capacity_bytes=10 * 64, page_nbytes=64)
    shadow = attach_tier(store, prefix)
    try:
        seq = pool.allocate_sequence(8)
        prefix.insert([b"h1", b"h2"], seq.pages[:2])
        store.admit(b"h2", {"payload": 2})   # demoted, device copy stale
        with pytest.raises(StaleDeviceReadError):
            prefix.lookup([b"h1", b"h2"])
        with pytest.raises(StaleDeviceReadError):
            prefix.insert([b"h2"], [seq.pages[1]])
        # discard clears host residency; the device side is legal again.
        store.discard(b"h2")
        prefix.insert([b"h2"], [seq.pages[1]])
        assert prefix.lookup([b"h1", b"h2"]) == list(seq.pages[:2])
        pool.release(seq)
        prefix.evict(10)
    finally:
        shadow.detach()


# --- serving: tiered bit-match, promotion, preemption, in-flight --------------


def _greedy(engine, prompts, n_new, uid0=0):
    reqs = [Request(uid0 + i, p, SamplingParams(max_tokens=n_new))
            for i, p in enumerate(prompts)]
    outs = engine.generate(reqs)
    return {o.uid - uid0: [int(t) for t in o.tokens] for o in outs}


def test_tiered_demote_promote_bit_match(llama):
    """The full round trip: serve P, pressure its pages host-side, serve
    P again — the promoted pages must reproduce the untiered tokens
    bit-for-bit, with real demotions AND promotions counted."""
    cfg, params = llama
    rng = np.random.default_rng(0)
    p_shared = rng.integers(1, cfg.vocab, size=33)
    fillers = [rng.integers(1, cfg.vocab, size=40 + 8 * i) for i in range(3)]

    ref_eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=96,
                        page_size=8)
    want = _greedy(ref_eng, [p_shared], 6)[0]
    ref_eng.close()

    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=20,
                    page_size=8, host_pool_bytes=1 << 20)
    first = _greedy(eng, [p_shared], 6, uid0=0)[0]
    _greedy(eng, fillers, 4, uid0=100)          # pressure: demote P's pages
    st = eng.backend.prefix_stats()
    assert st["demoted_pages"] > 0, st
    again = _greedy(eng, [p_shared], 6, uid0=200)[0]
    st = eng.backend.prefix_stats()
    assert st["promoted_pages"] > 0, st
    assert first == want and again == want
    assert eng.stats().demoted_pages == int(st["demoted_pages"])
    eng.close()   # leak-free or RefcountLeakError


def test_tiered_preemption_resume_bit_match(llama):
    """Preemption under decode pressure with the host tier on: resumed
    sequences replay through promoted/recomputed prefixes and still
    bit-match the pressure-free engine."""
    cfg, params = llama
    rng = np.random.default_rng(1)
    prompts = [rng.integers(1, cfg.vocab, size=24) for _ in range(3)]

    big = LLMEngine(cfg, params, kv_layout="paged", num_pages=96,
                    page_size=8, max_batch=3)
    want = _greedy(big, prompts, 24)
    big.close()

    small = LLMEngine(cfg, params, kv_layout="paged", num_pages=14,
                      page_size=8, max_batch=3, host_pool_bytes=1 << 20)
    got = _greedy(small, prompts, 24)
    assert small.backend.stats["preemptions"] > 0
    assert got == want
    small.close()


def test_host_pool_requires_prefix_sharing(llama):
    cfg, params = llama
    with pytest.raises(ValueError, match="prefix_sharing"):
        LLMEngine(cfg, params, kv_layout="paged", prefix_sharing=False,
                  host_pool_bytes=1 << 20)
    with pytest.raises(ValueError, match="paged"):
        LLMEngine(cfg, params, kv_layout="dense", kv_dtype="int8")


def test_int8_greedy_matches_fp32_on_smoke_shapes(llama):
    """The CI acceptance shape: seed-0 prompts, 8 new tokens — int8
    quantization noise must not flip any greedy argmax here."""
    cfg, params = llama
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, size=L) for L in (8, 17, 25, 33)]
    a = LLMEngine(cfg, params, kv_layout="paged", num_pages=64, page_size=8)
    want = _greedy(a, prompts, 8)
    a.close()
    b = LLMEngine(cfg, params, kv_layout="paged", num_pages=64, page_size=8,
                  kv_dtype="int8")
    assert _greedy(b, prompts, 8) == want
    assert b.backend.prefix_stats()["kv_dtype"] == "int8"
    b.close()


def test_inflight_prefix_match_same_flush(llama):
    """Two same-prefix requests admitted in ONE flush share the pages the
    first is about to write (vLLM-style in-flight matching) instead of
    prefilling twice — and still both produce the reference tokens."""
    cfg, params = llama
    rng = np.random.default_rng(2)
    shared = rng.integers(1, cfg.vocab, size=17)   # 2 full pages + 1

    ref_eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                        page_size=8)
    want = _greedy(ref_eng, [shared], 5)[0]
    ref_eng.close()

    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=8, max_batch=4)
    for i in range(3):
        eng.add_request(prompt=np.array(shared),
                        sampling=SamplingParams(max_tokens=5), uid=i)
    outs = []
    while len(outs) < 3:
        outs.extend(o for o in eng.step() if o.finished)
    assert eng.backend.stats["inflight_pages_reused"] > 0
    for o in outs:
        assert [int(t) for t in o.tokens] == want
    eng.close()


def test_stream_push_iterator(llama):
    """The async push surface: two concurrent streams over one engine,
    each sees exactly its own increments (with detokenized text), and the
    reassembled tokens match generate()."""
    cfg, params = llama
    rng = np.random.default_rng(3)
    prompts = [rng.integers(1, cfg.vocab, size=n) for n in (12, 20)]

    ref_eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                        page_size=8)
    want = _greedy(ref_eng, prompts, 6)
    ref_eng.close()

    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=8,
                    detokenizer=lambda toks: ",".join(
                        str(int(t)) for t in toks))

    async def consume(i):
        toks, texts = [], []
        async for out in eng.stream(prompt=np.array(prompts[i]),
                                    sampling=SamplingParams(max_tokens=6)):
            toks.extend(int(t) for t in out.new_tokens)
            texts.append(out.text)
        return toks, texts

    async def both():
        return await asyncio.gather(consume(0), consume(1))

    (t0, x0), (t1, x1) = asyncio.run(both())
    assert t0 == want[0] and t1 == want[1]
    # text is the detokenized increment, present on every emission
    assert all(x is not None for x in x0 + x1)
    assert ",".join(str(t) for t in t0) == ",".join(x for x in x0 if x)
    assert eng._stream_q == {}   # buffers torn down
    eng.close()
