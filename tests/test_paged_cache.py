"""Paged KV-cache control plane: pool, prefix cache, placement models."""

import numpy as np
import pytest

from repro.cache import layout
from repro.cache.pool import NULL_PAGE, OutOfPages, PagePool
from repro.cache.prefix import PrefixCache, page_hashes
from repro.core import cache_sim, numa, perf_model


# --- PagePool ----------------------------------------------------------------


def test_pool_alloc_free_roundtrip():
    pool = PagePool(num_pages=8, page_size=4)
    assert pool.free_pages == 7  # page 0 reserved
    pids = [pool.alloc() for _ in range(7)]
    assert NULL_PAGE not in pids
    assert len(set(pids)) == 7
    with pytest.raises(OutOfPages):
        pool.alloc()
    for p in pids:
        assert pool.decref(p)
    assert pool.free_pages == 7


def test_pool_sequence_grows_page_at_a_time():
    pool = PagePool(num_pages=16, page_size=4)
    seq = pool.allocate_sequence(5)  # 2 pages
    assert seq.num_pages() == 2 and seq.length == 5
    # tokens 5..7 fill page 2; token 8 opens page 3
    for expect_pages in (2, 2, 2, 3):
        pid, off, cow = pool.append_token(seq)
        assert cow is None
        assert seq.num_pages() == expect_pages
        assert pid == seq.tail_page()
    assert off == 0  # first slot of the new page
    freed = pool.release(seq)
    assert freed == 3
    assert pool.free_pages == 15


def test_pool_shared_prefix_refcounts():
    pool = PagePool(num_pages=16, page_size=4)
    a = pool.allocate_sequence(8)
    b = pool.allocate_sequence(8, shared_prefix=list(a.pages))
    assert b.pages == a.pages
    for p in a.pages:
        assert pool.refcount(p) == 2
    assert pool.release(a) == 0     # b still holds them
    assert pool.release(b) == 2


def test_pool_copy_on_write_on_fork():
    pool = PagePool(num_pages=16, page_size=4)
    a = pool.allocate_sequence(6)   # partial tail (2 tokens in page 2)
    b = pool.fork(a)
    tail = a.tail_page()
    assert pool.refcount(tail) == 2
    pid, off, cow = pool.append_token(b)
    assert cow == (tail, pid)       # b got a private copy of the tail
    assert pid != tail and off == 2
    assert pool.refcount(tail) == 1  # a's again
    # a appends into its (now exclusive) tail without COW
    pid_a, off_a, cow_a = pool.append_token(a)
    assert cow_a is None and pid_a == tail and off_a == 2


def test_pool_allocation_rollback():
    pool = PagePool(num_pages=4, page_size=4)  # 3 usable
    with pytest.raises(OutOfPages):
        pool.allocate_sequence(17)  # needs 5
    assert pool.free_pages == 3  # nothing leaked


# --- PrefixCache -------------------------------------------------------------


def test_page_hashes_chain_depends_on_prefix():
    ps = 4
    a = page_hashes([1, 2, 3, 4, 5, 6, 7, 8], ps)
    b = page_hashes([1, 2, 3, 4, 5, 6, 7, 8, 9], ps)  # partial tail ignored
    c = page_hashes([9, 2, 3, 4, 5, 6, 7, 8], ps)
    assert len(a) == 2 and a == b
    # same second page content, different first page => different chain hash
    assert a[1] != c[1]


def test_prefix_cache_longest_prefix_and_refs():
    pool = PagePool(num_pages=16, page_size=4)
    cache = PrefixCache(pool)
    toks = list(range(1, 13))  # 3 full pages
    seq = pool.allocate_sequence(12)
    h = page_hashes(toks, 4)
    cache.insert(h, seq.pages)
    for p in seq.pages:
        assert pool.refcount(p) == 2  # seq + cache
    # a request sharing the first 2 pages
    got = cache.lookup(page_hashes(toks[:8] + [99, 98, 97, 96], 4))
    assert got == seq.pages[:2]
    # diverging immediately: no match
    assert cache.lookup(page_hashes([7] + toks[1:], 4)) == []
    assert cache.hit_rate > 0


def test_prefix_cache_eviction_skips_live_pages():
    pool = PagePool(num_pages=8, page_size=4)
    cache = PrefixCache(pool)
    seq = pool.allocate_sequence(8)
    cache.insert(page_hashes(list(range(8)), 4), seq.pages)
    # live sequence still references the pages: evicting frees nothing
    assert cache.evict(2) == 0
    assert len(cache) == 2
    pool.release(seq)
    # now only the cache holds them
    assert cache.evict(2) == 2
    assert pool.free_pages == 7


# --- placement / traffic models ---------------------------------------------


def _mixed_tables(ps=16, batch=4, shared_pages=2):
    rng = np.random.default_rng(0)
    shared = list(range(1, 1 + shared_pages))
    tables, lengths = [], []
    next_pid = 1 + shared_pages
    for i in range(batch):
        own = rng.integers(1, 4)
        tables.append(shared + list(range(next_pid, next_pid + own)))
        next_pid += own
        lengths.append((shared_pages + own - 1) * ps + int(rng.integers(1, ps + 1)))
    return tables, lengths


def test_head_aligned_placement_is_all_local():
    tables, lengths = _mixed_tables()
    both = layout.compare_policies(
        tables, lengths, num_kv_heads=8, page_size=16, head_dim=64,
        topo=numa.MI300X,
    )
    aligned = both[layout.HEAD_ALIGNED]
    naive = both[layout.INTERLEAVED]
    assert aligned.local_fraction == 1.0
    assert aligned.remote_bytes == 0
    assert naive.remote_bytes > 0
    assert naive.local_fraction < 1.0
    # identical logical reads under both policies
    assert aligned.total_bytes == naive.total_bytes
    # shared prefix pages are deduplicated within a domain
    assert aligned.reuse_hits > 0
    assert aligned.time(numa.MI300X) < naive.time(numa.MI300X)


def test_paged_traffic_dedups_shared_prefix():
    ps, hkv = 16, 4
    shared = [[1, 2, 3]] * 4          # four sequences, same physical pages
    private = [[1, 2, 3], [4, 5, 6], [7, 8, 9], [10, 11, 12]]
    lengths = [3 * ps] * 4
    t_shared = layout.decode_page_traffic(
        shared, lengths, num_kv_heads=hkv, page_size=ps, head_dim=64,
        topo=numa.MI300X)
    t_priv = layout.decode_page_traffic(
        private, lengths, num_kv_heads=hkv, page_size=ps, head_dim=64,
        topo=numa.MI300X)
    assert t_shared.total_bytes == t_priv.total_bytes
    assert t_shared.unique_bytes == t_priv.unique_bytes // 4
    assert t_shared.reuse_hits == 3 * 3 * hkv


def test_perf_model_matches_layout_on_uniform_trace():
    """Analytic paged estimate == enumerated traffic on a uniform trace."""
    ps, hkv, hd, batch, pages = 16, 8, 64, 4, 3
    shared_pages = 2
    shared = list(range(1, 1 + shared_pages))
    tables = [shared + [100 + i * pages + j for j in range(pages - shared_pages)]
              for i in range(batch)]
    lengths = [pages * ps] * batch
    for policy in layout.PAGE_POLICIES:
        traffic = layout.decode_page_traffic(
            tables, lengths, num_kv_heads=hkv, page_size=ps, head_dim=hd,
            topo=numa.MI300X, policy=policy)
        est = perf_model.estimate_paged_decode(
            batch=batch, num_q_heads=hkv, num_kv_heads=hkv,
            mean_len=pages * ps, page_size=ps, head_dim=hd, dtype_bytes=2,
            topo=numa.MI300X, policy=policy,
            shared_prefix_len=shared_pages * ps)
        assert est.hbm_bytes == traffic.unique_bytes, policy


def test_cache_sim_paged_cross_check():
    """Event-level LRU replay agrees with the traffic model when the
    working set fits, and ranks the policies the same way."""
    tables, lengths = _mixed_tables()
    kw = dict(num_kv_heads=8, page_size=16, head_dim=64, topo=numa.MI300X)
    sim_a = cache_sim.simulate_paged_decode(tables, lengths,
                                            policy=layout.HEAD_ALIGNED, **kw)
    sim_n = cache_sim.simulate_paged_decode(tables, lengths,
                                            policy=layout.INTERLEAVED, **kw)
    traffic_a = layout.decode_page_traffic(tables, lengths,
                                           policy=layout.HEAD_ALIGNED, **kw)
    assert sim_a.hbm_bytes == traffic_a.unique_bytes
    assert sim_a.local_fraction == 1.0
    assert sim_n.remote_bytes > 0
    assert sim_a.elapsed <= sim_n.elapsed
    assert sim_a.hit_rate > 0  # shared prefix pages hit


def test_dense_vs_paged_estimates_rank_sanely():
    """Short live lengths in long stripes => paged wins; full stripes with
    no sharing => dense at least ties (no page bookkeeping modeled)."""
    topo = numa.MI300X
    kw = dict(batch=8, num_q_heads=32, num_kv_heads=8, head_dim=128,
              dtype_bytes=2, topo=topo)
    dense = perf_model.estimate_dense_decode(capacity=4096, **kw)
    short = perf_model.estimate_paged_decode(
        mean_len=512, page_size=64, policy=layout.HEAD_ALIGNED, **kw)
    full = perf_model.estimate_paged_decode(
        mean_len=4096, page_size=64, policy=layout.HEAD_ALIGNED, **kw)
    assert short.time < dense.time
    assert full.time <= dense.time * 1.01
    assert short.hbm_bytes < dense.hbm_bytes
