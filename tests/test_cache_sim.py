"""Cache-simulator tests: invariants + the paper's qualitative claims.

The quantitative reproduction of Figs. 12-16 lives in benchmarks/; here we
pin the *orderings* the paper establishes, at sizes that run in seconds.
"""

import dataclasses

import pytest

from repro.core import cache_sim, numa, swizzle
from repro.core.cache_sim import AttentionWorkload, compare_mappings, simulate
from repro.core.swizzle import AttentionGrid

TOPO = dataclasses.replace(numa.MI300X)


def wl(h=32, g=1, n=8192, b=1, d=128, pass_="fwd"):
    return AttentionWorkload(
        grid=AttentionGrid(batch=b, num_q_heads=h, blocks_per_head=0, group_size=g),
        seq_len=n, head_dim=d, pass_=pass_,
    )


def test_accounting_invariants():
    r = simulate(swizzle.SWIZZLED_HEAD_FIRST, wl(h=16, n=4096), TOPO, max_wgs=512)
    assert r.hits + r.misses > 0
    assert 0.0 <= r.hit_rate <= 1.0
    per_tensor_total = sum(h + m for h, m in r.per_tensor.values())
    assert per_tensor_total == r.hits + r.misses
    assert r.hbm_bytes > 0
    assert r.elapsed >= max(r.compute_time, r.hbm_time) - 1e-12


def test_paper_ordering_mha_long():
    """H=128, long context: swizzled head-first >> naive head-first >> block-first."""
    res = compare_mappings(wl(h=128, n=32768), TOPO, budget_accesses=1_500_000)
    hit = {m: r.hit_rate for m, r in res.items()}
    assert hit[swizzle.SWIZZLED_HEAD_FIRST] > 0.9          # paper: 90-96 %
    assert hit[swizzle.NAIVE_HEAD_FIRST] < hit[swizzle.SWIZZLED_HEAD_FIRST]
    assert hit[swizzle.NAIVE_BLOCK_FIRST] < 0.1            # paper: ~1 %
    assert hit[swizzle.SWIZZLED_BLOCK_FIRST] < 0.1
    thr = {m: r.throughput for m, r in res.items()}
    base = thr[swizzle.SWIZZLED_HEAD_FIRST]
    assert thr[swizzle.NAIVE_BLOCK_FIRST] < 0.8 * base     # paper: ~0.65-0.75x


def test_paper_small_h_parity():
    """At H=8, short context, all mappings perform comparably (Fig. 12 left)."""
    res = compare_mappings(wl(h=8, n=8192), TOPO)
    base = res[swizzle.SWIZZLED_HEAD_FIRST].throughput
    for m, r in res.items():
        assert r.throughput / base > 0.85, m


def test_gqa_swizzled_block_first_recovers():
    """GQA with groups == domains: swizzled block-first ~ swizzled head-first
    (paper §4.4), while naive block-first still degrades."""
    res = compare_mappings(wl(h=128, g=16, n=16384), TOPO, budget_accesses=1_500_000)
    hit = {m: r.hit_rate for m, r in res.items()}
    assert hit[swizzle.SWIZZLED_BLOCK_FIRST] > 0.9
    assert abs(hit[swizzle.SWIZZLED_BLOCK_FIRST] - hit[swizzle.SWIZZLED_HEAD_FIRST]) < 0.1
    assert hit[swizzle.NAIVE_BLOCK_FIRST] < hit[swizzle.SWIZZLED_BLOCK_FIRST]


def test_backward_pass_ordering():
    """Fig. 16: swizzled head-first fastest; gains smaller than forward."""
    res = compare_mappings(
        wl(h=64, n=16384, pass_="bwd"), TOPO, budget_accesses=1_200_000
    )
    thr = {m: r.throughput for m, r in res.items()}
    assert thr[swizzle.SWIZZLED_HEAD_FIRST] >= thr[swizzle.NAIVE_BLOCK_FIRST]
    assert res[swizzle.SWIZZLED_HEAD_FIRST].hit_rate > 0.8


def test_resident_regime_cold_misses_only():
    """When the whole KV fits in L2, hit rate ~ 1 - cold/total regardless of
    mapping order within a head-first family."""
    r = simulate(swizzle.SWIZZLED_HEAD_FIRST, wl(h=8, n=8192), TOPO)
    kv_tiles = 8192 // 64
    wgs = 8192 // 128
    accesses_per_head = sum(
        1 + 2 * ((m + 1) * 128 // 64) for m in range(wgs)
    )
    cold_frac = 2 * kv_tiles / accesses_per_head
    assert abs((1 - r.hit_rate) - cold_frac) < 0.02
