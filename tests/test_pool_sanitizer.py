"""Page-pool sanitizer: shadow state machine unit + property tests.

(Not in conftest's SANITIZED_MODULES on purpose: these tests construct
their own pools and attach/provoke shadows with intentional violations.)
"""

import random
from collections import Counter

import pytest

from repro.analysis.pool_sanitizer import (
    CowViolationError,
    DoubleFreeError,
    NullPageWriteError,
    ShadowDesyncError,
    ShadowPool,
    UseAfterReleaseError,
    attach,
)
from repro.cache.pool import (
    NULL_PAGE,
    OutOfPages,
    PagePool,
    RefcountLeakError,
    SequencePages,
    SequenceReleasedError,
)

try:  # dev-only dep (requirements-dev.txt); seeded traces run without it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _pool(num_pages=16, page_size=4):
    pool = PagePool(num_pages=num_pages, page_size=page_size)
    return pool, attach(pool)


# --- typed pool errors (satellite: no silent-no-op releases) -----------------


def test_double_release_raises_typed_error():
    pool = PagePool(num_pages=8, page_size=4)  # unsanitized: pool's own check
    seq = pool.allocate_sequence(6)
    pool.release(seq)
    with pytest.raises(SequenceReleasedError):
        pool.release(seq)
    with pytest.raises(SequenceReleasedError):
        pool.append_token(seq)
    with pytest.raises(SequenceReleasedError):
        pool.fork(seq)


def test_check_leaks_explains_refcounts():
    pool = PagePool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(8)
    with pytest.raises(RefcountLeakError) as ei:
        pool.check_leaks()  # caller claims nothing is live
    assert set(ei.value.leaks) == set(seq.pages)
    # claiming the live sequence's refs makes it clean
    assert pool.check_leaks({p: 1 for p in seq.pages}) == {}
    pool.release(seq)
    assert pool.check_leaks() == {}


# --- shadow transitions -------------------------------------------------------


def test_shadow_catches_double_free():
    pool, sh = _pool()
    pid = pool.alloc()
    pool.decref(pid)
    with pytest.raises(DoubleFreeError):
        pool.decref(pid)


def test_shadow_catches_incref_after_free():
    pool, sh = _pool()
    pid = pool.alloc()
    pool.decref(pid)
    with pytest.raises(UseAfterReleaseError):
        pool.incref(pid)


def test_shadow_catches_append_on_released_sequence():
    pool, sh = _pool()
    seq = pool.allocate_sequence(6)
    stale = SequencePages(pages=list(seq.pages), length=seq.length)
    pool.release(seq)
    # `stale` still points at the freed pages (a dropped-not-released
    # table): the shadow sees FREE pages behind a live-looking sequence.
    with pytest.raises(UseAfterReleaseError):
        pool.append_token(stale)


def test_shadow_catches_null_page_write():
    pool, sh = _pool()
    # An engine bug that left a row parked on the null page mid-page:
    seq = SequencePages(pages=[NULL_PAGE], length=2)
    with pytest.raises(NullPageWriteError):
        pool.append_token(seq)


def test_shadow_catches_cow_violation():
    pool, sh = _pool()
    a = pool.allocate_sequence(6)   # partial tail
    b = pool.fork(a)
    # Simulate a buggy pool that appends into the shared tail without
    # emitting the copy instruction.
    real = sh._orig["append_token"]

    def no_cow_append(seq):
        pid, off, _cow = real(seq)
        return pid, off, None

    sh._orig["append_token"] = no_cow_append
    with pytest.raises(CowViolationError):
        pool.append_token(b)


def test_shadow_catches_out_of_band_refcount_mutation():
    pool, sh = _pool()
    pid = pool.alloc()
    pool._refcount[pid] += 1  # some path bypassing the primitives
    with pytest.raises(ShadowDesyncError):
        pool.alloc()


def test_shadow_check_tables_and_detach():
    pool, sh = _pool()
    pid = pool.alloc()
    sh.check_tables([[NULL_PAGE, pid]])  # null placeholder is fine
    pool.decref(pid)
    with pytest.raises(UseAfterReleaseError):
        sh.check_tables([[pid]])
    sh.detach()
    # Unwrapped again: pool's own ValueError, not the shadow's error.
    with pytest.raises(ValueError):
        pool.decref(pid)
    sh.detach()  # idempotent


def test_shadow_passes_clean_lifecycle():
    pool, sh = _pool()
    a = pool.allocate_sequence(8)
    b = pool.fork(a)
    pid, off, cow = pool.append_token(b)   # page-aligned: fresh page, no COW
    assert cow is None
    pid_a, _, cow_a = pool.append_token(a)  # same boundary on the donor
    assert cow_a is None and pid_a != pid
    c = pool.allocate_sequence(4, shared_prefix=list(a.pages[:1]))
    for seq in (a, b, c):
        pool.release(seq)
    sh.check_leaks()
    assert sh.ops > 10


# --- random op traces against the refcount invariant -------------------------


def _run_trace(seed: int, steps: int = 120) -> None:
    """Drive a sanitized pool with random (legal) ops; after every step the
    pool's refcounts must be *exactly* explained by the live page tables —
    `check_leaks(live_refs)` is the reference model, the shadow re-checks
    every transition, and released sequences must refuse further use."""
    rng = random.Random(seed)
    pool = PagePool(num_pages=24, page_size=4)
    sh = attach(pool)
    live = []
    graveyard = []
    for _ in range(steps):
        op = rng.choice(("alloc", "alloc_shared", "append", "fork",
                         "release", "poke_dead"))
        try:
            if op == "alloc":
                live.append(pool.allocate_sequence(rng.randint(1, 24)))
            elif op == "alloc_shared" and live:
                donor = rng.choice(live)
                tokens = rng.randint(1, 24)
                k = min(len(donor.pages),
                        pool.pages_needed(tokens))
                live.append(pool.allocate_sequence(
                    tokens, shared_prefix=list(donor.pages[:k])))
            elif op == "append" and live:
                pool.append_token(rng.choice(live))
            elif op == "fork" and live:
                live.append(pool.fork(rng.choice(live)))
            elif op == "release" and live:
                seq = live.pop(rng.randrange(len(live)))
                pool.release(seq)
                graveyard.append(seq)
            elif op == "poke_dead" and graveyard:
                seq = rng.choice(graveyard)
                with pytest.raises(SequenceReleasedError):
                    pool.release(seq)
                # the shadow's UAF check fires before the pool's own
                # released-flag error; both are in the PoolError family
                with pytest.raises((SequenceReleasedError,
                                    UseAfterReleaseError)):
                    pool.append_token(seq)
        except OutOfPages:
            # Legal outcome; allocation rollback must leave no residue,
            # which the invariant check below proves.
            pass
        expected = Counter(pid for s in live for pid in s.pages)
        pool.check_leaks(dict(expected))
        sh.check_tables([s.pages for s in live])
    for seq in live:
        pool.release(seq)
    sh.check_leaks()


@pytest.mark.parametrize("seed", range(8))
def test_random_traces_seeded(seed):
    _run_trace(seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=2**32 - 1))
    def test_random_traces_hypothesis(seed):
        _run_trace(seed, steps=60)
