"""MoE dispatch tests: oracle equivalence, capacity behaviour, aux losses."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import MoEConfig
from repro.models import moe as moe_lib


def dense_moe_oracle(params, x, cfg: MoEConfig):
    """Per-token explicit top-k mixture (no capacity) — the semantics the
    scatter dispatch must match when capacity is not binding."""
    b, s, d = x.shape
    xt = x.reshape(-1, d)
    logits = xt @ params["router_de"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, cfg.top_k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)
    out = jnp.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xt @ params["wi_gate_edm"][e]) * (xt @ params["wi_up_edm"][e])
        ye = h @ params["wo_emd"][e]
        w = jnp.sum(jnp.where(idx == e, gate, 0.0), axis=-1)
        out = out + ye * w[:, None]
    return out.reshape(b, s, d)


def setup(e=4, k=2, d=16, dff=32, cf=8.0, seed=0):
    cfg = MoEConfig(num_experts=e, top_k=k, d_ff=dff, capacity_factor=cf)
    params = moe_lib.init_moe(jax.random.PRNGKey(seed), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 24, d))
    return cfg, params, x


def test_matches_dense_oracle_when_capacity_ample():
    cfg, params, x = setup(cf=16.0)
    y, aux = moe_lib.moe_ffn(params, x, cfg)
    y_ref = dense_moe_oracle(params, x, cfg)
    assert float(aux["moe_dropped_frac"]) == 0.0
    assert jnp.max(jnp.abs(y - y_ref)) < 1e-4


def test_capacity_drops_tokens():
    cfg, params, x = setup(cf=16.0)
    y, aux = moe_lib.moe_ffn(params, x, cfg, capacity=2)  # absurdly small
    assert float(aux["moe_dropped_frac"]) > 0.2
    assert bool(jnp.all(jnp.isfinite(y)))


def test_aux_losses_sane():
    cfg, params, x = setup()
    _, aux = moe_lib.moe_ffn(params, x, cfg)
    # Perfectly balanced router gives lb_loss == 1; anything >= ~1 is sane.
    assert 0.9 < float(aux["moe_lb_loss"]) < float(cfg.num_experts)
    assert float(aux["moe_z_loss"]) >= 0.0


def test_gradients_flow():
    cfg, params, x = setup()

    def loss(p):
        y, aux = moe_lib.moe_ffn(p, x, cfg)
        return jnp.sum(y**2) + aux["moe_lb_loss"]

    g = jax.grad(loss)(params)
    gnorm = sum(jnp.sum(jnp.abs(v)) for v in jax.tree.leaves(g))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0
    # Router must receive gradient through both gates and the lb loss.
    assert float(jnp.sum(jnp.abs(g["router_de"]))) > 0.0


def test_shared_experts():
    cfg = MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=8.0,
                    num_shared_experts=1)
    params = moe_lib.init_moe(jax.random.PRNGKey(0), 16, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 16))
    y, _ = moe_lib.moe_ffn(params, x, cfg)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))
