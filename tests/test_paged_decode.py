"""Paged flash-decode kernel vs dense flash-decode and the pure-JAX oracle.

All kernel runs are interpret-mode (CPU CI); the page tables are random
permutations of the physical pool with shared prefix pages between rows, so
the page-indexed BlockSpec index map is exercised out of logical order.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.paged_decode_attention import paged_flash_decode


def mk_paged(b, hq, hkv, d, ps, max_pages, seed=0, num_pages=None,
             shared_pages=0, dtype=jnp.float32):
    """Random q/page-pool/table/lengths. Rows share the first
    ``shared_pages`` physical pages (prefix sharing); the rest are a
    shuffled disjoint allocation. Lengths are random, >= shared prefix."""
    rng = np.random.default_rng(seed)
    num_pages = num_pages or (1 + shared_pages + b * max_pages)
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    k_pages = jax.random.normal(ks[1], (hkv, num_pages, ps, d), dtype)
    v_pages = jax.random.normal(ks[2], (hkv, num_pages, ps, d), dtype)
    avail = list(rng.permutation(np.arange(1 + shared_pages, num_pages)))
    pt = np.zeros((b, max_pages), np.int32)
    lengths = np.zeros((b,), np.int32)
    for i in range(b):
        lo = max(shared_pages * ps, 1)
        lengths[i] = rng.integers(lo, max_pages * ps + 1)
        live = -(-int(lengths[i]) // ps)
        row = list(range(1, 1 + min(shared_pages, live)))
        row += [avail.pop() for _ in range(live - len(row))]
        pt[i, :live] = row
    return q, k_pages, v_pages, jnp.asarray(pt), jnp.asarray(lengths)


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 64),       # GQA
    (3, 4, 4, 32),       # MHA
    (1, 25, 5, 64),      # odd group (hymba-like)
    (2, 4, 1, 128),      # MQA (gemma-like)
])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("softcap", [None, 50.0])
def test_paged_vs_oracle(b, hq, hkv, d, window, softcap):
    q, kp, vp, pt, lengths = mk_paged(b, hq, hkv, d, ps=16, max_pages=6,
                                      shared_pages=2)
    o = paged_flash_decode(q, kp, vp, pt, lengths, window=window,
                           softcap=softcap, interpret=True)
    o_ref = ref.paged_decode_attention(q, kp, vp, pt, lengths, window=window,
                                       softcap=softcap)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_paged_matches_dense_flash_decode():
    """Same sequences through the paged and the dense kernels."""
    q, kp, vp, pt, lengths = mk_paged(3, 8, 2, 64, ps=16, max_pages=8, seed=1)
    o_paged = paged_flash_decode(q, kp, vp, pt, lengths, interpret=True)
    k_dense = ref.gather_pages(kp, pt)
    v_dense = ref.gather_pages(vp, pt)
    o_dense = flash_decode(q, k_dense, v_dense, lengths, chunk=32,
                           interpret=True)
    assert jnp.max(jnp.abs(o_paged - o_dense)) < 2e-5


def test_paged_ignores_dead_table_entries():
    """Entries past a sequence's live pages (null-page padded) and data in
    unreferenced physical pages must not leak into the output."""
    q, kp, vp, pt, lengths = mk_paged(2, 4, 2, 32, ps=16, max_pages=4, seed=2)
    o1 = paged_flash_decode(q, kp, vp, pt, lengths, interpret=True)
    # Poison the null page and every unreferenced page.
    live = set()
    ptn = np.asarray(pt)
    for i, L in enumerate(np.asarray(lengths)):
        live |= set(ptn[i, : -(-int(L) // 16)].tolist())
    poison = jnp.asarray(
        [1e6 if p not in live else 0.0 for p in range(kp.shape[1])],
        kp.dtype,
    )[None, :, None, None]
    o2 = paged_flash_decode(q, kp + poison, vp + poison, pt, lengths,
                            interpret=True)
    assert jnp.max(jnp.abs(o1 - o2)) == 0.0


def test_paged_length_zero_row_is_zero():
    q, kp, vp, pt, lengths = mk_paged(3, 8, 2, 64, ps=16, max_pages=4, seed=3)
    lengths = lengths.at[1].set(0)
    o = paged_flash_decode(q, kp, vp, pt, lengths, interpret=True)
    o_ref = ref.paged_decode_attention(q, kp, vp, pt, lengths)
    assert jnp.max(jnp.abs(o[1])) == 0.0
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_paged_shared_prefix_rows_agree():
    """Two rows with identical page tables and lengths produce identical
    outputs for identical queries — the physical sharing is transparent."""
    b, hq, hkv, d, ps = 2, 4, 2, 32, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q1 = jax.random.normal(ks[0], (1, hq, d), jnp.float32)
    q = jnp.concatenate([q1, q1], axis=0)
    kp = jax.random.normal(ks[1], (hkv, 8, ps, d), jnp.float32)
    vp = jax.random.normal(ks[2], (hkv, 8, ps, d), jnp.float32)
    pt = jnp.asarray([[3, 5, 0, 0], [3, 5, 0, 0]], jnp.int32)
    lengths = jnp.asarray([28, 28], jnp.int32)
    o = paged_flash_decode(q, kp, vp, pt, lengths, interpret=True)
    assert jnp.max(jnp.abs(o[0] - o[1])) == 0.0


def test_ops_paged_dispatch():
    q, kp, vp, pt, lengths = mk_paged(2, 8, 2, 64, ps=16, max_pages=4, seed=4)
    o1 = ops.paged_decode_attention(q, kp, vp, pt, lengths, impl="pallas")
    o2 = ops.paged_decode_attention(q, kp, vp, pt, lengths, impl="xla")
    assert jnp.max(jnp.abs(o1 - o2)) < 2e-5
    with pytest.raises(ValueError):
        ops.paged_decode_attention(q, kp, vp, pt, lengths, impl="nope")


def test_page_size_must_be_sublane_multiple():
    q = jnp.zeros((1, 4, 32))
    kp = jnp.zeros((2, 4, 12, 32))  # page_size 12: not a multiple of 8
    pt = jnp.zeros((1, 2), jnp.int32)
    with pytest.raises(ValueError):
        paged_flash_decode(q, kp, kp, pt, jnp.asarray([5], jnp.int32),
                           interpret=True)
