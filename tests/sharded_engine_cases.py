"""Engine-level mesh-sharded serving cases (run in a fresh process).

These are the PR-9 engine acceptance tests: sharded decode bit-matching
the single-device engine (both layouts, fused N in {1, 8}, across
preemption/resume), retrace-flat on the mesh, per-device page budgets,
adaptive scan depth, and leak-free shutdown. The filename deliberately
does NOT match ``test_*.py``: the suite runs this file through
``tests/test_sharded_serving.py::test_sharded_engine_cases_subprocess``
in a fresh interpreter — the first sharded compile can segfault a
long-lived XLA CPU client late in the tier-1 suite (same reason
``test_multidevice.py`` subprocesses its mesh compiles), and a clean
client is also what real sharded serving gets. Run directly with::

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m pytest tests/sharded_engine_cases.py -q

The conftest shadow-pool sanitizer attaches to this module (it is in
``SANITIZED_MODULES``), so every pool refcount is re-verified.
"""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.core import perf_model
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams

NUM_DEVICES = 4

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < NUM_DEVICES,
    reason=f"needs {NUM_DEVICES} devices (set XLA_FLAGS="
           f"--xla_force_host_platform_device_count={NUM_DEVICES})",
)


def wide_cfg():
    """The smoke config widened so ``n_kv_heads`` divides the mesh."""
    return dataclasses.replace(
        registry.get_smoke_config("llama3-8b"),
        n_heads=8, n_kv_heads=4, head_dim=16, d_model=128, d_ff=256,
    )


@pytest.fixture(scope="module")
def llama():
    cfg = wide_cfg()
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def toks_of(out):
    return [int(t) for t in out.tokens]


LAYOUTS = {
    "dense": dict(kv_layout="dense", max_batch=3, cache_len=256,
                  prompt_buckets=(32, 64)),
    "paged": dict(kv_layout="paged", max_batch=3, num_pages=96,
                  page_size=16, max_pages_per_seq=8,
                  prompt_buckets=(16, 32, 64)),
}


def run_at(cfg, params, reqs, n, kw, **extra):
    eng = LLMEngine(cfg, params, steps_per_sync=n, **kw, **extra)
    out = {r.uid: r for r in eng.generate([r.clone() for r in reqs])}
    return eng, out


# --- sharded decode bit-exactness ---------------------------------------------


@pytest.mark.parametrize("layout", ["dense", "paged"])
@pytest.mark.parametrize("n", [1, 8])
def test_sharded_bit_matches_single_device(llama, layout, n):
    """The mesh run is a data-placement change, not a numerics change:
    params replicated, KV head-sharded, the split-K combine and sampler
    reduction the only cross-device traffic — outputs must be IDENTICAL
    to the single-device engine, greedy and seeded-stochastic rows alike,
    at N=1 and through the fused N=8 scan."""
    cfg, params = llama
    rng = np.random.default_rng(90)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20, 33)]
    reqs = [
        Request(uid=0, prompt=prompts[0], max_new_tokens=9),
        Request(uid=1, prompt=prompts[1],
                sampling=SamplingParams(temperature=0.9, top_k=25,
                                        max_tokens=7, seed=3)),
        Request(uid=2, prompt=prompts[2], max_new_tokens=3),
    ]
    kw = LAYOUTS[layout]
    _, base = run_at(cfg, params, reqs, n, kw)
    eng, sharded = run_at(cfg, params, reqs, n, kw, mesh=NUM_DEVICES)
    assert eng.backend.num_devices == NUM_DEVICES
    assert sorted(sharded) == [0, 1, 2]
    for uid in (0, 1, 2):
        assert toks_of(sharded[uid]) == toks_of(base[uid]), (layout, n, uid)
        assert sharded[uid].finish_reason == base[uid].finish_reason
    assert eng.stats().num_devices == NUM_DEVICES
    eng.close()


def test_sharded_bit_matches_across_preemption(llama):
    """Page pressure on the mesh: the head-sharded pool preempts and
    resumes exactly like the single-device pool (page tables are
    replicated host state), so outputs still bit-match."""
    cfg, params = llama
    rng = np.random.default_rng(91)
    prompts = [rng.integers(1, 400, size=(20,)) for _ in range(3)]
    kw = dict(kv_layout="paged", num_pages=12, page_size=16, max_batch=3,
              max_pages_per_seq=4, prompt_buckets=(16, 32))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=30, priority=i)
            for i, p in enumerate(prompts)]
    _, base = run_at(cfg, params, reqs, 4, kw)
    eng, sharded = run_at(cfg, params, reqs, 4, kw, mesh=NUM_DEVICES)
    stats = eng.stats()
    assert stats.preemptions >= 1
    assert stats.resumed_tokens > 0
    for uid in (0, 1, 2):
        assert toks_of(sharded[uid]) == toks_of(base[uid]), uid
    assert eng.backend.check_leaks() == {}
    eng.close()
    assert eng.backend.pool.used_pages == 0


def test_sharded_retrace_flat_after_warmup(llama):
    """Sharding constraints ride inside the same jit keys: after the
    first sync compiles on the mesh, later request waves add ZERO decode
    traces."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16,), steps_per_sync=4,
                    mesh=NUM_DEVICES)
    rng = np.random.default_rng(92)

    def wave(uid0):
        return [Request(uid=uid0 + i,
                        prompt=rng.integers(1, 400, size=(8 + i,)),
                        max_new_tokens=6) for i in range(2)]

    eng.generate(wave(0))
    warm = eng.backend.stats["decode_traces"]
    assert warm >= 1
    for k in (10, 20, 30):
        eng.generate(wave(k))
        assert eng.backend.stats["decode_traces"] == warm
    eng.close()


# --- per-device page budgets --------------------------------------------------


def test_per_device_page_budgets(llama):
    """``device_hbm_bytes`` caps the pool at the smallest device's
    capacity (pages span every device, so the min rules), and the
    engine still serves correctly inside the clamped pool."""
    cfg, params = llama
    # Wide smoke config on 4 devices: one KV head per device, so a page
    # slice is 2 (k+v) * 2 layers * 1 head * 16 tokens * 16 dims * 4 B.
    slice_bytes = 2 * cfg.n_layers * 1 * 16 * 16 * 4
    hetero = (20 * slice_bytes, 10 * slice_bytes,
              20 * slice_bytes, 20 * slice_bytes)
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=16, max_batch=2, max_pages_per_seq=4,
                    prompt_buckets=(16,), mesh=NUM_DEVICES,
                    device_hbm_bytes=hetero)
    budgets = eng.backend.device_page_budgets()
    assert budgets["capacities"] == (20, 10, 20, 20)
    assert budgets["limiting_device"] == 1
    assert budgets["effective_num_pages"] == 10
    assert eng.backend.pool.num_pages == 10
    rng = np.random.default_rng(93)
    out = eng.generate([Request(uid=0, prompt=rng.integers(1, 400, (8,)),
                                max_new_tokens=4)])
    assert len(out[0].tokens) == 4
    assert eng.backend.check_leaks() == {}
    eng.close()
    assert eng.backend.pool.used_pages == 0


def test_page_budget_too_small_names_limiting_device(llama):
    cfg, params = llama
    slice_bytes = 2 * cfg.n_layers * 1 * 16 * 16 * 4
    with pytest.raises(ValueError, match="device"):
        LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                  page_size=16, max_batch=2, max_pages_per_seq=4,
                  prompt_buckets=(16,), mesh=NUM_DEVICES,
                  device_hbm_bytes=3 * slice_bytes)


# --- adaptive fused-scan depth ------------------------------------------------


def test_adaptive_steps_per_sync_in_stats(llama):
    """``steps_per_sync='auto'``: the scheduler re-picks N from the live
    batch's modeled tick before every admission, and the chosen depth
    lands in ``stats()`` alongside the mesh width."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=96,
                    page_size=16, max_batch=3, max_pages_per_seq=8,
                    prompt_buckets=(16, 32), steps_per_sync="auto",
                    mesh=NUM_DEVICES)
    rng = np.random.default_rng(94)
    out = eng.generate([Request(uid=i, prompt=rng.integers(1, 400, (10,)),
                                max_new_tokens=6) for i in range(2)])
    assert sorted(r.uid for r in out) == [0, 1]
    stats = eng.stats()
    n = stats.steps_per_sync
    assert n == eng.steps_per_sync
    assert 1 <= n <= perf_model.MAX_STEPS_PER_SYNC
    assert n & (n - 1) == 0
    assert stats.num_devices == NUM_DEVICES
    assert eng.backend.check_leaks() == {}
    eng.close()


# --- sharded placement of the caches ------------------------------------------


def test_pool_pages_are_head_sharded(llama):
    """The pool's page arrays live head-sharded on the mesh (the
    device-local half of the tentpole): every ``k_pages``/``v_pages``
    leaf carries a NamedSharding splitting the KV-head axis over
    ``model``; dense serving caches shard their head axis too."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=32,
                    page_size=16, max_batch=2, max_pages_per_seq=4,
                    prompt_buckets=(16,), mesh=NUM_DEVICES)
    found = []

    def visit(path, leaf):
        name = "".join(getattr(p, "key", "") for p in path)
        if "k_pages" in name or "v_pages" in name:
            spec = leaf.sharding.spec
            assert "model" in tuple(spec), (name, spec)
            head_axis = tuple(spec).index("model")
            assert leaf.shape[head_axis] == cfg.n_kv_heads
            found.append(name)

    jax.tree_util.tree_map_with_path(visit, eng.backend.caches)
    assert found, "no paged leaves inspected"
    eng.close()
