"""Split-K decode: parity with the one-pass kernels and the oracles.

The PR-4 acceptance sweep: dense + paged split-K decode vs the one-pass
kernels and the ``ref.py`` oracles across ``num_splits in {1, 2, 7}``,
non-divisible split boundaries, sliding window, softcap, GQA/MQA, and
length-0 rows — plus the plan layer's occupancy-driven split choice and
the provable domain alignment of the paged split ranges.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import layout
from repro.kernels import decode_common, ops, ref
from repro.kernels import plan as plan_lib
from repro.kernels.decode_attention import flash_decode
from repro.kernels.paged_decode_attention import paged_flash_decode


def mk(b, hq, hkv, smax, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), dtype)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), dtype)
    return q, kc, vc


def mk_paged(b, hq, hkv, d, ps, max_pages, seed=0, dtype=jnp.float32):
    """Random q / head-major pool / shuffled page tables / lengths."""
    rng = np.random.default_rng(seed)
    num_pages = 1 + b * max_pages
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kp = jax.random.normal(ks[1], (hkv, num_pages, ps, d), dtype)
    vp = jax.random.normal(ks[2], (hkv, num_pages, ps, d), dtype)
    avail = list(rng.permutation(np.arange(1, num_pages)))
    pt = np.zeros((b, max_pages), np.int32)
    lengths = np.zeros((b,), np.int32)
    for i in range(b):
        lengths[i] = rng.integers(1, max_pages * ps + 1)
        live = -(-int(lengths[i]) // ps)
        pt[i, :live] = [avail.pop() for _ in range(live)]
    return q, kp, vp, jnp.asarray(pt), jnp.asarray(lengths)


# --- dense split-K -----------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,smax,d,chunk", [
    (2, 8, 2, 1024, 64, 128),     # GQA
    (1, 25, 5, 512, 64, 64),      # hymba-like odd group
    (2, 4, 1, 512, 128, 128),     # MQA (gemma-like)
])
@pytest.mark.parametrize("num_splits", [1, 2, 7])
@pytest.mark.parametrize("window,softcap", [(None, None), (64, 50.0)])
def test_dense_split_parity(b, hq, hkv, smax, d, chunk, num_splits, window,
                            softcap):
    """Split-K output matches the one-pass kernel and both oracles to fp32
    tolerance. num_splits=7 over 8/4 chunks exercises non-divisible
    boundaries (uneven ranges + an empty trailing range)."""
    q, kc, vc = mk(b, hq, hkv, smax, d)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, smax + 1, size=(b,)), jnp.int32
    )
    kw = dict(window=window, softcap=softcap)
    o = flash_decode(q, kc, vc, lengths, chunk=chunk,
                     num_splits=num_splits, interpret=True, **kw)
    o_one = flash_decode(q, kc, vc, lengths, chunk=chunk, interpret=True, **kw)
    o_ref = ref.decode_attention(q, kc, vc, lengths, **kw)
    o_split_ref = ref.split_decode_attention(
        q, kc, vc, lengths, num_splits=num_splits, granule=chunk, **kw
    )
    assert jnp.max(jnp.abs(o - o_one)) < 2e-5
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5
    assert jnp.max(jnp.abs(o - o_split_ref)) < 2e-5


def test_dense_split_length_zero_row_is_zero():
    """A length-0 row has no live split: every partial carries the empty
    (0, -inf, 0) state and the combine's l == 0 guard emits exact zeros."""
    q, kc, vc = mk(3, 8, 2, 512, 64, seed=3)
    lengths = jnp.asarray([0, 17, 512], jnp.int32)
    o = flash_decode(q, kc, vc, lengths, chunk=128, num_splits=2,
                     interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o[0])) == 0.0
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_dense_split_window_inside_one_split():
    """A window much smaller than a split range: only one split sees
    relevant chunks, the rest must contribute empty states."""
    q, kc, vc = mk(2, 8, 2, 1024, 64, seed=4)
    lengths = jnp.asarray([700, 1024], jnp.int32)
    for window in (8, 100):
        o = flash_decode(q, kc, vc, lengths, window=window, chunk=128,
                         num_splits=4, interpret=True)
        o_ref = ref.decode_attention(q, kc, vc, lengths, window=window)
        assert jnp.max(jnp.abs(o - o_ref)) < 2e-5, window


def test_dense_split_clamps_to_chunk_count():
    """num_splits > chunks degenerates gracefully (one chunk per split)."""
    q, kc, vc = mk(2, 8, 2, 256, 64, seed=5)
    lengths = jnp.asarray([100, 256], jnp.int32)
    o = flash_decode(q, kc, vc, lengths, chunk=128, num_splits=64,
                     interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


# --- paged split-K -----------------------------------------------------------


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 64),       # GQA
    (1, 25, 5, 64),      # odd group
    (2, 4, 1, 128),      # MQA
])
@pytest.mark.parametrize("num_splits", [1, 2, 7])
@pytest.mark.parametrize("window,softcap", [(None, None), (24, 50.0)])
def test_paged_split_parity(b, hq, hkv, d, num_splits, window, softcap):
    """Paged split-K vs the one-pass paged kernel and the gather oracle;
    8 pages into 7 splits exercises non-divisible page ranges."""
    q, kp, vp, pt, lengths = mk_paged(b, hq, hkv, d, ps=16, max_pages=8)
    kw = dict(window=window, softcap=softcap)
    o = paged_flash_decode(q, kp, vp, pt, lengths, num_splits=num_splits,
                           interpret=True, **kw)
    o_one = paged_flash_decode(q, kp, vp, pt, lengths, interpret=True, **kw)
    o_ref = ref.paged_decode_attention(q, kp, vp, pt, lengths, **kw)
    assert jnp.max(jnp.abs(o - o_one)) < 2e-5
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_paged_split_length_zero_row_is_zero():
    q, kp, vp, pt, lengths = mk_paged(3, 8, 2, 64, ps=16, max_pages=6, seed=3)
    lengths = lengths.at[1].set(0)
    o = paged_flash_decode(q, kp, vp, pt, lengths, num_splits=3,
                           interpret=True)
    o_ref = ref.paged_decode_attention(q, kp, vp, pt, lengths)
    assert jnp.max(jnp.abs(o[1])) == 0.0
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_paged_split_matches_dense_split():
    """Same sequences through the paged and dense split kernels (page size
    as the dense chunk), same split count."""
    q, kp, vp, pt, lengths = mk_paged(3, 8, 2, 64, ps=16, max_pages=8, seed=1)
    o_paged = paged_flash_decode(q, kp, vp, pt, lengths, num_splits=3,
                                 interpret=True)
    k_dense = ref.gather_pages(kp, pt)
    v_dense = ref.gather_pages(vp, pt)
    o_dense = flash_decode(q, k_dense, v_dense, lengths, chunk=16,
                           num_splits=3, interpret=True)
    assert jnp.max(jnp.abs(o_paged - o_dense)) < 2e-5


# --- split boundaries: domain alignment --------------------------------------


def test_split_ranges_are_domain_aligned_under_head_major_pool():
    """The kernel's split boundaries (decode_split_ranges) must be provably
    domain-pure under the head-aligned placement the pool uses — for every
    head, split count, and table width — and provably NOT under the naive
    interleaved placement (why the pool is head-major)."""
    for max_pages, num_splits in [(8, 2), (8, 7), (13, 4), (16, 16), (5, 2)]:
        ranges = layout.decode_split_ranges(max_pages, num_splits)
        # page-granular, contiguous, covering
        assert ranges[0][0] == 0 and ranges[-1][1] == max_pages
        for (a0, a1), (b0, b1) in zip(ranges, ranges[1:]):
            assert a1 == b0 and a0 <= a1
        for hkv in (1, 2, 8):
            for h in range(hkv):
                assert layout.split_ranges_domain_aligned(
                    ranges, head=h, policy=layout.HEAD_ALIGNED,
                    num_kv_heads=hkv, num_domains=8,
                )
    wide = layout.decode_split_ranges(8, 2)  # 4-page ranges
    assert not layout.split_ranges_domain_aligned(
        wide, head=0, policy=layout.INTERLEAVED, num_kv_heads=8,
        num_domains=8,
    )


# --- plan-driven dispatch ----------------------------------------------------


def test_plan_chooses_splits_by_occupancy():
    """The occupancy model splits exactly when cells x splits can cover
    idle domains at long context, and never at high occupancy."""
    # B x Hkv = 1 on the 2-domain megacore topology, 32k context: split.
    lonely = plan_lib.plan_attention(
        (1, 4, 1, 1, 32768, 64), phase=plan_lib.DECODE, backend="cpu",
        dtype_bytes=4,
    )
    assert lonely.num_splits > 1
    # A full batch (cells >> domains): one pass.
    busy = plan_lib.plan_attention(
        (8, 8, 2, 1, 2048, 64), phase=plan_lib.DECODE, backend="cpu",
    )
    assert busy.num_splits == 1
    # Paged plans pick splits too (page granule), at B*Hkv < domains.
    paged = plan_lib.plan_attention(
        (1, 32, 4, 1, 32768, 128), phase=plan_lib.DECODE,
        kv_layout=plan_lib.PAGED, page_size=64, backend="gpu",
    )
    assert paged.num_splits > 1
    # Non-decode phases never split.
    assert plan_lib.plan_attention((2, 8, 2, 512, 512, 64)).num_splits == 1


def test_ops_decode_executes_plan_num_splits():
    """ops.decode_attention / paged_decode_attention run whatever split
    count rides the plan and stay parity-clean — no call-site changes."""
    q, kc, vc = mk(2, 8, 2, 512, 64, seed=6)
    lengths = jnp.asarray([100, 300], jnp.int32)
    base = plan_lib.plan_attention(
        (2, 8, 2, 1, 512, 64), phase=plan_lib.DECODE, backend="cpu",
        impl="pallas",
    )
    split_plan = dataclasses.replace(base, num_splits=3)
    o = ops.decode_attention(q, kc, vc, lengths, plan=split_plan)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5

    q2, kp, vp, pt, lengths2 = mk_paged(2, 8, 2, 64, ps=16, max_pages=6,
                                        seed=7)
    pbase = plan_lib.plan_attention(
        (2, 8, 2, 1, 96, 64), phase=plan_lib.DECODE,
        kv_layout=plan_lib.PAGED, page_size=16, backend="cpu", impl="pallas",
    )
    psplit = dataclasses.replace(pbase, num_splits=2)
    o2 = ops.paged_decode_attention(q2, kp, vp, pt, lengths2, plan=psplit)
    o2_ref = ref.paged_decode_attention(q2, kp, vp, pt, lengths2)
    assert jnp.max(jnp.abs(o2 - o2_ref)) < 2e-5


def test_split_estimate_charges_combine_overhead():
    """estimate_decode_splits: the combine cost is explicit — at short
    context the launch overhead outweighs the occupancy win and the model
    keeps one pass even at B x Hkv = 1."""
    from repro.core import numa, perf_model

    short = perf_model.estimate_decode_splits(
        batch=1, num_q_heads=4, num_kv_heads=1, seq_kv=1024, granule=128,
        head_dim=64, dtype_bytes=2, topo=numa.TPU_V5P_MEGACORE,
    )
    assert short.num_splits == 1
    long = perf_model.estimate_decode_splits(
        batch=1, num_q_heads=4, num_kv_heads=1, seq_kv=131072, granule=128,
        head_dim=64, dtype_bytes=2, topo=numa.TPU_V5P_MEGACORE,
    )
    assert long.num_splits > 1 and long.speedup > 1.0
    assert long.times[0][1] == long.base_time
    # With all domains already covered, splitting never wins.
    full = perf_model.estimate_decode_splits(
        batch=16, num_q_heads=32, num_kv_heads=8, seq_kv=131072, granule=128,
        head_dim=128, dtype_bytes=2, topo=numa.MI300X,
    )
    assert full.num_splits == 1


def test_combine_split_states_empty_and_all_empty():
    """The shared combine: empty splits vanish, all-empty rows emit zeros."""
    g, d = 8, 16
    acc = jnp.zeros((2, 3, g, d))
    m = jnp.full((2, 3, g, 1), decode_common.NEG_INF)
    l = jnp.zeros((2, 3, g, 1))
    # row 0: split 1 live, others empty; row 1: all empty.
    acc = acc.at[0, 1].set(2.0)
    m = m.at[0, 1].set(0.5)
    l = l.at[0, 1].set(2.0)
    out = decode_common.combine_split_states(acc, m, l)
    assert jnp.allclose(out[0], 1.0)       # 2.0 / 2.0, empties contribute 0
    assert jnp.max(jnp.abs(out[1])) == 0.0  # l* == 0 guard
