"""Mesh-sharded serving: KV-head-sharded pool + sharded fused decode.

PR-9 acceptance criteria covered here and in
``tests/sharded_engine_cases.py`` (the engine-level half, run in a
fresh subprocess below):
  * sharded decode (4-device 1-D ``model`` mesh, head-sharded KV)
    bit-matches the single-device engine for BOTH kv layouts, with the
    fused scan at N in {1, 8}, and across preemption/resume under page
    pressure;
  * the retrace counter stays FLAT after warmup on the mesh, and the
    head-sharded pool leaks zero pages per device at shutdown (the
    shadow sanitizer auto-attaches to the cases module via
    ``conftest.py``);
  * per-device page budgets (``device_hbm_bytes``) clamp the pool to
    the *smallest* device and name the limiting device when nothing
    fits;
  * ``plan_attention`` scores (domain, device) placement jointly:
    device-pure split-K ranges win when the inter-device tier is slower
    than local HBM, and straddled ranges win when a fast fabric makes
    the extra aggregate bandwidth worth the crossing — BOTH directions
    pinned;
  * the adaptive fused-scan depth (``steps_per_sync="auto"``) lands in
    ``stats()`` and respects the ``MAX_STEPS_PER_SYNC`` cap.

The placement-model and shard-math tests here run in-process anywhere
(no devices needed). The engine cases run in a subprocess that forces 4
virtual CPU devices — same idiom as ``test_multidevice.py``, because a
long-lived XLA CPU client can segfault on its first *sharded* compile
late in the tier-1 suite, and a fresh client is also what real sharded
serving gets.
"""

import os
import subprocess
import sys

import pytest

from repro.cache import layout as layout_lib
from repro.core import numa, perf_model
from repro.distributed import sharding as sharding_lib
from repro.kernels import plan as plan_lib

NUM_DEVICES = 4


# --- shard math ---------------------------------------------------------------


def test_kv_head_shards_match_device_of_head():
    """The pool's contiguous head shards and the placement helper agree
    on which device owns every KV head, for every mesh width."""
    for d in (1, 2, 4):
        shards = sharding_lib.kv_head_shards(8, d)
        assert len(shards) == d
        for h in range(8):
            owner = layout_lib.device_of_head(h, 8, d)
            lo, hi = shards[owner]
            assert lo <= h < hi, (d, h, owner)
    with pytest.raises(ValueError, match="divide"):
        sharding_lib.kv_head_shards(6, 4)


# --- joint (domain, device) placement model -----------------------------------


SLOW_LINK = 1e9      # fabric far below one domain's HBM stream
FAST_LINK = 1e13     # fabric above the whole chip's HBM


def _split(num_kv_heads, link_bw, num_devices=NUM_DEVICES):
    chip = numa.MI300X
    return perf_model.estimate_decode_splits(
        batch=1, num_q_heads=2 * num_kv_heads, num_kv_heads=num_kv_heads,
        seq_kv=32768, granule=16, head_dim=128, dtype_bytes=2, topo=chip,
        mesh=numa.mesh_topology(num_devices, chip=chip,
                                device_link_bw=link_bw),
    )


def test_split_model_prefers_device_pure_on_slow_fabric():
    """When the inter-device tier is slower than local HBM, split ranges
    that stay inside one device's head shard must win."""
    est = _split(num_kv_heads=4, link_bw=SLOW_LINK)
    assert est.device_pure is True
    assert est.num_devices == NUM_DEVICES


def test_split_model_prefers_straddling_on_fast_fabric():
    """The reverse direction: with few KV heads (2 owners for 4 devices)
    and a fabric faster than the owners' combined HBM, straddled ranges
    tap all four devices' bandwidth and must win."""
    est = _split(num_kv_heads=2, link_bw=FAST_LINK)
    assert est.device_pure is False
    # Same head count on the slow fabric flips back to device-pure.
    assert _split(num_kv_heads=2, link_bw=SLOW_LINK).device_pure is True


def test_split_model_single_device_unchanged():
    """No mesh: the estimate carries no placement verdict and matches
    the single-device formula (num_devices=1)."""
    chip = numa.MI300X
    est = perf_model.estimate_decode_splits(
        batch=1, num_q_heads=8, num_kv_heads=4, seq_kv=32768, granule=16,
        head_dim=128, dtype_bytes=2, topo=chip,
    )
    assert est.device_pure is None
    assert est.num_devices == 1


def test_plan_attention_threads_joint_placement():
    """The plan layer exposes the verdict: ``split_device_pure`` pinned
    in both directions through ``plan_attention``'s mesh knobs."""
    shape = (1, 8, 4, 1, 32768, 128)
    single = plan_lib.plan_attention(
        shape, phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED,
        page_size=16, backend="gpu")
    assert single.num_devices == 1
    assert single.split_device_pure is None
    slow = plan_lib.plan_attention(
        shape, phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED,
        page_size=16, backend="gpu", num_devices=NUM_DEVICES,
        device_link_bw=SLOW_LINK)
    assert slow.num_devices == NUM_DEVICES
    assert slow.split_device_pure is True
    fast = plan_lib.plan_attention(
        (1, 8, 2, 1, 32768, 128), phase=plan_lib.DECODE,
        kv_layout=plan_lib.PAGED, page_size=16, backend="gpu",
        num_devices=NUM_DEVICES, device_link_bw=FAST_LINK)
    assert fast.split_device_pure is False


def test_sharded_estimate_scales_with_devices():
    """Aggregate decode throughput from the sharded estimate grows with
    the mesh (each device streams only its head slice)."""
    kw = dict(batch=8, num_q_heads=8, num_kv_heads=4, mean_len=4096,
              page_size=16, head_dim=128, dtype_bytes=2)
    chip = numa.MI300X
    one = perf_model.estimate_sharded_paged_decode(
        mesh=numa.mesh_topology(1, chip=chip), **kw)
    four = perf_model.estimate_sharded_paged_decode(
        mesh=numa.mesh_topology(4, chip=chip), **kw)
    assert four.tokens_per_second > 2 * one.tokens_per_second
    assert "mesh4" in four.layout


def test_choose_steps_per_sync_bounds():
    pick = perf_model.choose_steps_per_sync
    assert pick(decode_tick_s=1e-3) == 1     # tick dwarfs host overhead
    assert pick(decode_tick_s=1e-7) == perf_model.MAX_STEPS_PER_SYNC
    ns = [pick(decode_tick_s=t) for t in (1e-3, 1e-4, 1e-5, 1e-6, 1e-7)]
    assert ns == sorted(ns)                  # deeper scans as ticks shrink
    assert all(n & (n - 1) == 0 for n in ns)  # powers of two (jit keys)


# --- engine-level mesh cases (fresh process) ----------------------------------


@pytest.mark.slow
def test_sharded_engine_cases_subprocess():
    """Run ``tests/sharded_engine_cases.py`` — bit-exactness vs
    single-device (both layouts, N in {1, 8}, preemption/resume),
    retrace-flat, per-device budgets, adaptive N, head-sharded
    placement, zero leaks — in a fresh interpreter with 4 virtual CPU
    devices (see the cases module's docstring for why)."""
    here = os.path.dirname(__file__)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(here, "..", "src")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else [])
    )
    proc = subprocess.run(
        [sys.executable, "-m", "pytest", "-q",
         os.path.join(here, "sharded_engine_cases.py")],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, \
        f"\n--- stdout ---\n{proc.stdout[-4000:]}\n--- stderr ---\n" \
        f"{proc.stderr[-2000:]}"
    assert " passed" in proc.stdout and "failed" not in proc.stdout, \
        proc.stdout[-1000:]
