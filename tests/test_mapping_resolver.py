"""Tests for the compat layer and the auto mapping resolver.

Covers the PR's acceptance criteria directly:
  * ``resolve_mapping`` prefers kv-resident head-first exactly when
    ``2*S*D*dtype`` fits the VMEM budget (``MappingConfig.resolve_resident``),
  * the HBM traffic model never reports reuse_efficiency > 1,
  * no versioned JAX API (CompilerParams / TPUCompilerParams / AxisType)
    is referenced outside ``src/repro/compat.py``.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import compat
from repro.kernels import ops
from repro.kernels.flash_attention import (
    BLOCK_FIRST,
    HEAD_FIRST,
    MappingConfig,
    hbm_block_fetches,
)


# --- resolve_mapping ---------------------------------------------------------


@pytest.mark.parametrize("seq", [1024, 4096, 131072, 131072 + 128, 262144])
def test_resolver_residency_matches_vmem_budget(seq):
    """kv_resident head-first is chosen exactly when 2*S*D*dtype fits VMEM."""
    d, dtype_bytes = 128, 2
    mc = ops.resolve_mapping((1, 16, 4, seq, seq, d), dtype_bytes=dtype_bytes)
    fits = MappingConfig().resolve_resident(seq, d, dtype_bytes)
    # (budget boundary: 2*131072*128*2 == 64 MiB fits; one block more spills)
    assert mc.kv_resident == fits
    assert mc.order == HEAD_FIRST
    assert mc.acc_parallel


def test_resolver_respects_explicit_budget():
    seq, d = 8192, 128
    assert ops.resolve_mapping((1, 8, 8, seq, seq, d)).kv_resident
    tiny = ops.resolve_mapping(
        (1, 8, 8, seq, seq, d), vmem_budget_bytes=seq * d  # << 2*S*D*2
    )
    assert not tiny.kv_resident


def test_resolver_is_cached_and_hashable():
    a = ops.resolve_mapping((2, 8, 2, 2048, 2048, 64))
    b = ops.resolve_mapping((2, 8, 2, 2048, 2048, 64))
    assert a is b  # same LRU entry
    hash(a)  # usable as a custom_vjp nondiff arg


def test_resolver_backends_agree_on_headline_result():
    """Every modeled backend prefers the paper's swizzled head-first when
    K/V fits; the paper's Fig. 12 headline is backend-independent."""
    for backend in ("cpu", "gpu", "tpu"):
        mc = ops.resolve_mapping((8, 32, 8, 8192, 8192, 128), backend)
        assert (mc.order, mc.kv_resident) == (HEAD_FIRST, True), backend


def test_flash_attention_auto_mapping_runs():
    """ops.flash_attention(mapping=None) resolves and matches the oracle."""
    from repro.kernels import ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    o = ops.flash_attention(q, k, v, causal=True, impl="pallas")
    o_ref = ref.attention(q, k, v, causal=True)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


# --- HBM traffic model -------------------------------------------------------


@pytest.mark.parametrize("seq_q,seq_kv", [(4096, 4096), (200, 2048), (384, 260)])
@pytest.mark.parametrize("order", [HEAD_FIRST, BLOCK_FIRST])
@pytest.mark.parametrize("kv_resident", [True, False])
def test_reuse_efficiency_bounded(seq_q, seq_kv, order, kv_resident):
    r = hbm_block_fetches(
        batch=2, num_q_heads=16, num_kv_heads=4, seq_q=seq_q, seq_kv=seq_kv,
        head_dim=128,
        mapping=MappingConfig(order=order, kv_resident=kv_resident),
    )
    assert 0.0 < r["reuse_efficiency"] <= 1.0
    assert r["total_bytes"] == r["kv_bytes"] + r["q_bytes"]
    assert r["total_bytes"] >= r["ideal_bytes"]


def test_sawtooth_wins_long_context_streaming():
    """ROADMAP 5(a): once K/V spills the VMEM budget (streaming), the
    serpentine sawtooth traversal shares one boundary tile per KV sweep,
    the exact traffic model prices it strictly below linear at equal
    modeled time, and the resolver's tie-break picks it."""
    import dataclasses

    from repro.core import swizzle

    mc = ops.resolve_mapping((1, 16, 4, 262144, 262144, 128), dtype_bytes=2)
    assert not mc.kv_resident          # 256K KV never fits residency
    assert mc.order == HEAD_FIRST
    assert mc.traversal == swizzle.SAWTOOTH
    kw = dict(batch=1, num_q_heads=16, num_kv_heads=4, seq_q=262144,
              seq_kv=262144, head_dim=128, dtype_bytes=2)
    saw = hbm_block_fetches(mapping=mc, **kw)
    lin = hbm_block_fetches(
        mapping=dataclasses.replace(mc, traversal=swizzle.LINEAR), **kw
    )
    assert saw["kv_bytes"] < lin["kv_bytes"]
    assert saw["total_bytes"] < lin["total_bytes"]
    assert 0.0 < saw["reuse_efficiency"] <= 1.0


def test_sawtooth_streaming_kernel_matches_oracle():
    """The serpentine kv index_map + in-kernel tile remap is numerically
    the same attention: odd sweeps visit tiles in reverse, online softmax
    is order-independent up to float tolerance."""
    from repro.core import swizzle
    from repro.kernels import ref
    from repro.kernels.flash_attention import flash_attention_fwd

    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 384, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 384, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 384, 64), jnp.float32)
    mc = MappingConfig(kv_resident=False, block_m=128, block_n=128,
                       traversal=swizzle.SAWTOOTH)
    o, _ = flash_attention_fwd(
        q, k, v, mapping=mc, causal=True, interpret=compat.use_interpret()
    )
    o_ref = ref.attention(q, k, v, causal=True)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_streaming_traffic_counts_tiles():
    """The streaming sweep is num_n tiles per (head, q-block) — a ceil-padded
    seq_kv pays for whole tiles, not raw bytes (the pre-fix math ignored
    num_n and silently under-counted the padded case)."""
    common = dict(batch=1, num_q_heads=4, num_kv_heads=4, seq_q=256,
                  head_dim=64)
    mc = MappingConfig(kv_resident=False)  # block_n = 128
    exact = hbm_block_fetches(seq_kv=256, mapping=mc, **common)
    padded = hbm_block_fetches(seq_kv=257, mapping=mc, **common)  # 3 tiles
    assert padded["kv_bytes"] == exact["kv_bytes"] * 3 // 2


# --- compat layer ------------------------------------------------------------


def test_compat_compiler_params_builds():
    p = compat.tpu_compiler_params(
        dimension_semantics=(compat.PARALLEL, compat.ARBITRARY)
    )
    assert p is not None
    # kwargs pass through to whichever dataclass the installed JAX has
    p2 = compat.tpu_compiler_params(
        dimension_semantics=(compat.PARALLEL,), vmem_limit_bytes=1 << 20
    )
    assert p2.vmem_limit_bytes == 1 << 20


def test_compat_make_mesh_host():
    n = len(jax.devices())
    mesh = compat.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(compat.AXIS_AUTO, compat.AXIS_AUTO),
    )
    assert mesh.axis_names == ("data", "model")
    assert mesh.devices.size == n


def test_compat_interpret_detection():
    assert compat.use_interpret("cpu")
    assert not compat.use_interpret("tpu")
    assert compat.use_interpret() == (not compat.on_tpu())
    assert compat.JAX_VERSION >= (0, 4, 37)


def test_no_versioned_jax_api_outside_compat():
    """The next JAX bump must be a one-file change: only compat.py may name
    the version-dependent symbols. The contract's single implementation is
    the linter's ``compat-only-versioned-jax`` rule (repro.analysis.lint);
    this test just runs it over the live tree."""
    from repro.analysis import run_rules

    assert run_rules(rules=["compat-only-versioned-jax"]) == []
