"""Serving-engine tests: continuous batching == direct greedy decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving.engine import PagedServingEngine, Request, ServingEngine


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def direct_greedy(cfg, params, prompt, n_new, cache_len=256):
    lg, caches = transformer.prefill(
        params, cfg, jnp.asarray(prompt)[None], cache_len=cache_len
    )
    toks, lengths = [], jnp.array([len(prompt)], jnp.int32)
    nxt = int(jnp.argmax(lg[0]))
    for _ in range(n_new):
        toks.append(nxt)
        lengths = lengths + 1
        lg, caches = transformer.decode_step(
            params, cfg, jnp.asarray([nxt]), caches, lengths
        )
        nxt = int(jnp.argmax(lg[0]))
    return toks


def test_continuous_batching_matches_direct(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, num_slots=3, cache_len=256,
                        prompt_buckets=(32, 64))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20, 33, 11, 40)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert len(results) == len(reqs)
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 5)
        assert [int(t) for t in r.tokens] == want, r.uid


def test_slot_reuse(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, num_slots=1, cache_len=128,
                        prompt_buckets=(16,))
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(1, 400, size=(10,)),
                    max_new_tokens=3) for i in range(4)]
    results = eng.run(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2, 3]


def test_eos_terminates(llama):
    cfg, params = llama
    prompt = np.random.default_rng(2).integers(1, 400, size=(12,))
    ref_toks = direct_greedy(cfg, params, prompt, 8, cache_len=128)
    eos = ref_toks[2]
    eng = ServingEngine(cfg, params, num_slots=1, cache_len=128,
                        prompt_buckets=(16,))
    res = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=int(eos))])
    assert len(res[0].tokens) == 3  # stopped right after emitting EOS


# --- paged engine (PR 2) -----------------------------------------------------


def test_paged_matches_direct_with_prefix_sharing(llama):
    """Requests sharing a system prompt: pages are reused (hit rate > 0),
    only tails are prefilled, and every output still equals the dense
    direct greedy decode."""
    cfg, params = llama
    rng = np.random.default_rng(3)
    system = rng.integers(1, 400, size=(32,))
    prompts = [np.concatenate([system, rng.integers(1, 400, size=(L,))])
               for L in (5, 18, 2)]
    prompts.append(rng.integers(1, 400, size=(9,)))  # unshared
    eng = PagedServingEngine(cfg, params, num_pages=64, page_size=16,
                             max_batch=3, max_pages_per_seq=8,
                             prompt_buckets=(16, 32, 64))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert len(results) == len(reqs)
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 4)
        assert [int(t) for t in r.tokens] == want, r.uid
    stats = eng.prefix_stats()
    assert stats["prefix_hit_rate"] > 0
    assert stats["pages_reused"] >= 2 * 2  # 32-token prefix = 2 pages, 2 reusers
    # all sequence pages released; only prefix-cache pages remain in use
    assert eng.pool.used_pages == len(eng.prefix)


def test_paged_preemption_under_page_pressure(llama):
    """A pool too small for all concurrent sequences preempts the lowest
    priority one, requeues it, and still completes everything exactly."""
    cfg, params = llama
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 400, size=(20,)) for _ in range(3)]
    # 9 usable pages; each sequence grows to 4 pages (20 + 30 tokens).
    eng = PagedServingEngine(cfg, params, num_pages=10, page_size=16,
                             max_batch=3, max_pages_per_seq=4,
                             prompt_buckets=(16, 32))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=30, priority=i)
            for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2]
    assert eng.prefix_stats()["preemptions"] >= 1
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 30)
        assert [int(t) for t in r.tokens] == want, r.uid


def test_paged_prefix_reuse_survives_eviction_pressure(llama):
    """Admission that must evict prefix-cache pages to fit may never
    recycle the very pages it is about to reuse: here the request matches
    page 1 of a cached 3-page prefix while eviction frees pages 2-3, and
    the decoded output must still be exact."""
    cfg, params = llama
    rng = np.random.default_rng(6)
    prompt_a = rng.integers(1, 400, size=(48,))
    prompt_b = np.concatenate([prompt_a[:16], rng.integers(1, 400, size=(48,))])
    # 5 usable pages: A peaks at 4 and leaves 3 in the prefix cache; B
    # (sharing one page) needs 3 fresh + 1 reserve => 2 cached pages must
    # be evicted while the matched one is in flight.
    eng = PagedServingEngine(cfg, params, num_pages=6, page_size=16,
                             max_batch=1, max_pages_per_seq=5,
                             prompt_buckets=(16, 32, 48, 64))
    reqs = [Request(uid=0, prompt=prompt_a, max_new_tokens=16),
            Request(uid=1, prompt=prompt_b, max_new_tokens=16)]
    results = eng.run(reqs)
    assert sorted(r.uid for r in results) == [0, 1]
    stats = eng.prefix_stats()
    assert stats["pages_reused"] >= 1
    assert eng.stats["prefix_evictions"] >= 2
    for r in results:
        want = direct_greedy(cfg, params, reqs[r.uid].prompt, 16)
        assert [int(t) for t in r.tokens] == want, r.uid


def test_paged_prefill_compile_cache_is_log_bounded(llama):
    """Diverse live prefix lengths must NOT mint one tail-prefill
    compilation each: the jit key buckets prefix pages to powers of two,
    so the cache stays O(log smax) while outputs remain exact."""
    cfg, params = llama
    rng = np.random.default_rng(7)
    base = rng.integers(1, 400, size=(96,))  # 6 full pages once published
    eng = PagedServingEngine(cfg, params, num_pages=64, page_size=16,
                             max_batch=2, max_pages_per_seq=10,
                             prompt_buckets=(16, 32, 64, 96))
    prompts = [base]  # publishes all 6 full pages into the prefix cache
    # Prefixes of 1..6 shared pages, each with a short unique tail.
    for i in range(1, 7):
        prompts.append(
            np.concatenate([base[: 16 * i], rng.integers(1, 400, size=(8,))])
        )
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert len(results) == len(reqs)
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 3)
        assert [int(t) for t in r.tokens] == want, r.uid
    assert eng.stats["extend_prefills"] >= 5  # the sweep hit the extend path
    prefix_keys = {k[1] for k in eng._prefill_p if k[1] > 0}
    # Powers of two only, and logarithmically many despite 6 distinct
    # matched prefix lengths.
    assert all(p & (p - 1) == 0 for p in prefix_keys), prefix_keys
    import math

    assert len(prefix_keys) <= math.ceil(math.log2(eng.max_pages_per_seq)) + 1, \
        prefix_keys


def test_paged_preemption_resumes_generated_tokens(llama):
    """A preempted sequence must resume by replaying its generated tokens
    through the extend path — not restart decode from scratch — and still
    bit-match the direct greedy decode."""
    cfg, params = llama
    rng = np.random.default_rng(8)
    prompts = [rng.integers(1, 400, size=(20,)) for _ in range(3)]
    eng = PagedServingEngine(cfg, params, num_pages=10, page_size=16,
                             max_batch=3, max_pages_per_seq=4,
                             prompt_buckets=(16, 32, 64))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=30, priority=i)
            for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2]
    stats = eng.prefix_stats()
    assert stats["preemptions"] >= 1
    # The victim had decoded tokens before eviction and they were replayed
    # (restart-from-scratch would leave this at 0).
    assert stats["resumed_tokens"] > 0
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 30)
        assert [int(t) for t in r.tokens] == want, r.uid


def test_paged_resume_truncates_oversized_replay(llama):
    """A resumed request whose prompt+generated replay tail exceeds every
    prefill bucket must shed replayed tokens until the tail fits (they are
    regenerated by decode) instead of raising mid-run."""
    cfg, params = llama
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, 400, size=(30,))
    eng = PagedServingEngine(cfg, params, num_pages=32, page_size=16,
                             max_batch=2, max_pages_per_seq=5,
                             prompt_buckets=(16, 32))
    req = Request(uid=0, prompt=prompt, max_new_tokens=45)
    # Seed the prefix cache with the prompt's full page, as a prior
    # admission would have.
    assert eng.submit(req)
    eng._preempt_one(protect=-1)
    # Resume with a 40-token replay: tail 30+40-16 = 54 exceeds bucket 32,
    # so the engine must keep only the 18 replayed tokens that fit
    # (30+18-16 = 32) and re-decode the rest.
    fake = [int(t) for t in rng.integers(1, 400, size=(40,))]
    assert eng.submit(req, resume_tokens=fake)
    row = int(np.flatnonzero(eng.active)[0])
    assert eng.slot_out[row] == fake[:18]
    assert eng.lengths[row] == 30 + 18
    assert eng.stats["resumed_tokens"] == 18


def test_paged_batched_admissions_bit_exact(llama):
    """Batched admission (PR 4): ready requests sharing a jit bucket ride
    one tail-prefill launch — fewer launches, identical tokens vs the
    legacy one-launch-per-request loop, and still equal to direct greedy."""
    cfg, params = llama
    rng = np.random.default_rng(10)
    system = rng.integers(1, 400, size=(32,))
    prompts = []
    for i in range(6):
        tail = rng.integers(1, 400, size=(int(rng.integers(2, 14)),))
        prompts.append(np.concatenate([system, tail]) if i % 3 else tail)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    kw = dict(num_pages=96, page_size=16, max_batch=4, max_pages_per_seq=8,
              prompt_buckets=(16, 32, 64))

    batched = PagedServingEngine(cfg, params, batch_admissions=True, **kw)
    res_b = batched.run([Request(**vars(r)) for r in reqs])
    serial = PagedServingEngine(cfg, params, batch_admissions=False, **kw)
    res_s = serial.run([Request(**vars(r)) for r in reqs])

    toks_b = {r.uid: [int(t) for t in r.tokens] for r in res_b}
    toks_s = {r.uid: [int(t) for t in r.tokens] for r in res_s}
    assert toks_b == toks_s  # bit-exact across the two admission modes
    for uid, toks in toks_b.items():
        assert toks == direct_greedy(cfg, params, prompts[uid], 4), uid
    # The batched engine actually coalesced launches; the serial one never.
    assert batched.stats["batched_prefills"] > 0
    assert batched.stats["prefill_launches"] < serial.stats["prefill_launches"]
    assert serial.stats["batched_prefills"] == 0


def test_paged_batched_extend_rows_share_one_launch(llama):
    """Several requests matching the same cached prefix (same tail bucket
    and page bucket) must extend in ONE launch with per-row prefix
    lengths."""
    cfg, params = llama
    rng = np.random.default_rng(11)
    base = rng.integers(1, 400, size=(32,))
    eng = PagedServingEngine(cfg, params, num_pages=96, page_size=16,
                             max_batch=4, max_pages_per_seq=8,
                             prompt_buckets=(16, 32, 64))
    # Publish the prefix first (its own flush), then three same-bucket
    # extenders arrive together.
    warm = [Request(uid=0, prompt=base, max_new_tokens=2)]
    eng.run(warm)
    launches_before = eng.stats["prefill_launches"]
    tails = [rng.integers(1, 400, size=(6 + i,)) for i in range(3)]
    reqs = [Request(uid=10 + i, prompt=np.concatenate([base, t]),
                    max_new_tokens=3) for i, t in enumerate(tails)]
    results = [r for r in eng.run(reqs) if r.uid >= 10]  # results accumulate
    assert len(results) == 3
    assert eng.stats["extend_prefills"] >= 3
    assert eng.stats["prefill_launches"] == launches_before + 1  # one flush
    assert eng.stats["batched_prefills"] >= 1
    # A (bucket, pages, rows=3) jit key exists — the kernel consumed (B,)
    # prefix/tail lengths in one call.
    assert any(k[2] == 3 and k[1] > 0 for k in eng._prefill_p), \
        sorted(eng._prefill_p)
    for r in results:
        want = direct_greedy(
            cfg, params, np.concatenate([base, tails[r.uid - 10]]), 3
        )
        assert [int(t) for t in r.tokens] == want, r.uid


def test_paged_rejects_unservable_request_at_admission(llama):
    """prompt + max_new_tokens that cannot fit max_pages_per_seq must fail
    at submit, not crash mid-decode."""
    cfg, params = llama
    eng = PagedServingEngine(cfg, params, num_pages=16, page_size=16,
                             max_batch=2, max_pages_per_seq=4,
                             prompt_buckets=(16, 32))
    bad = Request(uid=0, prompt=np.arange(1, 17), max_new_tokens=60)
    with pytest.raises(ValueError, match="outgrow"):
        eng.submit(bad)
    assert eng.pool.used_pages == 0  # nothing leaked


def test_paged_batched_flushes_before_raising(llama):
    """A bad request admitted *after* good ones in the same batched round
    must not strand the good rows unprefilled: the flush runs before the
    ValueError propagates, so a caller that catches it can keep driving
    the engine."""
    cfg, params = llama
    rng = np.random.default_rng(12)
    good = Request(uid=0, prompt=rng.integers(1, 400, size=(10,)),
                   max_new_tokens=3)
    bad = Request(uid=1, prompt=np.arange(1, 17), max_new_tokens=60)
    eng = PagedServingEngine(cfg, params, num_pages=64, page_size=16,
                             max_batch=2, max_pages_per_seq=4,
                             prompt_buckets=(16, 32))
    with pytest.raises(ValueError, match="outgrow"):
        eng.run([good, bad])
    row = int(np.flatnonzero(eng.active)[0])
    assert row in eng._pending_first  # good row's prefill was flushed
    res = eng.run([])  # drain the good request to completion
    assert [int(t) for t in res[0].tokens] == \
        direct_greedy(cfg, params, good.prompt, 3)


def test_paged_pool_must_hold_one_max_sequence(llama):
    """A pool smaller than one max-size sequence would hit OutOfPages
    mid-decode with nothing to preempt; reject at construction."""
    cfg, params = llama
    with pytest.raises(ValueError, match="cannot hold"):
        PagedServingEngine(cfg, params, num_pages=4, page_size=16,
                           max_batch=1, max_pages_per_seq=4,
                           prompt_buckets=(16,))


def test_paged_admission_is_page_governed(llama):
    """With rows to spare but pages for only one sequence at a time, the
    engine serializes admission instead of overcommitting."""
    cfg, params = llama
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 400, size=(30,)) for _ in range(2)]
    # 5 usable pages; a 30-token prompt + 14 new tokens needs 3 pages, so
    # two concurrent sequences (6 pages) never fit -> one at a time.
    eng = PagedServingEngine(cfg, params, num_pages=6, page_size=16,
                             max_batch=4, max_pages_per_seq=3,
                             prompt_buckets=(16, 32), prefix_sharing=False,
                             reserve_pages=1)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=14)
            for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert sorted(r.uid for r in results) == [0, 1]
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 14)
        assert [int(t) for t in r.tokens] == want, r.uid
