"""Serving facade tests: LLMEngine == direct greedy decode, both layouts.

PR-5 acceptance criteria covered here:
  * one public entry point (``LLMEngine(cfg, params, kv_layout=...)``)
    serves mixed batches through both backends with greedy outputs
    bit-matching the pre-refactor engines' oracle (direct greedy decode),
    including across preemption/resume;
  * ``step()`` streams incremental ``RequestOutput``s with correct
    ``finish_reason``s;
  * ``kv_layout="auto"`` resolves through the plan layer and falls back
    to dense for models the paged subsystem cannot hold;
  * the deprecated ``ServingEngine`` / ``PagedServingEngine`` shims stay
    drop-in, and nothing outside ``src/repro/serving/`` constructs them
    (grep-enforced, pattern of ``tests/test_attention_plan.py``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.pool import OutOfPages
from repro.configs import registry
from repro.models import transformer
from repro.serving import LLMEngine, Request, RequestOutput, SamplingParams


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def direct_greedy(cfg, params, prompt, n_new, cache_len=256):
    lg, caches = transformer.prefill(
        params, cfg, jnp.asarray(prompt)[None], cache_len=cache_len
    )
    toks, lengths = [], jnp.array([len(prompt)], jnp.int32)
    nxt = int(jnp.argmax(lg[0]))
    for _ in range(n_new):
        toks.append(nxt)
        lengths = lengths + 1
        lg, caches = transformer.decode_step(
            params, cfg, jnp.asarray([nxt]), caches, lengths
        )
        nxt = int(jnp.argmax(lg[0]))
    return toks


def toks_of(out: RequestOutput):
    return [int(t) for t in out.tokens]


# --- dense backend ------------------------------------------------------------


def test_continuous_batching_matches_direct(llama):
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=3,
                    cache_len=256, prompt_buckets=(32, 64))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20, 33, 11, 40)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert len(results) == len(reqs)
    for r in results:
        assert r.finished and r.finish_reason == "length"
        assert toks_of(r) == direct_greedy(cfg, params, prompts[r.uid], 5), r.uid


def test_slot_reuse(llama):
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=1,
                    cache_len=128, prompt_buckets=(16,))
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(1, 400, size=(10,)),
                    max_new_tokens=3) for i in range(4)]
    results = eng.generate(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2, 3]


def test_stop_token_terminates_with_reason(llama):
    cfg, params = llama
    prompt = np.random.default_rng(2).integers(1, 400, size=(12,))
    ref_toks = direct_greedy(cfg, params, prompt, 8, cache_len=128)
    # A stop token that first appears at position i > 0 (greedy decode may
    # repeat tokens, so pick one with no earlier occurrence).
    i = next(k for k in range(1, 8) if ref_toks[k] not in ref_toks[:k])
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=1,
                    cache_len=128, prompt_buckets=(16,))
    res = eng.generate([Request(uid=0, prompt=prompt, max_new_tokens=8,
                                eos_id=int(ref_toks[i]))])
    # Stopped right after emitting the stop token (which is included).
    assert [int(t) for t in res[0].tokens] == ref_toks[: i + 1]
    assert res[0].finish_reason == "stop"
    # A stop token as the very FIRST generated token must terminate too
    # (the pre-facade engines only checked decode-sampled tokens).
    res0 = eng.generate([Request(uid=1, prompt=prompt, max_new_tokens=8,
                                 eos_id=int(ref_toks[0]))])
    assert [int(t) for t in res0[0].tokens] == [ref_toks[0]]
    assert res0[0].finish_reason == "stop"


def test_streaming_deltas_reassemble(llama):
    """step() emits disjoint new_tokens whose concatenation equals the
    final output, and the last delta carries the finish_reason."""
    cfg, params = llama
    rng = np.random.default_rng(20)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 14)]
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16,))
    for i, p in enumerate(prompts):
        eng.add_request(Request(uid=i, prompt=p, max_new_tokens=4))
    streams = {0: [], 1: []}
    finals = {}
    for _ in range(20):
        for out in eng.step():
            streams[out.uid].extend(int(t) for t in out.new_tokens)
            if out.finished:
                finals[out.uid] = out
        if not eng.backend.active.any() and not eng.scheduler.has_work():
            break
    assert sorted(finals) == [0, 1]
    for uid, out in finals.items():
        assert out.finish_reason == "length"
        assert streams[uid] == toks_of(out)  # deltas reassemble exactly
        assert streams[uid] == direct_greedy(cfg, params, prompts[uid], 4)


def test_mixed_sampling_batch_one_engine(llama):
    """The acceptance-criteria batch: different sampling params,
    priorities and lengths in one engine — greedy rows bit-match the
    oracle, stochastic rows are reproducible solo (per-request keys)."""
    cfg, params = llama
    rng = np.random.default_rng(21)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20, 13, 30)]
    mk = [
        SamplingParams(max_tokens=5),
        SamplingParams(temperature=0.9, top_k=25, max_tokens=4, seed=3),
        SamplingParams(temperature=1.1, top_p=0.8, max_tokens=6, seed=4),
        SamplingParams(max_tokens=3, seed=9),
    ]
    reqs = [Request(uid=i, prompt=p, sampling=s, priority=i % 2)
            for i, (p, s) in enumerate(zip(prompts, mk))]
    for layout, kw in (
        ("dense", dict(cache_len=256, prompt_buckets=(32, 64))),
        ("paged", dict(num_pages=96, page_size=16, max_pages_per_seq=8,
                       prompt_buckets=(16, 32, 64))),
    ):
        eng = LLMEngine(cfg, params, kv_layout=layout, max_batch=3, **kw)
        results = {r.uid: r for r in eng.generate([r.clone() for r in reqs])}
        assert sorted(results) == [0, 1, 2, 3]
        for uid in (0, 3):  # greedy rows == oracle regardless of batchmates
            want = direct_greedy(cfg, params, prompts[uid],
                                 mk[uid].max_tokens)
            assert toks_of(results[uid]) == want, (layout, uid)
        for uid in (1, 2):  # stochastic rows reproduce solo (same seed)
            solo = LLMEngine(cfg, params, kv_layout=layout, max_batch=1, **kw)
            (ref,) = solo.generate([reqs[uid].clone()])
            assert toks_of(results[uid]) == toks_of(ref), (layout, uid)


def test_kv_layout_auto_resolves_through_plan_layer(llama):
    cfg, params = llama
    eng = LLMEngine(cfg, params, max_batch=2, num_pages=32, page_size=16,
                    max_pages_per_seq=4, prompt_buckets=(16, 32))
    # The analytic NUMA decode model prefers the paged pool over streaming
    # full dense stripes for an attention-only model.
    assert eng.kv_layout == "paged"
    # The zero-knob constructor (the README example) must be valid: the
    # default per-sequence page cap clamps to what the pool can hold.
    eng_default = LLMEngine(cfg, params)
    assert eng_default.kv_layout == "paged"
    assert eng_default.backend.max_pages_per_seq <= \
        eng_default.backend.pool.num_pages - 1
    # Models the paged subsystem cannot hold fall back to dense.
    mcfg = registry.get_smoke_config("musicgen-medium")
    mparams = transformer.init_model(jax.random.PRNGKey(0), mcfg)
    meng = LLMEngine(mcfg, mparams, max_batch=2, cache_len=64,
                     prompt_buckets=(16,))
    assert meng.kv_layout == "dense"
    with pytest.raises(ValueError, match="single-codebook"):
        LLMEngine(mcfg, mparams, kv_layout="paged", max_batch=2)
    with pytest.raises(ValueError, match="kv_layout"):
        LLMEngine(cfg, params, kv_layout="sparse")


def test_multi_codebook_serving(llama):
    """MusicGen-style (S, K) prompts serve through the facade (dense
    fallback) with (K,) token outputs."""
    mcfg = registry.get_smoke_config("musicgen-medium")
    mparams = transformer.init_model(jax.random.PRNGKey(0), mcfg)
    rng = np.random.default_rng(22)
    eng = LLMEngine(mcfg, mparams, max_batch=2, cache_len=64,
                    prompt_buckets=(16,))
    res = eng.generate([
        Request(uid=0, prompt=rng.integers(1, 200, size=(8, 4)),
                max_new_tokens=3),
        Request(uid=1, prompt=rng.integers(1, 200, size=(6, 4)),
                sampling=SamplingParams(temperature=0.7, max_tokens=3,
                                        seed=1)),
    ])
    assert sorted(r.uid for r in res) == [0, 1]
    for r in res:
        assert all(np.asarray(t).shape == (4,) for t in r.tokens), r.uid


# --- paged backend ------------------------------------------------------------


def test_paged_matches_direct_with_prefix_sharing(llama):
    """Requests sharing a system prompt: pages are reused (hit rate > 0),
    only tails are prefilled, and every output still equals the dense
    direct greedy decode."""
    cfg, params = llama
    rng = np.random.default_rng(3)
    system = rng.integers(1, 400, size=(32,))
    prompts = [np.concatenate([system, rng.integers(1, 400, size=(L,))])
               for L in (5, 18, 2)]
    prompts.append(rng.integers(1, 400, size=(9,)))  # unshared
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=16, max_batch=3, max_pages_per_seq=8,
                    prompt_buckets=(16, 32, 64))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert len(results) == len(reqs)
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 4)
        assert toks_of(r) == want, r.uid
    stats = eng.stats()
    assert stats.prefix_hit_rate > 0
    assert eng.backend.stats["pages_reused"] >= 2 * 2  # 2-page prefix, 2 reusers
    # all sequence pages released; only prefix-cache pages remain in use
    assert eng.backend.pool.used_pages == len(eng.backend.prefix)
    # Admission pricing (quote) is a pure peek: no LRU refresh, no
    # phantom hit-rate queries, however often the scheduler re-prices.
    before = eng.backend.prefix.stats()
    for _ in range(3):
        eng.backend.quote(Request(uid=99, prompt=prompts[0],
                                  max_new_tokens=2))
    assert eng.backend.prefix.stats() == before


def test_close_proves_zero_leak_teardown(llama):
    """`close()` mid-flight releases live rows, drains the prefix cache,
    and `PagePool.check_leaks()` certifies every page returned — the
    teardown path is the leak detector, not a best-effort cleanup."""
    cfg, params = llama
    rng = np.random.default_rng(11)
    system = rng.integers(1, 400, size=(32,))
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=16, max_batch=3, max_pages_per_seq=8,
                    prompt_buckets=(16, 64))
    for i in range(3):
        tail = rng.integers(1, 400, size=(6 + i,))
        eng.add_request(Request(uid=i, prompt=np.concatenate([system, tail]),
                                max_new_tokens=32))
    for _ in range(4):   # partway through decode: rows + prefix pages live
        eng.step()
    assert eng.backend.pool.used_pages > 0
    assert eng.backend.check_leaks() == {}      # live refs fully explained
    eng.close()
    assert eng.backend.pool.used_pages == 0     # rows AND prefix drained
    assert eng.backend.pool.check_leaks() == {}


def test_paged_release_of_empty_row_raises(llama):
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=32,
                    page_size=16, max_batch=2, max_pages_per_seq=8,
                    prompt_buckets=(16,))
    from repro.cache.pool import SequenceReleasedError
    with pytest.raises(SequenceReleasedError):
        eng.backend.release(0)   # row holds no sequence


def test_paged_preemption_under_page_pressure(llama):
    """A pool too small for all concurrent sequences preempts the lowest
    priority one, requeues it, and still completes everything exactly —
    the bit-match-across-preemption acceptance check."""
    cfg, params = llama
    rng = np.random.default_rng(4)
    prompts = [rng.integers(1, 400, size=(20,)) for _ in range(3)]
    # 9 usable pages; each sequence grows to 4 pages (20 + 30 tokens).
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=10,
                    page_size=16, max_batch=3, max_pages_per_seq=4,
                    prompt_buckets=(16, 32))
    reqs = [Request(uid=i, prompt=p, max_new_tokens=30, priority=i)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2]
    stats = eng.stats()
    assert stats.preemptions >= 1
    # The victim had decoded tokens before eviction and they were replayed
    # (restart-from-scratch would leave this at 0).
    assert stats.resumed_tokens > 0
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 30)
        assert toks_of(r) == want, r.uid


def test_paged_prefix_reuse_survives_eviction_pressure(llama):
    """Admission that must evict prefix-cache pages to fit may never
    recycle the very pages it is about to reuse: here the request matches
    page 1 of a cached 3-page prefix while eviction frees pages 2-3, and
    the decoded output must still be exact."""
    cfg, params = llama
    rng = np.random.default_rng(6)
    prompt_a = rng.integers(1, 400, size=(48,))
    prompt_b = np.concatenate([prompt_a[:16], rng.integers(1, 400, size=(48,))])
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=6,
                    page_size=16, max_batch=1, max_pages_per_seq=5,
                    prompt_buckets=(16, 32, 48, 64))
    reqs = [Request(uid=0, prompt=prompt_a, max_new_tokens=16),
            Request(uid=1, prompt=prompt_b, max_new_tokens=16)]
    results = eng.generate(reqs)
    assert sorted(r.uid for r in results) == [0, 1]
    assert eng.backend.stats["pages_reused"] >= 1
    assert eng.backend.stats["prefix_evictions"] >= 2
    for r in results:
        want = direct_greedy(cfg, params, reqs[r.uid].prompt, 16)
        assert toks_of(r) == want, r.uid


def test_paged_prefill_compile_cache_is_log_bounded(llama):
    """Diverse live prefix lengths must NOT mint one tail-prefill
    compilation each: the jit key buckets prefix pages to powers of two,
    so the cache stays O(log smax) while outputs remain exact."""
    cfg, params = llama
    rng = np.random.default_rng(7)
    base = rng.integers(1, 400, size=(96,))  # 6 full pages once published
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=16, max_batch=2, max_pages_per_seq=10,
                    prompt_buckets=(16, 32, 64, 96))
    prompts = [base]
    for i in range(1, 7):
        prompts.append(
            np.concatenate([base[: 16 * i], rng.integers(1, 400, size=(8,))])
        )
    reqs = [Request(uid=i, prompt=p, max_new_tokens=3)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert len(results) == len(reqs)
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 3)
        assert toks_of(r) == want, r.uid
    backend = eng.backend
    assert backend.stats["extend_prefills"] >= 5
    prefix_keys = {k[1] for k in backend._prefill_p if k[1] > 0}
    assert all(p & (p - 1) == 0 for p in prefix_keys), prefix_keys
    import math

    assert len(prefix_keys) <= \
        math.ceil(math.log2(backend.max_pages_per_seq)) + 1, prefix_keys


def test_paged_resume_truncates_oversized_replay(llama):
    """A resumed request whose prompt+generated replay tail exceeds every
    prefill bucket must shed replayed tokens until the tail fits (they are
    regenerated by decode) instead of raising mid-run."""
    cfg, params = llama
    rng = np.random.default_rng(9)
    prompt = rng.integers(1, 400, size=(30,))
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=32,
                    page_size=16, max_batch=2, max_pages_per_seq=5,
                    prompt_buckets=(16, 32))
    backend = eng.backend
    req = Request(uid=0, prompt=prompt, max_new_tokens=45)
    # Seed the prefix cache with the prompt's full page, as a prior
    # admission would have.
    rec = backend.try_admit(req)
    assert rec is not None
    eng._flush([rec])
    assert backend._preempt_one(protect=-1)
    assert eng.scheduler.num_waiting == 1  # requeued for resume
    # Resume with a 40-token replay: tail 30+40-16 = 54 exceeds bucket 32,
    # so the engine must keep only the 18 replayed tokens that fit
    # (30+18-16 = 32) and re-decode the rest.
    fake = [int(t) for t in rng.integers(1, 400, size=(40,))]
    rec = backend.try_admit(req, resume_tokens=fake)
    assert rec is not None
    row = rec["row"]
    assert backend.out[row] == fake[:18]
    assert backend.lengths[row] == 30 + 18
    assert backend.stats["resumed_tokens"] == 18


def test_paged_batched_prefills_bit_exact(llama):
    """Batched prefill flushing: ready requests sharing a jit bucket ride
    one tail-prefill launch — fewer launches, identical tokens vs the
    one-launch-per-request oracle, and still equal to direct greedy."""
    cfg, params = llama
    rng = np.random.default_rng(10)
    system = rng.integers(1, 400, size=(32,))
    prompts = []
    for i in range(6):
        tail = rng.integers(1, 400, size=(int(rng.integers(2, 14)),))
        prompts.append(np.concatenate([system, tail]) if i % 3 else tail)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4)
            for i, p in enumerate(prompts)]
    kw = dict(kv_layout="paged", num_pages=96, page_size=16, max_batch=4,
              max_pages_per_seq=8, prompt_buckets=(16, 32, 64))

    batched = LLMEngine(cfg, params, batch_prefills=True, **kw)
    res_b = batched.generate([r.clone() for r in reqs])
    serial = LLMEngine(cfg, params, batch_prefills=False, **kw)
    res_s = serial.generate([r.clone() for r in reqs])

    toks_b = {r.uid: toks_of(r) for r in res_b}
    toks_s = {r.uid: toks_of(r) for r in res_s}
    assert toks_b == toks_s  # bit-exact across the two flush modes
    for uid, toks in toks_b.items():
        assert toks == direct_greedy(cfg, params, prompts[uid], 4), uid
    assert batched.backend.stats["batched_prefills"] > 0
    assert batched.backend.stats["prefill_launches"] < \
        serial.backend.stats["prefill_launches"]
    assert serial.backend.stats["batched_prefills"] == 0


def test_paged_batched_extend_rows_share_one_launch(llama):
    """Several requests matching the same cached prefix (same tail bucket
    and page bucket) must extend in ONE launch with per-row prefix
    lengths."""
    cfg, params = llama
    rng = np.random.default_rng(11)
    base = rng.integers(1, 400, size=(32,))
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=96,
                    page_size=16, max_batch=4, max_pages_per_seq=8,
                    prompt_buckets=(16, 32, 64))
    # Publish the prefix first (its own flush), then three same-bucket
    # extenders arrive together.
    eng.generate([Request(uid=0, prompt=base, max_new_tokens=2)])
    backend = eng.backend
    launches_before = backend.stats["prefill_launches"]
    tails = [rng.integers(1, 400, size=(6 + i,)) for i in range(3)]
    reqs = [Request(uid=10 + i, prompt=np.concatenate([base, t]),
                    max_new_tokens=3) for i, t in enumerate(tails)]
    results = eng.generate(reqs)
    assert len(results) == 3
    assert backend.stats["extend_prefills"] >= 3
    assert backend.stats["prefill_launches"] == launches_before + 1
    assert backend.stats["batched_prefills"] >= 1
    # A (bucket, pages, rows=3) jit key exists — the kernel consumed (B,)
    # prefix/tail lengths in one call.
    assert any(k[2] == 3 and k[1] > 0 for k in backend._prefill_p), \
        sorted(backend._prefill_p)
    for r in results:
        want = direct_greedy(
            cfg, params, np.concatenate([base, tails[r.uid - 10]]), 3
        )
        assert toks_of(r) == want, r.uid


def test_paged_rejects_unservable_request_at_add(llama):
    """prompt + max_tokens that cannot fit max_pages_per_seq must fail at
    add_request, not crash mid-decode."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=16,
                    page_size=16, max_batch=2, max_pages_per_seq=4,
                    prompt_buckets=(16, 32))
    bad = Request(uid=0, prompt=np.arange(1, 17), max_new_tokens=60)
    with pytest.raises(ValueError, match="outgrow"):
        eng.add_request(bad)
    assert eng.backend.pool.used_pages == 0  # nothing leaked
    assert eng.scheduler.num_waiting == 0    # nothing queued
    # Without prefix sharing, a prompt no bucket holds can never be
    # served either: rejected at add_request, not mid-run.
    eng2 = LLMEngine(cfg, params, kv_layout="paged", num_pages=16,
                     page_size=16, max_batch=2, max_pages_per_seq=8,
                     prompt_buckets=(16, 32), prefix_sharing=False)
    with pytest.raises(ValueError, match="exceeds buckets"):
        eng2.add_request(Request(uid=0, prompt=np.arange(1, 49),
                                 max_new_tokens=3))
    # Passing both a Request and loose keywords (incl. priority) errors.
    with pytest.raises(ValueError, match="either"):
        eng2.add_request(Request(uid=1, prompt=np.arange(1, 9),
                                 max_new_tokens=2), priority=5)


def test_poison_request_flushes_good_rows_and_is_ejected(llama):
    """A request whose tail overflows every prefill bucket only surfaces
    at admission time. It must (a) not strand same-round good rows
    unprefilled — the flush runs before the error propagates — and (b) be
    ejected from the queue so later steps are not wedged."""
    cfg, params = llama
    rng = np.random.default_rng(12)
    good = Request(uid=0, prompt=rng.integers(1, 400, size=(10,)),
                   max_new_tokens=3)
    # Fits pages (48 + 3 tokens < 5 pages) but no 48-token tail bucket.
    bad = Request(uid=1, prompt=rng.integers(1, 400, size=(48,)),
                  max_new_tokens=3)
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=16, max_batch=2, max_pages_per_seq=5,
                    prompt_buckets=(16, 32))
    with pytest.raises(ValueError, match="exceeds buckets"):
        eng.generate([good, bad])
    row = int(np.flatnonzero(eng.backend.active)[0])
    assert row in eng._pending  # good row's prefill was flushed + sampled
    assert eng.scheduler.num_waiting == 0  # poison request ejected
    res = eng.generate([])  # drain the good request to completion
    assert toks_of(res[0]) == direct_greedy(cfg, params, good.prompt, 3)


def test_paged_pool_must_hold_one_max_sequence(llama):
    """A pool smaller than one max-size sequence would hit OutOfPages
    mid-decode with nothing to preempt; reject at construction."""
    cfg, params = llama
    with pytest.raises(ValueError, match="cannot hold"):
        LLMEngine(cfg, params, kv_layout="paged", num_pages=4, page_size=16,
                  max_batch=1, max_pages_per_seq=4, prompt_buckets=(16,))


def test_paged_admission_is_page_governed(llama):
    """With rows to spare but pages for only one sequence at a time, the
    scheduler serializes admission instead of overcommitting."""
    cfg, params = llama
    rng = np.random.default_rng(5)
    prompts = [rng.integers(1, 400, size=(30,)) for _ in range(2)]
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=6,
                    page_size=16, max_batch=4, max_pages_per_seq=3,
                    prompt_buckets=(16, 32), prefix_sharing=False,
                    reserve_pages=1)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=14)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert sorted(r.uid for r in results) == [0, 1]
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 14)
        assert toks_of(r) == want, r.uid


def test_generate_raises_when_nothing_can_fit(llama):
    """A request that passes per-request validation but can never be
    admitted (pages + decode headroom exceed the whole pool) must raise
    OutOfPages from generate — carrying the outputs that already finished
    this call, not discarding them."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=10,
                    page_size=16, max_batch=2, max_pages_per_seq=8,
                    prompt_buckets=(16, 32, 64), reserve_pages=6)
    good = Request(uid=0, prompt=np.arange(1, 11) % 400, max_new_tokens=3)
    # 4 prompt pages + 6 reserve > 9 usable: the scheduler's page budget
    # can never clear it.
    big = Request(uid=1, prompt=np.arange(1, 65) % 400, max_new_tokens=3)
    with pytest.raises(OutOfPages) as ei:
        eng.generate([good, big])
    (done,) = ei.value.completed  # the finished request survives the error
    assert done.uid == 0 and done.finish_reason == "length"
    assert toks_of(done) == direct_greedy(cfg, params, good.prompt, 3)


# --- deprecated shims ---------------------------------------------------------


def test_deprecated_shims_are_drop_in(llama):
    """Old constructor surface + run() still work (with a
    DeprecationWarning) and produce exactly the facade's outputs."""
    from repro.serving import PagedServingEngine, Result, ServingEngine

    cfg, params = llama
    rng = np.random.default_rng(13)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=4, temperature=0.0)
            for i, p in enumerate(prompts)]

    with pytest.warns(DeprecationWarning):
        dense = ServingEngine(cfg, params, num_slots=2, cache_len=128,
                              prompt_buckets=(32,))
    res = dense.run([r.clone() for r in reqs])
    assert all(isinstance(r, Result) for r in res)
    for r in res:
        assert [int(t) for t in r.tokens] == \
            direct_greedy(cfg, params, prompts[r.uid], 4), r.uid

    with pytest.warns(DeprecationWarning):
        paged = PagedServingEngine(cfg, params, num_pages=32, page_size=16,
                                   max_batch=2, max_pages_per_seq=4,
                                   prompt_buckets=(16, 32))
    res_p = paged.run([r.clone() for r in reqs])
    for r in res_p:
        assert [int(t) for t in r.tokens] == \
            direct_greedy(cfg, params, prompts[r.uid], 4), r.uid
    # Thin delegation: legacy introspection still reachable.
    assert paged.pool.used_pages == len(paged.prefix)
    assert paged.prefix_stats()["prefill_launches"] >= 2
    # Hand-driven submit()+step() loops still populate .results.
    manual = Request(uid=9, prompt=prompts[0], max_new_tokens=2)
    assert paged.submit(manual)
    for _ in range(5):
        paged.step()
    assert any(r.uid == 9 for r in paged.results)
    with pytest.raises(KeyError), pytest.warns(DeprecationWarning):
        ServingEngine(cfg, params, num_slots=1, cache_len=64,
                      prompt_buckets=(16,), mapping="bogus")


def test_no_legacy_engine_construction_outside_serving():
    """The deprecated engine classes may only be constructed inside
    ``src/repro/serving/`` — and this test file, which tests the shims
    themselves. Everything else goes through ``LLMEngine``. Single
    implementation: the linter's ``no-legacy-engine-construction`` rule."""
    from repro.analysis import run_rules

    assert run_rules(rules=["no-legacy-engine-construction"]) == []


# --- telemetry (PR 7) ---------------------------------------------------------


def test_disabled_telemetry_shares_null_instruments(llama):
    """The telemetry-off contract: an un-instrumented engine threads the
    module-level no-op singletons — no span or metric objects exist per
    step, and nothing is recorded."""
    from repro.obs import NULL_TELEMETRY
    from repro.obs.metrics import NULL_COUNTER, NULL_HISTOGRAM
    from repro.obs.tracing import NULL_SPAN

    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16,))
    assert eng.telemetry is NULL_TELEMETRY
    assert eng._m_steps is NULL_COUNTER
    assert eng._h_decode is NULL_HISTOGRAM
    assert eng._tr.span("step") is eng._tr.span("decode") is NULL_SPAN
    rng = np.random.default_rng(0)
    eng.generate([Request(uid=0, prompt=rng.integers(1, 400, size=(8,)),
                          max_new_tokens=3)])
    assert NULL_COUNTER.value == 0.0
    assert NULL_HISTOGRAM.count == 0
    assert eng.telemetry.tracer.spans == []
    assert eng.telemetry.drift.num_samples == 0


def test_telemetry_records_lifecycle_spans_and_drift(llama):
    from repro.obs import Telemetry

    cfg, params = llama
    tel = Telemetry.create()
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16,), telemetry=tel)
    rng = np.random.default_rng(2)
    reqs = [Request(uid=i, prompt=rng.integers(1, 400, size=(8,)),
                    max_new_tokens=4) for i in range(2)]
    results = eng.generate(reqs)
    assert len(results) == 2

    snap = tel.metrics.snapshot()
    assert snap["serving_requests_total"]["value"] == 2.0
    assert snap["serving_finished_total"]["value"] == 2.0
    total = sum(len(r.tokens) for r in results)
    assert snap["serving_tokens_total"]["value"] == float(total)
    assert snap["serving_steps_total"]["value"] > 0
    assert snap["serving_decode_step_seconds"]["count"] > 0

    span_names = {s.name for s in tel.tracer.spans}
    assert {"step", "schedule", "flush", "decode"} <= span_names
    for uid in (0, 1):
        events = [e for e, _, _ in tel.tracer.request_lifecycle(uid)]
        assert events[0] == "arrival" and events[-1] == "finish"
        assert "admitted" in events and "first_token" in events
        lat = tel.tracer.request_latencies()[uid]
        assert lat["ttft"] is not None and lat["ttft"] >= 0
        assert lat["e2e"] is not None and lat["e2e"] >= lat["ttft"]
        # max_new_tokens=4 -> first token + 3 inter-token intervals
        assert len(lat["itl"]) == 3

    assert tel.drift.num_samples > 0
    report = tel.drift.report(eng.drift_model_fn())
    assert report.rows and report.worst_ratio() is not None


def test_telemetry_counts_preemptions(llama):
    """Preempt/resume lifecycles reach the tracer and the counter (the
    page-pressure trace from test_paged_preemption_under_page_pressure,
    instrumented)."""
    from repro.obs import Telemetry

    cfg, params = llama
    tel = Telemetry.create()
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=17,
                    page_size=16, max_batch=2, max_pages_per_seq=16,
                    prompt_buckets=(16, 32), prefix_sharing=False,
                    telemetry=tel)
    rng = np.random.default_rng(3)
    reqs = [
        Request(uid=0, prompt=rng.integers(1, 400, size=(16,)),
                max_new_tokens=40, priority=1),
        Request(uid=1, prompt=rng.integers(1, 400, size=(16,)),
                max_new_tokens=8),
    ]
    results = eng.generate(reqs)
    assert len(results) == 2
    stats = eng.stats()
    if stats.preemptions:  # page pressure fired
        snap = tel.metrics.snapshot()
        assert snap["serving_preemptions_total"]["value"] == \
            float(stats.preemptions)
        preempted = [
            uid for uid in (0, 1)
            if any(e == "preempt"
                   for e, _, _ in tel.tracer.request_lifecycle(uid))
        ]
        assert preempted, "preemption happened but no lifecycle event"
        for uid in preempted:
            events = [e for e, _, _ in tel.tracer.request_lifecycle(uid)]
            assert "resume" in events, events
            assert tel.tracer.request_latencies()[uid]["preemptions"] >= 1


def test_stats_split_measured_vs_modeled(llama):
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16,))
    rng = np.random.default_rng(4)
    eng.generate([Request(uid=0, prompt=rng.integers(1, 400, size=(8,)),
                          max_new_tokens=4)])
    stats = eng.stats()
    assert stats.tokens_per_s > 0
    assert stats.measured_tok_s > 0
    assert stats.decode_elapsed_s > 0
    # Decode-phase wall time is a subset of total engine wall time, so
    # the decode-normalized rate can only be faster.
    assert stats.decode_elapsed_s <= stats.elapsed_s
    assert stats.measured_tok_s >= stats.tokens_per_s
    assert stats.modeled_tok_s > 0
    assert "measured decode" in stats.summary()


def test_modeled_tok_s_near_zero_model_reports_zero(llama):
    """The PR-7 satellite fix: a denormal decode_time_model result used
    to print as 10^15 modeled tok/s; safe_rate reports 0.0 (unknown)."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=1,
                    cache_len=128, prompt_buckets=(16,))
    eng.backend.decode_time_model = lambda batch, mean_len=None: 1e-12
    stats = eng.stats()
    assert stats.modeled_tok_s == 0.0
    # And zero elapsed/decode time reports 0.0 rates, not a blow-up.
    assert stats.tokens_per_s == 0.0
    assert stats.measured_tok_s == 0.0


def test_dense_prefix_hit_rate_is_none_not_zero(llama):
    """Dense engines have no prefix cache: stats must say "n/a" (None),
    never a fake 0.0 that reads as a cold cache (PR 7 satellite)."""
    from repro.obs import Telemetry

    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=1,
                    cache_len=128, prompt_buckets=(16,))
    ps = eng.backend.prefix_stats()
    assert ps["prefix_hit_rate"] is None
    assert ps["prefix_lookup_queries"] == 0.0
    assert eng.stats().prefix_hit_rate is None
    assert "prefix hit n/a" in eng.stats().summary()

    # Paged engines report a real float (0.0 means "never shared").
    tel = Telemetry.create()
    peng = LLMEngine(cfg, params, kv_layout="paged", num_pages=96,
                     page_size=16, max_batch=2, max_pages_per_seq=8,
                     prompt_buckets=(16, 32), telemetry=tel)
    pps = peng.backend.prefix_stats()
    assert pps["prefix_hit_rate"] == 0.0
    assert peng.stats().prefix_hit_rate == 0.0
    assert {"prefix_lookup_hits", "prefix_lookup_queries",
            "prefix_evictions"} <= set(pps)
