"""Serving-engine tests: continuous batching == direct greedy decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def direct_greedy(cfg, params, prompt, n_new, cache_len=256):
    lg, caches = transformer.prefill(
        params, cfg, jnp.asarray(prompt)[None], cache_len=cache_len
    )
    toks, lengths = [], jnp.array([len(prompt)], jnp.int32)
    nxt = int(jnp.argmax(lg[0]))
    for _ in range(n_new):
        toks.append(nxt)
        lengths = lengths + 1
        lg, caches = transformer.decode_step(
            params, cfg, jnp.asarray([nxt]), caches, lengths
        )
        nxt = int(jnp.argmax(lg[0]))
    return toks


def test_continuous_batching_matches_direct(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, num_slots=3, cache_len=256,
                        prompt_buckets=(32, 64))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20, 33, 11, 40)]
    reqs = [Request(uid=i, prompt=p, max_new_tokens=5) for i, p in enumerate(prompts)]
    results = eng.run(reqs)
    assert len(results) == len(reqs)
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 5)
        assert [int(t) for t in r.tokens] == want, r.uid


def test_slot_reuse(llama):
    cfg, params = llama
    eng = ServingEngine(cfg, params, num_slots=1, cache_len=128,
                        prompt_buckets=(16,))
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(1, 400, size=(10,)),
                    max_new_tokens=3) for i in range(4)]
    results = eng.run(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2, 3]


def test_eos_terminates(llama):
    cfg, params = llama
    prompt = np.random.default_rng(2).integers(1, 400, size=(12,))
    ref_toks = direct_greedy(cfg, params, prompt, 8, cache_len=128)
    eos = ref_toks[2]
    eng = ServingEngine(cfg, params, num_slots=1, cache_len=128,
                        prompt_buckets=(16,))
    res = eng.run([Request(uid=0, prompt=prompt, max_new_tokens=8, eos_id=int(eos))])
    assert len(res[0].tokens) == 3  # stopped right after emitting EOS
