"""The attention-plan layer: resolution, caching, and call-site hygiene.

Covers the PR-3 acceptance criteria directly:
  * ``plan_attention`` is the single resolver for every phase; the legacy
    entry points (``ops.resolve_mapping`` / ``ops.resolve_kv_layout``) are
    thin wrappers over it,
  * the plan LRU cache keys on **backend + interpret flag** as well as
    shape (the PR-1 resolver silently shared entries across backends in
    tests that flip ``JAX_PLATFORMS``),
  * grep enforcement: no dispatch site threads ``mapping_name`` /
    ``q_offset`` out-of-band or hand-rolls a ``MappingConfig`` past the
    plan layer.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.kernels import ops
from repro.kernels import plan as plan_lib
from repro.kernels.flash_attention import (
    HEAD_FIRST,
    PAPER_MAPPINGS,
    MappingConfig,
)


SHAPE = (2, 8, 2, 2048, 2048, 64)


# --- resolution ---------------------------------------------------------------


def test_plan_phases_resolve_distinct_impls():
    prefill = plan_lib.plan_attention(SHAPE, backend="cpu")
    decode = plan_lib.plan_attention(
        (2, 8, 2, 1, 2048, 64), phase=plan_lib.DECODE, backend="cpu"
    )
    extend = plan_lib.plan_attention(
        (1, 8, 2, 32, 96, 64), phase=plan_lib.EXTEND,
        kv_layout=plan_lib.PAGED, page_size=16, prefix_pages=4, backend="cpu",
    )
    assert prefill.impl == "xla_flash"
    assert decode.impl == "xla" and decode.chunk is not None
    # The headline: paged extend is the Pallas kernel on EVERY backend (no
    # gather fallback); CPU hosts run it in interpret mode.
    assert extend.impl == "pallas" and extend.interpret
    assert extend.prefix_capacity == 64
    # Dense extend stays the legacy XLA q_offset oracle (pallas cannot
    # carry the offset).
    dense_ext = plan_lib.plan_attention(
        (1, 8, 2, 32, 96, 64), phase=plan_lib.EXTEND, backend="cpu",
    )
    assert dense_ext.impl == "xla_flash"
    # An explicitly pinned compiled CPU impl never lands on the
    # interpreter: paged extend coerces it to the compiled gather oracle.
    pinned = plan_lib.plan_attention(
        (1, 8, 2, 32, 96, 64), phase=plan_lib.EXTEND,
        kv_layout=plan_lib.PAGED, page_size=16, prefix_pages=4,
        backend="cpu", impl="xla_flash",
    )
    assert pinned.impl == "xla"


def test_plan_on_tpu_backend_targets_mosaic():
    p = plan_lib.plan_attention(SHAPE, backend="tpu")
    assert p.impl == "pallas" and not p.interpret


def test_plan_cache_keys_on_backend_and_interpret():
    """Same shape, different backend / interpret flag -> distinct entries;
    identical key -> the same LRU object."""
    cpu = plan_lib.plan_attention(SHAPE, backend="cpu")
    tpu = plan_lib.plan_attention(SHAPE, backend="tpu")
    assert cpu is not tpu
    assert (cpu.backend, cpu.impl) != (tpu.backend, tpu.impl)
    forced = plan_lib.plan_attention(SHAPE, backend="tpu", interpret=True)
    assert forced is not tpu and forced.interpret
    again = plan_lib.plan_attention(SHAPE, backend="cpu")
    assert again is cpu
    hash(cpu)  # usable as a jit-closure constant / custom_vjp nondiff arg


def test_plan_decode_chunk_prefers_capacity_divisor():
    # 2048 divides by the resolver's block_n (128) -> chunk 128, no pad.
    even = plan_lib.plan_attention(
        (2, 8, 2, 1, 2048, 64), phase=plan_lib.DECODE, backend="cpu"
    )
    assert even.chunk and 2048 % even.chunk == 0
    # An odd capacity picks the largest sublane-multiple divisor.
    odd = plan_lib.plan_attention(
        (2, 8, 2, 1, 2000, 64), phase=plan_lib.DECODE, backend="cpu"
    )
    assert odd.chunk and 2000 % odd.chunk == 0 and odd.chunk % 8 == 0


def test_plan_pinned_mapping_and_bad_names():
    p = plan_lib.plan_attention(
        SHAPE, backend="cpu", mapping_name="naive_block_first"
    )
    assert p.mapping is PAPER_MAPPINGS["naive_block_first"]
    with pytest.raises(KeyError):
        plan_lib.plan_attention(SHAPE, backend="cpu", mapping_name="nope")
    with pytest.raises(ValueError):
        plan_lib.plan_attention(SHAPE, phase="warmup")
    with pytest.raises(ValueError):
        plan_lib.plan_attention(SHAPE, kv_layout=plan_lib.PAGED)  # no page_size


def test_plan_for_config_reads_policy():
    cfg = registry.get_smoke_config("llama3-8b")
    shape = (1, cfg.n_heads, cfg.n_kv_heads, 64, 64, cfg.head_dim)
    p = plan_lib.plan_for_config(cfg, shape)
    assert p.mapping.order == HEAD_FIRST
    pinned = plan_lib.with_mapping(cfg, "swizzled_block_first")
    p2 = plan_lib.plan_for_config(pinned, shape)
    assert p2.mapping is PAPER_MAPPINGS["swizzled_block_first"]
    with pytest.raises(KeyError):
        plan_lib.with_mapping(cfg, "not_a_mapping")


# --- thin wrappers ------------------------------------------------------------


def test_resolve_mapping_is_a_thin_wrapper():
    mc = ops.resolve_mapping(SHAPE)
    assert mc is plan_lib.plan_attention(SHAPE).mapping
    dec = ops.resolve_mapping((2, 8, 2, 1, 2048, 64), decode=True)
    assert dec is plan_lib.plan_attention(
        (2, 8, 2, 1, 2048, 64), phase=plan_lib.DECODE
    ).mapping


def test_resolve_kv_layout_is_a_thin_wrapper():
    shape = (6, 32, 8, 512, 128)
    assert ops.resolve_kv_layout(shape, capacity=2048, page_size=16) == \
        plan_lib.resolve_kv_layout(shape, capacity=2048, page_size=16)


def test_flash_attention_executes_a_plan():
    """An explicitly resolved plan drives ops.flash_attention and matches
    the oracle (the pallas route, interpret mode)."""
    from repro.kernels import ref

    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
    plan = plan_lib.plan_attention(
        (1, 4, 2, 256, 256, 64), impl="pallas", dtype_bytes=4
    )
    o = ops.flash_attention(q, k, v, causal=True, plan=plan)
    o_ref = ref.attention(q, k, v, causal=True)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_explicit_mapping_and_paged_extend_skip_scoring():
    """A caller-decided MappingConfig (plan_for_mapping) and a paged
    extend plan (whose kernel takes no mapping) must not pay the
    12-candidate scoring sweep."""
    before = plan_lib._score_mapping.cache_info().misses
    p = plan_lib.plan_for_mapping(
        MappingConfig(block_m=256), impl="pallas", backend="cpu"
    )
    assert p.impl == "pallas" and p.interpret
    assert p.mapping.block_m == 256
    ext = plan_lib.plan_attention(
        (1, 8, 2, 32, 32 * 16 + 32, 64), phase=plan_lib.EXTEND,
        kv_layout=plan_lib.PAGED, page_size=16, prefix_pages=32,
        backend="cpu",
    )
    assert ext.impl == "pallas"
    assert plan_lib._score_mapping.cache_info().misses == before


def test_prefill_rejects_dense_prefix_caches():
    """A dense (non-paged) prefix cache in prefill mode must raise, not
    silently drop the prefix (the dense prefix_kv route is gone)."""
    import numpy as np

    from repro.models import transformer

    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    dense = transformer.init_caches(params, cfg, batch=1, cache_len=32)
    tokens = jnp.asarray(np.arange(1, 17)[None])
    with pytest.raises(ValueError, match="paged"):
        transformer.prefill(
            params, cfg, tokens, cache_len=16, prefix_caches=dense,
            page_table=jnp.zeros((1, 2), jnp.int32),
            prefix_len=jnp.asarray([16], jnp.int32),
        )


def test_perf_model_scores_plans():
    """perf_model.estimate_attention_plan dispatches on the plan's
    phase/layout, and the paged extend kernel models cheaper than the
    gather route it replaced (prefix bytes read once, not thrice)."""
    from repro.core import numa, perf_model

    shape_e = (1, 32, 8, 64, 512 + 64, 128)
    pe = plan_lib.plan_attention(
        shape_e, phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
        page_size=16, prefix_pages=32, backend="gpu",
    )
    paged = perf_model.estimate_attention_plan(pe, shape_e, topo=numa.MI300X)
    gather = perf_model.estimate_extend_prefill(
        batch=1, num_q_heads=32, num_kv_heads=8, prefix_len=512, tail_len=64,
        page_size=16, head_dim=128, dtype_bytes=2, topo=numa.MI300X,
        gather=True,
    )
    assert paged.layout == "extend:paged" and gather.layout == "extend:gather"
    assert paged.hbm_bytes < gather.hbm_bytes
    assert paged.time <= gather.time
    # Reuse ranks the kernel above the gather route (fraction of logical
    # per-q-head prefix reads served without a fetch).
    assert paged.reuse_rate > gather.reuse_rate

    shape_d = (8, 32, 8, 1, 2048, 128)
    pd = plan_lib.plan_attention(shape_d, phase=plan_lib.DECODE, backend="gpu")
    assert perf_model.estimate_attention_plan(
        pd, shape_d, topo=numa.MI300X
    ).layout == "dense"
    pdp = plan_lib.plan_attention(
        shape_d, phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED,
        page_size=16, backend="gpu",
    )
    assert perf_model.estimate_attention_plan(
        pdp, shape_d, topo=numa.MI300X
    ).layout.startswith("paged:")

    shape_p = (8, 32, 8, 4096, 4096, 128)
    pp = plan_lib.plan_attention(shape_p, backend="gpu")
    assert perf_model.estimate_attention_plan(
        pp, shape_p, topo=numa.MI300X
    ).time > 0


def test_extend_route_is_scored_per_shape():
    """PR-4 satellite: the paged-extend impl is chosen by
    perf_model.estimate_extend_prefill, and each route wins somewhere.

    Low occupancy (one MQA request: B x Hkv = 1 of MI300X's 8 domains,
    long tail) -> the gather route's dense flash regains the idle domains
    and beats the kernel despite 3x prefix traffic. High occupancy
    (batched GQA, long prefix, short tail) -> the kernel's once-per-page
    reads win. A pinned impl skips the scoring entirely."""
    from repro.core import numa, perf_model

    gather_shape = (1, 8, 1, 512, 512 + 16, 64)
    gp = plan_lib.plan_attention(
        gather_shape, phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
        page_size=16, prefix_pages=1, backend="gpu",
    )
    assert gp.impl == "xla"
    paged_shape = (8, 32, 8, 64, 2048 + 64, 128)
    pp = plan_lib.plan_attention(
        paged_shape, phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
        page_size=16, prefix_pages=128, backend="gpu",
    )
    assert pp.impl == "pallas"
    # The choices agree with the estimates they claim to come from.
    for shape, plan in ((gather_shape, gp), (paged_shape, pp)):
        b, hq, hkv, sq, skv, hd = shape
        kw = dict(batch=b, num_q_heads=hq, num_kv_heads=hkv,
                  prefix_len=skv - sq, tail_len=sq, page_size=16,
                  head_dim=hd, dtype_bytes=2, topo=numa.MI300X)
        paged_t = perf_model.estimate_extend_prefill(gather=False, **kw).time
        gather_t = perf_model.estimate_extend_prefill(gather=True, **kw).time
        assert (plan.impl == "pallas") == (paged_t <= gather_t), shape
    # Pinned impls are never re-routed.
    pinned = plan_lib.plan_attention(
        gather_shape, phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
        page_size=16, prefix_pages=1, backend="gpu", impl="pallas",
    )
    assert pinned.impl == "pallas"


# --- grep enforcement ---------------------------------------------------------


def test_no_out_of_band_schedule_threading():
    """The four former dispatch sites consume AttentionPlans: none of them
    may thread ``q_offset`` / ``mapping_name`` by hand, look up
    ``PAPER_MAPPINGS``, or hand-roll a ``MappingConfig`` past the plan
    layer. (kernels/ops.py keeps ``q_offset`` only as the oracle/fallback
    argument of ``flash_attention``; the plan layer itself is the one
    reader of the config policy.) Single implementation: the linter's
    ``plan-dispatch-only`` rule."""
    from repro.analysis import run_rules

    assert run_rules(rules=["plan-dispatch-only"]) == []


def test_engine_resolves_schedules_through_plans():
    """Both facade backends' advertised mapping comes from the plan layer
    and honors a pinned override."""
    from repro.models import transformer
    from repro.serving import LLMEngine

    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=64, prompt_buckets=(16,))
    assert eng.mapping is plan_lib.plan_for_config(
        cfg, (2, cfg.n_heads, cfg.n_kv_heads, 64, 64, cfg.head_dim)
    ).mapping
    pinned = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                       cache_len=64, prompt_buckets=(16,),
                       mapping="naive_head_first")
    assert pinned.mapping is PAPER_MAPPINGS["naive_head_first"]
    with pytest.raises(KeyError):
        LLMEngine(cfg, params, kv_layout="dense", max_batch=2, cache_len=64,
                  prompt_buckets=(16,), mapping="bogus")
    paged = LLMEngine(cfg, params, kv_layout="paged", num_pages=32,
                      page_size=16, max_batch=2, max_pages_per_seq=4,
                      prompt_buckets=(16, 32))
    assert paged.mapping is plan_lib.plan_for_config(
        cfg, (2, cfg.n_heads, cfg.n_kv_heads, 1, 64, cfg.head_dim),
        phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED, page_size=16,
    ).mapping
