"""Property test for the shared chunk/page ``relevant`` predicate.

``decode_common.chunk_relevant`` gates whole KV units (dense chunks, pool
pages) in *both* decode kernels and both their split-K variants: a False
must mean "no position in this unit survives the mask" (soundness — a
false skip silently corrupts the softmax) and a True must mean at least
one position survives (completeness — a false admit only wastes compute,
but the predicate is exact and we pin that). Hypothesis drives windows
smaller than / equal to / straddling the unit, plus the length-0 and
full-cache edges.
"""

import numpy as np
import pytest

try:  # dev-only dep (requirements-dev.txt); the exhaustive sweep below
    from hypothesis import given, settings, strategies as st  # still runs without it
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.kernels.decode_common import chunk_relevant


def _valid_positions(chunk_start, chunk_len, length, window):
    """Ground truth: the decode mask evaluated per position."""
    pos = np.arange(chunk_start, chunk_start + chunk_len)
    valid = pos < length
    if window is not None and window > 0:
        valid &= pos > length - 1 - window
    return valid


def _check_exact(chunk_start, chunk_len, length, window):
    rel = bool(chunk_relevant(chunk_start, chunk_len, length, window))
    truth = bool(_valid_positions(chunk_start, chunk_len, length, window).any())
    assert rel == truth, (
        f"start={chunk_start} len={chunk_len} length={length} window={window}"
    )


def test_chunk_relevant_exhaustive_small_domain():
    """Every (unit index, length, window) over a small cache: the
    predicate equals per-position ground truth — including windows
    smaller than, equal to, and straddling the unit, and length 0."""
    chunk_len = 8
    for chunk_idx in range(8):
        for length in range(0, 65, 3):
            for window in (None, 1, 4, 7, 8, 9, 20, 64, 100):
                _check_exact(chunk_idx * chunk_len, chunk_len, length, window)


if HAVE_HYPOTHESIS:
    @settings(max_examples=300, deadline=None)
    @given(
        chunk_len=st.sampled_from([8, 16, 128, 512]),
        chunk_idx=st.integers(min_value=0, max_value=64),
        length=st.integers(min_value=0, max_value=4096),
        window=st.one_of(
            st.none(),
            st.integers(min_value=1, max_value=4096),
        ),
    )
    def test_chunk_relevant_is_exact(chunk_len, chunk_idx, length, window):
        _check_exact(chunk_idx * chunk_len, chunk_len, length, window)


@pytest.mark.parametrize("window", [8, 128, 200])
def test_window_vs_chunk_edges(window):
    """Window smaller than / equal to / straddling a 128-wide chunk: the
    single chunk holding the window's left edge must be admitted, chunks
    entirely behind it must not."""
    chunk = 128
    length = 1000  # window covers [length-window, length-1]
    for idx in range(0, 10):
        start = idx * chunk
        rel = bool(chunk_relevant(start, chunk, length, window))
        truth = bool(_valid_positions(start, chunk, length, window).any())
        assert rel == truth, (idx, window)
    # the chunk straddling the left edge specifically
    lo = length - window
    idx = lo // chunk
    assert bool(chunk_relevant(idx * chunk, chunk, length, window))
    if idx > 0:
        assert not bool(chunk_relevant((idx - 1) * chunk, chunk, length, window))


def test_length_zero_admits_nothing():
    for start in (0, 128, 512):
        assert not bool(chunk_relevant(start, 128, 0, None))
        assert not bool(chunk_relevant(start, 128, 0, 64))


def test_both_decode_kernels_share_the_predicate():
    """The dense and paged kernels (one-pass and split-K paths alike) must
    gate units through decode_common.chunk_relevant and merge partials via
    combine_split_states, not re-derive either locally. Single
    implementation: the linter's ``decode-relevance-shared`` rule."""
    from repro.analysis import run_rules

    assert run_rules(rules=["decode-relevance-shared"]) == []
