"""Unit tests for the analysis tooling: HLO collective parsing, roofline
terms, analytic perf model consistency with the event simulator."""

import pytest

from repro.core import cache_sim, numa, perf_model, swizzle
from repro.core.cache_sim import AttentionWorkload
from repro.core.swizzle import AttentionGrid
from repro.launch import hlo_analysis


def test_collective_bytes_parsing():
    hlo = """
      %ar = f32[16,1024]{1,0} all-reduce(%x), channel_id=1
      %ag = bf16[8,256,128]{2,1,0} all-gather(%y), dims={0}
      %rs = f32[4,4]{1,0} reduce-scatter(%z)
      %aa = (f32[2,8]{1,0}, f32[2,8]{1,0}) all-to-all(%a, %b)
      %cp = s32[16]{0} collective-permute(%c)
      %not_a_collective = f32[999,999]{1,0} dot(%p, %q)
      %ar2 = f32[8]{0} all-reduce-start(%w)
    """
    out = hlo_analysis.collective_bytes(hlo)
    assert out["all-reduce"] == 16 * 1024 * 4 + 8 * 4
    assert out["all-gather"] == 8 * 256 * 128 * 2
    assert out["reduce-scatter"] == 16 * 4
    assert out["all-to-all"] == 2 * 2 * 8 * 4
    assert out["collective-permute"] == 16 * 4
    assert out["total"] == sum(out[k] for k in hlo_analysis.COLLECTIVE_OPS)


def test_collective_bytes_ignores_plain_ops():
    assert hlo_analysis.collective_bytes("%d = f32[10]{0} dot(%a, %b)")["total"] == 0


def test_roofline_terms_dominance():
    t = hlo_analysis.roofline_terms(
        flops=197e12,            # exactly 1s of compute
        bytes_accessed=819e9 / 2,  # 0.5s of HBM
        coll_bytes=50e9 / 4,       # 0.25s of ICI
    )
    assert t["dominant"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    assert t["memory_s"] == pytest.approx(0.5)
    assert t["collective_s"] == pytest.approx(0.25)
    assert t["bound_s"] == pytest.approx(1.0)


def test_analytic_model_orders_match_simulator():
    """The fast analytic model must preserve the simulator's mapping order
    (used for quick sweeps; the event sim is ground truth)."""
    wl = AttentionWorkload(
        grid=AttentionGrid(batch=1, num_q_heads=32, blocks_per_head=0),
        seq_len=8192, head_dim=128,
    )
    sim = cache_sim.compare_mappings(wl, numa.MI300X, budget_accesses=400_000)
    for m in (swizzle.SWIZZLED_HEAD_FIRST, swizzle.NAIVE_BLOCK_FIRST):
        est = perf_model.estimate(m, wl, numa.MI300X)
        assert 0.0 <= est.hit_rate <= 1.0
    rel = perf_model.relative_performance(wl, numa.MI300X)
    # block-first must not beat swizzled head-first in either model
    assert rel[swizzle.NAIVE_BLOCK_FIRST] <= 1.05
    assert (sim[swizzle.NAIVE_BLOCK_FIRST].throughput
            <= sim[swizzle.SWIZZLED_HEAD_FIRST].throughput * 1.05)


def test_acc_info_fits():
    from repro.core import acc
    grid = AttentionGrid(batch=1, num_q_heads=8, blocks_per_head=64, group_size=2)
    info = acc.acc_info(grid, seq_len_kv=8192, head_dim=128, block_m=128)
    assert info.kv_bytes == 2 * 8192 * 128 * 2
    assert info.fits_cache(4 * 1024 * 1024)       # 4 MB: fits exactly
    assert not info.fits_cache(4 * 1024 * 1024 - 1)
    assert info.num_wgs == 2 * 64
