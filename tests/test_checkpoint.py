"""Checkpoint tests: roundtrip, atomicity, gc, resharding restore, async."""

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpoint as ck


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {
            "a_dm": jax.random.normal(k, (8, 16)),
            "nested": (jnp.arange(6, dtype=jnp.int32), {"b_r": jnp.ones((3,))}),
        },
        "step": jnp.asarray(7, jnp.int32),
    }


def assert_tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y))


def test_roundtrip(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 5, t, meta={"data_step": 5})
    restored, meta, step = ck.restore(str(tmp_path), t)
    assert step == 5 and meta["data_step"] == 5
    assert_tree_equal(t, restored)


def test_latest_and_gc(tmp_path):
    t = tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep_last=3)
    assert ck.latest_step(str(tmp_path)) == 5
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 3  # gc keeps the last 3


def test_partial_write_is_invisible(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 1, t)
    # Simulate a crashed writer: orphan tmp dir must be ignored by restore.
    os.makedirs(tmp_path / "step_00000002.tmp-deadbeef")
    assert ck.latest_step(str(tmp_path)) == 1
    restored, _, step = ck.restore(str(tmp_path), t)
    assert step == 1


def test_corrupt_manifest_ignored(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 1, t)
    bad = tmp_path / "step_00000009"
    os.makedirs(bad)
    # no manifest.json inside => not a valid checkpoint
    assert ck.latest_step(str(tmp_path)) == 1


def test_async_save(tmp_path):
    t = tree()
    ck.save(str(tmp_path), 3, t, async_write=True)
    deadline = time.time() + 10
    while ck.latest_step(str(tmp_path)) != 3 and time.time() < deadline:
        time.sleep(0.05)
    assert ck.latest_step(str(tmp_path)) == 3
    restored, _, _ = ck.restore(str(tmp_path), t)
    assert_tree_equal(t, restored)


def test_reshard_on_restore(tmp_path):
    """Elastic restore: load with explicit target shardings."""
    t = tree()
    ck.save(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
        t,
    )
    restored, _, _ = ck.restore(str(tmp_path), t, shardings=sh)
    assert_tree_equal(t, restored)
    for leaf in jax.tree.leaves(restored):
        assert isinstance(leaf, jax.Array)


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ck.restore(str(tmp_path / "nope"), tree())
