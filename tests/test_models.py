"""Per-architecture smoke tests: reduced configs of every assigned family.

Each arch: forward (train) produces finite logits of the right shape; a
train step reduces loss; prefill+decode match the full forward. Covers all
10 assigned architectures from the public pool.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.models import transformer
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, make_train_step

ARCHS = list(registry.ARCH_IDS)


def make_batch(cfg, b, s, seed=0):
    key = jax.random.PRNGKey(seed)
    shape = (b, s) if cfg.num_codebooks == 1 else (b, s, cfg.num_codebooks)
    tokens = jax.random.randint(key, shape, 0, cfg.vocab)
    batch = {
        "tokens": tokens,
        "targets": jnp.roll(tokens, -1, axis=1),
        "mask": jnp.ones((b, s), jnp.float32),
    }
    if cfg.vision_tokens:
        batch["image_embeds"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.vision_dim)
        )
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finiteness(arch):
    cfg = registry.get_smoke_config(arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 2, 64)
    logits, aux = transformer.forward(
        params, cfg, batch["tokens"],
        image_embeds=batch.get("image_embeds"), remat=False,
    )
    want = (2, 64, cfg.vocab) if cfg.num_codebooks == 1 else (
        2, 64, cfg.num_codebooks, cfg.vocab)
    assert logits.shape == want
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_reduces_loss(arch):
    cfg = registry.get_smoke_config(arch)
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=2e-3, warmup_steps=1, total_steps=10),
        microbatches=1,
    )
    state = init_train_state(jax.random.PRNGKey(0), cfg, tcfg)
    step = jax.jit(make_train_step(cfg, tcfg))
    batch = make_batch(cfg, 2, 32)
    losses = []
    for _ in range(6):
        state, metrics = step(state, batch)  # same batch: loss must fall
        losses.append(float(metrics["loss"]))
    assert all(jnp.isfinite(jnp.asarray(losses)))
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ["llama3-8b", "gemma2-2b", "mamba2-1.3b",
                                  "hymba-1.5b", "mixtral-8x7b", "musicgen-medium",
                                  "llama-3.2-vision-11b"])
def test_prefill_decode_matches_forward(arch):
    cfg = registry.get_smoke_config(arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    b, s, extra = 2, 48, 3
    batch = make_batch(cfg, b, s + extra, seed=1)
    tokens = batch["tokens"]
    img = batch.get("image_embeds")
    full, _ = transformer.forward(params, cfg, tokens, image_embeds=img, remat=False)
    lg, caches = transformer.prefill(
        params, cfg, tokens[:, :s], cache_len=s + extra, image_embeds=img
    )
    assert jnp.max(jnp.abs(lg - full[:, s - 1])) < 1e-3
    lengths = jnp.full((b,), s, jnp.int32)
    for t in range(extra):
        lengths = lengths + 1
        lg, caches = transformer.decode_step(
            params, cfg, tokens[:, s + t], caches, lengths
        )
        assert jnp.max(jnp.abs(lg - full[:, s + t])) < 1e-3


def test_scan_vs_unrolled_stack():
    """scan-over-periods == the same stack with the scan unrolled."""
    cfg = registry.get_smoke_config("gemma2-2b")
    import dataclasses
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, 1, 32)
    l1, _ = transformer.forward(params, cfg, batch["tokens"], remat=False)
    cfg2 = dataclasses.replace(cfg, scan_unroll=cfg.n_periods)
    l2, _ = transformer.forward(params, cfg2, batch["tokens"], remat=False)
    assert jnp.max(jnp.abs(l1 - l2)) < 1e-4


def test_param_counts_match_published():
    expected = {
        "llama3-8b": 8.0e9, "llama3-405b": 405e9, "mixtral-8x7b": 46.7e9,
        "mamba2-1.3b": 1.3e9, "gemma2-2b": 2.6e9,
    }
    for arch, want in expected.items():
        got = registry.get_config(arch).param_count()
        assert abs(got - want) / want < 0.06, (arch, got)
