"""Flash-decode kernel sweeps vs the decode oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import flash_decode


def mk(b, hq, hkv, smax, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), dtype)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), dtype)
    return q, kc, vc


@pytest.mark.parametrize("b,hq,hkv,smax,d", [
    (2, 8, 2, 1024, 64),
    (3, 4, 4, 512, 128),     # MHA
    (1, 25, 5, 512, 64),     # hymba-like odd group
    (2, 4, 1, 1024, 256),    # gemma-like
])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("softcap", [None, 50.0])
def test_decode_vs_oracle(b, hq, hkv, smax, d, window, softcap):
    q, kc, vc = mk(b, hq, hkv, smax, d)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, smax + 1, size=(b,)), jnp.int32
    )
    o = flash_decode(q, kc, vc, lengths, window=window, softcap=softcap,
                     chunk=256, interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths, window=window, softcap=softcap)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_length_one_and_full():
    q, kc, vc = mk(2, 8, 2, 512, 64, seed=1)
    lengths = jnp.asarray([1, 512], jnp.int32)
    o = flash_decode(q, kc, vc, lengths, chunk=128, interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_ops_dispatch():
    q, kc, vc = mk(2, 8, 2, 512, 64, seed=2)
    lengths = jnp.asarray([100, 300], jnp.int32)
    o1 = ops.decode_attention(q, kc, vc, lengths, impl="pallas")
    o2 = ops.decode_attention(q, kc, vc, lengths, impl="xla")
    assert jnp.max(jnp.abs(o1 - o2)) < 2e-5
