"""Flash-decode kernel sweeps vs the decode oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.decode_attention import flash_decode


def mk(b, hq, hkv, smax, d, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, d), dtype)
    kc = jax.random.normal(ks[1], (b, hkv, smax, d), dtype)
    vc = jax.random.normal(ks[2], (b, hkv, smax, d), dtype)
    return q, kc, vc


@pytest.mark.parametrize("b,hq,hkv,smax,d", [
    (2, 8, 2, 1024, 64),
    (3, 4, 4, 512, 128),     # MHA
    (1, 25, 5, 512, 64),     # hymba-like odd group
    (2, 4, 1, 1024, 256),    # gemma-like
])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("softcap", [None, 50.0])
def test_decode_vs_oracle(b, hq, hkv, smax, d, window, softcap):
    q, kc, vc = mk(b, hq, hkv, smax, d)
    lengths = jnp.asarray(
        np.random.default_rng(0).integers(1, smax + 1, size=(b,)), jnp.int32
    )
    o = flash_decode(q, kc, vc, lengths, window=window, softcap=softcap,
                     chunk=256, interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths, window=window, softcap=softcap)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_length_one_and_full():
    q, kc, vc = mk(2, 8, 2, 512, 64, seed=1)
    lengths = jnp.asarray([1, 512], jnp.int32)
    o = flash_decode(q, kc, vc, lengths, chunk=128, interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_ops_dispatch():
    q, kc, vc = mk(2, 8, 2, 512, 64, seed=2)
    lengths = jnp.asarray([100, 300], jnp.int32)
    o1 = ops.decode_attention(q, kc, vc, lengths, impl="pallas")
    o2 = ops.decode_attention(q, kc, vc, lengths, impl="xla")
    assert jnp.max(jnp.abs(o1 - o2)) < 2e-5


# --- edge cases (PR 2 satellites) --------------------------------------------


def test_length_zero_slot_is_zero():
    """An admitted-but-empty slot (length 0) must emit exactly zero — the
    l == 0 guard path — and match the (fixed) dense reference."""
    q, kc, vc = mk(3, 8, 2, 512, 64, seed=3)
    lengths = jnp.asarray([0, 17, 512], jnp.int32)
    o = flash_decode(q, kc, vc, lengths, chunk=128, interpret=True)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o[0])) == 0.0
    assert jnp.max(jnp.abs(o_ref[0])) == 0.0
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_window_smaller_than_chunk():
    """window < chunk: the chunk-relevance test must still admit the single
    chunk straddling the window, and in-chunk masking trims it."""
    q, kc, vc = mk(2, 8, 2, 1024, 64, seed=4)
    lengths = jnp.asarray([700, 1024], jnp.int32)
    for window in (8, 100):  # both << chunk
        o = flash_decode(q, kc, vc, lengths, window=window, chunk=256,
                         interpret=True)
        o_ref = ref.decode_attention(q, kc, vc, lengths, window=window)
        assert jnp.max(jnp.abs(o - o_ref)) < 2e-5, window


@pytest.mark.parametrize("smax", [100, 700, 1000])
def test_cache_length_not_chunk_multiple_pads(smax):
    """ops.decode_attention pads odd cache lengths up to a whole number of
    chunks; masking keeps the padded tail inert."""
    q, kc, vc = mk(2, 8, 2, smax, 64, seed=5)
    lengths = jnp.asarray([smax // 3, smax], jnp.int32)
    o = ops.decode_attention(q, kc, vc, lengths, impl="pallas")
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_resolver_decode_and_window_are_distinct_keys():
    """decode/window enter the resolver cache key and the scoring: decode
    shapes clamp the q block to the sublane quantum, and a sliding window
    shrinks the scored KV span."""
    shape = (8, 32, 8, 1, 131072 + 128, 128)
    prefill = ops.resolve_mapping((8, 32, 8, 4096, 4096, 128))
    decode = ops.resolve_mapping(shape, decode=True)
    windowed = ops.resolve_mapping(shape, decode=True, window=1024)
    assert decode is not prefill
    assert windowed is not decode
    assert decode.block_m == 16  # clamped to the one-token q block
    # 256K KV never fits residency, but a 1K window does.
    assert not decode.kv_resident
    assert windowed.kv_resident
