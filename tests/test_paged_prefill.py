"""Paged prefix-aware prefill kernel vs its two oracles (PR-3 headline).

All kernel runs are interpret-mode (CPU CI). Two independent ground truths:

  * ``ref.paged_prefill_attention`` — gather the prefix pages to dense and
    run exact attention with per-row dynamic offsets (the paged-decode-style
    oracle),
  * ``ops.flash_attention(q_offset=...)`` — the legacy dense XLA route the
    kernel replaces in the engine, for uniform (static) prefix lengths.

Coverage demanded by the issue: GQA / MQA / MHA shapes, prefix lengths that
are *not* page multiples, and length-0 tails.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_prefill_attention import paged_flash_prefill


def mk_extend(b, hq, hkv, d, ps, max_pages, st, seed=0, prefix_lens=None,
              tail_lens=None, dtype=jnp.float32):
    """Random q / page pool / table / tail K-V / lengths.

    prefix_lens may be arbitrary (non-page-multiple) per row; the page
    table holds ceil(len/ps) live pages from a shuffled pool (null page 0
    elsewhere). tail_lens default to the full tail bucket ``st``.
    """
    rng = np.random.default_rng(seed)
    num_pages = 1 + b * max_pages + 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, hq, st, d), dtype)
    kp = jax.random.normal(ks[1], (hkv, num_pages, ps, d), dtype)
    vp = jax.random.normal(ks[2], (hkv, num_pages, ps, d), dtype)
    kt = jax.random.normal(ks[3], (b, hkv, st, d), dtype)
    vt = jax.random.normal(ks[4], (b, hkv, st, d), dtype)
    if prefix_lens is None:
        prefix_lens = [int(rng.integers(0, max_pages * ps + 1)) for _ in range(b)]
    if tail_lens is None:
        tail_lens = [st] * b
    avail = list(rng.permutation(np.arange(1, num_pages)))
    pt = np.zeros((b, max_pages), np.int32)
    for i, plen in enumerate(prefix_lens):
        live = -(-int(plen) // ps)
        pt[i, :live] = [avail.pop() for _ in range(live)]
    return (q, kp, vp, jnp.asarray(pt), kt, vt,
            jnp.asarray(prefix_lens, jnp.int32),
            jnp.asarray(tail_lens, jnp.int32))


@pytest.mark.parametrize("b,hq,hkv,d", [
    (2, 8, 2, 64),       # GQA
    (1, 4, 4, 32),       # MHA
    (2, 4, 1, 64),       # MQA (gemma-like)
    (1, 25, 5, 64),      # odd group (hymba-like)
])
@pytest.mark.parametrize("window", [None, 24])
@pytest.mark.parametrize("softcap", [None, 50.0])
def test_paged_prefill_vs_oracle(b, hq, hkv, d, window, softcap):
    """Parity vs the gather-based exact oracle, random (non-page-multiple)
    prefix lengths and random tails."""
    q, kp, vp, pt, kt, vt, plen, tlen = mk_extend(
        b, hq, hkv, d, ps=16, max_pages=4, st=32, seed=b * 31 + hq,
    )
    o = paged_flash_prefill(q, kp, vp, pt, kt, vt, plen, tlen,
                            window=window, softcap=softcap, interpret=True)
    o_ref = ref.paged_prefill_attention(q, kp, vp, pt, kt, vt, plen, tlen,
                                        window=window, softcap=softcap)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


@pytest.mark.parametrize("plen", [16, 19, 37, 64])  # incl. non-multiples
def test_paged_prefill_vs_dense_q_offset_path(plen):
    """Parity vs the legacy dense XLA ``q_offset`` route the kernel
    replaces: gather the prefix to dense, concatenate the tail, and run
    ``ops.flash_attention`` with a static offset."""
    b, hq, hkv, d, ps, st = 1, 8, 2, 64, 16, 32
    q, kp, vp, pt, kt, vt, plen_a, tlen = mk_extend(
        b, hq, hkv, d, ps=ps, max_pages=4, st=st, seed=plen,
        prefix_lens=[plen],
    )
    o = paged_flash_prefill(q, kp, vp, pt, kt, vt, plen_a, tlen,
                            interpret=True)
    k_pref = ref.gather_pages(kp, pt)[:, :, :plen]
    v_pref = ref.gather_pages(vp, pt)[:, :, :plen]
    k_full = jnp.concatenate([k_pref, kt], axis=2)
    v_full = jnp.concatenate([v_pref, vt], axis=2)
    o_dense = ops.flash_attention(
        q, k_full, v_full, causal=True, q_offset=plen, impl="xla_flash",
    )
    assert jnp.max(jnp.abs(o - o_dense)) < 2e-5


def test_paged_prefill_zero_length_tail_rows_are_zero():
    """Rows at/past the live tail (bucket padding; a whole length-0 tail)
    emit exact zeros — no NaNs from fully-masked softmax rows."""
    q, kp, vp, pt, kt, vt, plen, _ = mk_extend(
        3, 8, 2, 32, ps=16, max_pages=3, st=16, seed=5,
        prefix_lens=[40, 16, 0],
    )
    tlen = jnp.asarray([7, 0, 16], jnp.int32)   # incl. a length-0 tail
    o = paged_flash_prefill(q, kp, vp, pt, kt, vt, plen, tlen, interpret=True)
    assert not jnp.any(jnp.isnan(o))
    assert float(jnp.max(jnp.abs(o[0, :, 7:]))) == 0.0
    assert float(jnp.max(jnp.abs(o[1]))) == 0.0
    o_ref = ref.paged_prefill_attention(q, kp, vp, pt, kt, vt, plen, tlen)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


def test_paged_prefill_zero_prefix_matches_plain_causal():
    """prefix_len == 0 (all-null table) degenerates to plain causal
    attention over the tail alone."""
    b, hq, hkv, d, st = 2, 8, 2, 32, 32
    q, kp, vp, pt, kt, vt, plen, tlen = mk_extend(
        b, hq, hkv, d, ps=16, max_pages=2, st=st, seed=7,
        prefix_lens=[0, 0],
    )
    o = paged_flash_prefill(q, kp, vp, pt, kt, vt, plen, tlen, interpret=True)
    o_plain = ref.attention(q, kt, vt, causal=True)
    assert jnp.max(jnp.abs(o - o_plain)) < 2e-5


def test_paged_prefill_ignores_dead_table_entries():
    """Null-page padding past the live prefix and unreferenced physical
    pages must not leak into the output (bucketed page tables rely on it)."""
    q, kp, vp, pt, kt, vt, plen, tlen = mk_extend(
        2, 4, 2, 32, ps=16, max_pages=4, st=16, seed=9,
        prefix_lens=[20, 48],
    )
    o1 = paged_flash_prefill(q, kp, vp, pt, kt, vt, plen, tlen, interpret=True)
    live = set()
    ptn = np.asarray(pt)
    for i, L in enumerate(np.asarray(plen)):
        live |= set(ptn[i, : -(-int(L) // 16)].tolist())
    poison = jnp.asarray(
        [1e6 if p not in live else 0.0 for p in range(kp.shape[1])], kp.dtype
    )[None, :, None, None]
    o2 = paged_flash_prefill(q, kp + poison, vp + poison, pt, kt, vt,
                             plen, tlen, interpret=True)
    assert jnp.max(jnp.abs(o1 - o2)) == 0.0
    # ...even inside the live pages, tokens past a non-multiple prefix_len
    # (the partial last page's dead rows) must be masked too.
    row_poison = kp.at[:, ptn[0, 1], 4:].add(1e6)  # prefix_len=20 < 32
    o3 = paged_flash_prefill(q, row_poison, vp, pt, kt, vt, plen, tlen,
                             interpret=True)
    assert jnp.max(jnp.abs(o1[0] - o3[0])) == 0.0


def test_ops_paged_prefill_dispatch():
    """ops-level dispatch: the pallas plan path equals the xla oracle plan
    path; unknown impls raise."""
    q, kp, vp, pt, kt, vt, plen, tlen = mk_extend(
        2, 8, 2, 64, ps=16, max_pages=3, st=16, seed=11,
    )
    o1 = ops.paged_prefill_attention(q, kp, vp, pt, kt, vt, plen, tlen,
                                     impl="pallas")
    o2 = ops.paged_prefill_attention(q, kp, vp, pt, kt, vt, plen, tlen,
                                     impl="xla")
    assert jnp.max(jnp.abs(o1 - o2)) < 2e-5
    with pytest.raises(ValueError):
        ops.paged_prefill_attention(q, kp, vp, pt, kt, vt, plen, tlen,
                                    impl="nope")


def test_paged_prefill_page_size_must_be_sublane_multiple():
    q = jnp.zeros((1, 4, 16, 32))
    kp = jnp.zeros((2, 4, 12, 32))  # page_size 12: not a multiple of 8
    pt = jnp.zeros((1, 2), jnp.int32)
    kt = jnp.zeros((1, 2, 16, 32))
    one = jnp.asarray([5], jnp.int32)
    with pytest.raises(ValueError):
        paged_flash_prefill(q, kp, kp, pt, kt, kt, one, one, interpret=True)
