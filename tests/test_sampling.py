"""On-device batched sampler edge cases (PR-5 satellite).

temperature=0 == argmax exactly; top-k=1 == greedy; top-p keeps the
smallest sorted-mass set (boundary token included); per-request seeds are
independent of batch composition; multi-codebook shapes sample one token
per codebook with codebook-distinct streams.
"""

import numpy as np
import pytest

from repro.serving.sampling import sample_tokens


def _params(b, temperature=1.0, top_k=0, top_p=1.0, seed=0, pos=0):
    return (
        np.full((b,), temperature, np.float32),
        np.full((b,), top_k, np.int32),
        np.full((b,), top_p, np.float32),
        np.full((b,), seed, np.int32),
        np.full((b,), pos, np.int32),
    )


def test_temperature_zero_is_exact_argmax():
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(5, 97)).astype(np.float32)
    out = np.asarray(sample_tokens(logits, *_params(5, temperature=0.0)))
    assert out.dtype == np.int32
    np.testing.assert_array_equal(out, np.argmax(logits, axis=-1))
    # ...even with adversarial top-k/top-p settings in the same call.
    t, k, p, s, c = _params(5, temperature=0.0, top_k=1, top_p=0.1)
    np.testing.assert_array_equal(
        np.asarray(sample_tokens(logits, t, k, p, s, c)),
        np.argmax(logits, axis=-1),
    )


def test_top_k_one_equals_greedy():
    rng = np.random.default_rng(1)
    logits = rng.normal(size=(4, 64)).astype(np.float32)
    for pos in range(5):  # any stream position
        out = np.asarray(sample_tokens(
            logits, *_params(4, temperature=1.3, top_k=1, pos=pos)
        ))
        np.testing.assert_array_equal(out, np.argmax(logits, axis=-1))


def test_top_k_support_is_the_k_largest():
    rng = np.random.default_rng(2)
    logits = rng.normal(size=(1, 32)).astype(np.float32)
    topk = set(np.argsort(logits[0])[-5:])
    seen = set()
    for pos in range(200):
        out = np.asarray(sample_tokens(
            logits, *_params(1, temperature=2.0, top_k=5, pos=pos)
        ))
        seen.add(int(out[0]))
    assert seen <= topk
    assert len(seen) > 1  # actually stochastic


def test_top_p_mass_boundary():
    """probs (0.5, 0.25, 0.15, 0.10): top_p keeps the smallest sorted set
    whose mass reaches p — {0} at 0.4 (the top token always survives),
    {0,1} at 0.6 (mass before token 1 is 0.5 < 0.6; before token 2 it is
    0.75 >= 0.6), {0,1,2} at 0.8."""
    logits = np.log(np.array([[0.5, 0.25, 0.15, 0.10]], np.float32))
    for top_p, want in ((0.4, {0}), (0.6, {0, 1}), (0.8, {0, 1, 2}),
                        (1.0, {0, 1, 2, 3})):
        seen = set()
        for pos in range(300):
            out = np.asarray(sample_tokens(
                logits, *_params(1, temperature=1.0, top_p=top_p, pos=pos)
            ))
            seen.add(int(out[0]))
        assert seen <= want, (top_p, seen)
        if len(want) > 1:
            assert len(seen) > 1, (top_p, seen)


def test_top_p_ties_at_cutoff_are_kept():
    """Tokens tied with the boundary probability all stay in the nucleus
    (value-threshold semantics): probs (0.5, 0.25, 0.125, 0.125) at
    top_p=0.8 keep token 3 because it ties token 2's cutoff prob."""
    logits = np.log(np.array([[0.5, 0.25, 0.125, 0.125]], np.float32))
    seen = set()
    for pos in range(400):
        out = np.asarray(sample_tokens(
            logits, *_params(1, temperature=1.0, top_p=0.8, pos=pos)
        ))
        seen.add(int(out[0]))
    assert seen == {0, 1, 2, 3}


def test_per_request_seeds_independent_within_batch():
    """Same logits in every row: equal seeds produce identical streams
    regardless of row position; a different seed diverges."""
    rng = np.random.default_rng(3)
    row = rng.normal(size=(128,)).astype(np.float32)
    logits = np.stack([row, row, row])
    t = np.full((3,), 1.0, np.float32)
    k = np.zeros((3,), np.int32)
    p = np.ones((3,), np.float32)
    seeds = np.asarray([7, 7, 9], np.int32)
    streams = {0: [], 1: [], 2: []}
    for pos in range(40):
        out = np.asarray(sample_tokens(
            logits, t, k, p, seeds, np.full((3,), pos, np.int32)
        ))
        for r in range(3):
            streams[r].append(int(out[r]))
    assert streams[0] == streams[1]   # same seed, different rows
    assert streams[0] != streams[2]   # different seed diverges


def test_seed_stream_independent_of_batch_size():
    """A request's stream depends only on (seed, position, logits) — not
    on how many rows share the tick (reproducible across batch
    compositions, the resume-after-preemption guarantee)."""
    rng = np.random.default_rng(4)
    row = rng.normal(size=(64,)).astype(np.float32)
    solo = [int(np.asarray(sample_tokens(
        row[None], *_params(1, temperature=0.9, seed=5, pos=pos)))[0])
        for pos in range(10)]
    other = rng.normal(size=(3, 64)).astype(np.float32)
    batched = []
    for pos in range(10):
        logits = np.concatenate([other[:2], row[None], other[2:]])
        t = np.asarray([0.0, 1.5, 0.9, 2.0], np.float32)
        k = np.zeros((4,), np.int32)
        p = np.ones((4,), np.float32)
        s = np.asarray([1, 2, 5, 3], np.int32)
        c = np.full((4,), pos, np.int32)
        batched.append(int(np.asarray(sample_tokens(logits, t, k, p, s, c))[2]))
    assert batched == solo


def test_multi_codebook_shapes_and_streams():
    """(B, K, V) logits -> (B, K) tokens; greedy matches per-codebook
    argmax exactly (musicgen shapes); stochastic codebooks draw from
    distinct streams."""
    rng = np.random.default_rng(5)
    logits = rng.normal(size=(2, 4, 48)).astype(np.float32)
    out = np.asarray(sample_tokens(logits, *_params(2, temperature=0.0)))
    assert out.shape == (2, 4)
    np.testing.assert_array_equal(out, np.argmax(logits, axis=-1))
    # Identical logits in every codebook: the per-codebook fold_in must
    # still decorrelate the draws (not 4 copies of one sample).
    same = np.broadcast_to(logits[:1, :1], (1, 4, 48)).copy()
    draws = set()
    for pos in range(50):
        out = np.asarray(sample_tokens(
            same, *_params(1, temperature=1.5, pos=pos)
        ))
        draws.add(tuple(out[0].tolist()))
        assert out.shape == (1, 4)
    assert any(len(set(d)) > 1 for d in draws)


def test_param_validation():
    from repro.serving.request import SamplingParams

    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(max_tokens=0)
    sp = SamplingParams(stop_token_ids=[3, np.int64(5)])
    assert sp.stop_token_ids == (3, 5)


def test_legacy_request_kwargs_build_sampling_params():
    from repro.serving.request import Request

    r = Request(uid=1, prompt=np.arange(4), max_new_tokens=7, eos_id=2,
                temperature=0.5)
    assert r.sampling.max_tokens == 7 == r.max_new_tokens
    assert r.sampling.stop_token_ids == (2,) and r.eos_id == 2
    assert r.sampling.temperature == 0.5 == r.temperature
    with pytest.raises(ValueError, match="not both"):
        from repro.serving.request import SamplingParams

        Request(uid=1, prompt=np.arange(4), sampling=SamplingParams(),
                max_new_tokens=3)
