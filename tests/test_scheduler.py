"""Scheduler policy tests in isolation: no models, no jax — fake
backends over the real ``PagePool`` accounting.

Property targets (PR-5 satellites):
  * no starvation under a continuous high-priority mix (aging),
  * the page-accounting invariant (used pages never exceed the pool; the
    scheduler never over-admits what the allocator cannot hold),
  * preemption always frees enough pages, never the protected row, and
    picks the lowest-priority / newest victim,
  * the NUMA-occupancy cap: a declining modeled tokens/s curve bounds
    admission; a linear (bandwidth-bound) curve never does.
"""

import numpy as np
import pytest

from repro.cache.pool import OutOfPages, PagePool
from repro.serving.request import Request, SamplingParams
from repro.serving.scheduler import DEFERRED, Scheduler


def make_req(uid, n_tokens=4, priority=0, max_tokens=4):
    return Request(uid, np.arange(1, n_tokens + 1),
                   SamplingParams(max_tokens=max_tokens), priority)


class FakeBackend:
    """Row + page mechanism with the real allocator, none of the model."""

    def __init__(self, rows=4, num_pages=32, page_size=1, reserve_pages=0,
                 decode_time_model=None):
        self.rows = rows
        self.pool = PagePool(num_pages, page_size)
        self.seqs = {}          # row -> (req, SequencePages, submit_order)
        self.reserve_pages = reserve_pages
        self._order = 0
        self._model = decode_time_model
        self.evictable_pages = 0

    @property
    def num_active(self):
        return len(self.seqs)

    @property
    def free_pages(self):
        return self.pool.free_pages

    def decode_time_model(self, batch):
        if self._model is None:
            return batch * 1e-6  # linear: bandwidth-bound, cap never binds
        return self._model(batch)

    def quote(self, req):
        return self.pool.pages_needed(len(req.prompt)), 0

    def try_admit(self, req, resume_tokens=(), pending_hashes=()):
        if len(self.seqs) >= self.rows:
            return None
        n = len(req.prompt) + len(resume_tokens)
        if not self.pool.can_allocate(n, reserve=self.reserve_pages):
            return None
        try:
            seq = self.pool.allocate_sequence(n)
        except OutOfPages:
            return None
        row = next(r for r in range(self.rows) if r not in self.seqs)
        self.seqs[row] = (req, seq, self._order)
        self._order += 1
        return {"req": req, "row": row}

    def release(self, row):
        _, seq, _ = self.seqs.pop(row)
        self.pool.release(seq)

    def victim_candidates(self, protect=-1):
        return [(req.priority, order, row)
                for row, (req, _, order) in self.seqs.items()]


def drain(records, backend):
    for rec in records:
        backend.release(rec["row"])


# --- fairness -----------------------------------------------------------------


def test_no_starvation_under_priority_mix():
    """A low-priority request facing an endless stream of fresh
    high-priority arrivals must still be admitted within the aging bound
    ((delta_priority + 1) * aging_rounds rounds)."""
    sched = Scheduler(aging_rounds=3)
    backend = FakeBackend(rows=1, num_pages=64)
    low = make_req(0, priority=0)
    sched.add(low)
    bound = (5 - 0 + 1) * sched.aging_rounds + 2
    admitted_round = None
    for rnd in range(bound + 5):
        sched.add(make_req(100 + rnd, priority=5))  # fresh high-prio rival
        records = []
        sched.schedule(backend, records)
        assert len(records) == 1  # one row -> one admission per round
        if records[0]["req"].uid == 0:
            admitted_round = rnd
            break
        drain(records, backend)   # rival finishes, row frees
    assert admitted_round is not None and admitted_round <= bound, \
        (admitted_round, bound)


def test_priority_order_with_fcfs_ties():
    sched = Scheduler()
    backend = FakeBackend(rows=3, num_pages=64)
    for uid, prio in ((0, 0), (1, 2), (2, 2)):
        sched.add(make_req(uid, priority=prio))
    records = []
    sched.schedule(backend, records)
    # Highest priority first; FCFS within a priority class; the
    # low-priority request still fits the third row this round.
    assert [r["req"].uid for r in records] == [1, 2, 0]


def test_requeued_preempted_requests_enter_first():
    sched = Scheduler()
    backend = FakeBackend(rows=2, num_pages=64)
    sched.add(make_req(0, priority=9))
    sched.requeue(make_req(7, priority=0), generated=[1, 2, 3])
    records = []
    sched.schedule(backend, records)
    # The preempted request re-enters before even a higher-priority
    # arrival, and carries its resume tokens.
    assert [r["req"].uid for r in records] == [7, 0]


def test_head_of_line_blocking_stops_the_round():
    """The first request that cannot fit ends the round: later (smaller)
    requests must not leapfrog it forever."""
    sched = Scheduler()
    backend = FakeBackend(rows=4, num_pages=8)  # 7 usable pages
    sched.add(make_req(0, n_tokens=6))   # 6 pages
    sched.add(make_req(1, n_tokens=6))   # does not fit alongside 0
    sched.add(make_req(2, n_tokens=1))   # would fit, but queues behind 1
    records = []
    sched.schedule(backend, records)
    assert [r["req"].uid for r in records] == [0]
    assert sched.num_waiting == 2


# --- page accounting ----------------------------------------------------------


def test_page_accounting_invariant_random_trace():
    """Random admission/finish trace: used pages never exceed the pool,
    free counts never go negative, and a drained system returns every
    page."""
    rng = np.random.default_rng(0)
    sched = Scheduler()
    backend = FakeBackend(rows=6, num_pages=24, reserve_pages=1)
    live = {}
    uid = 0
    for _ in range(300):
        for _ in range(int(rng.integers(0, 3))):
            sched.add(make_req(uid, n_tokens=int(rng.integers(1, 9))))
            uid += 1
        records = []
        sched.schedule(backend, records)
        for rec in records:
            live[rec["row"]] = rec["req"]
        assert 0 <= backend.pool.free_pages <= backend.pool.num_pages - 1
        assert backend.pool.used_pages <= backend.pool.num_pages - 1
        assert backend.num_active <= sched.occupancy_cap(backend)
        for row in list(live):
            if rng.random() < 0.4:
                backend.release(row)
                del live[row]
    for row in list(live):
        backend.release(row)
    assert backend.pool.used_pages == 0


@pytest.mark.slow
def test_page_accounting_invariant_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(st.lists(st.tuples(st.integers(1, 10), st.booleans()),
                    min_size=1, max_size=60))
    def run(trace):
        sched = Scheduler()
        backend = FakeBackend(rows=4, num_pages=12)
        uid = 0
        for n_tokens, release_one in trace:
            sched.add(make_req(uid, n_tokens=min(n_tokens, 11)))
            uid += 1
            records = []
            sched.schedule(backend, records)
            assert backend.pool.used_pages <= backend.pool.num_pages - 1
            if release_one and backend.seqs:
                backend.release(next(iter(backend.seqs)))

    run()


# --- preemption ---------------------------------------------------------------


def test_choose_victim_lowest_priority_newest_never_protected():
    sched = Scheduler()
    cands = [(2, 0, 0), (0, 1, 1), (0, 2, 2), (5, 3, 3)]
    assert sched.choose_victim(cands) == 2        # prio 0, newest
    assert sched.choose_victim(cands, protect=2) == 1
    assert sched.choose_victim([(1, 0, 4)], protect=4) is None


def test_preemption_frees_enough_pages_and_terminates():
    """Simulated decode growth: when the pool runs dry, repeatedly
    preempting scheduler-chosen victims must free enough pages for the
    protected row to append, never evict the protected row, and
    terminate."""
    sched = Scheduler()
    backend = FakeBackend(rows=4, num_pages=9, page_size=1)
    records = []
    for uid, prio in ((0, 1), (1, 0), (2, 2), (3, 0)):
        sched.add(make_req(uid, n_tokens=2, priority=prio))
    sched.schedule(backend, records)
    assert backend.num_active == 4  # 8 of 8 usable pages in use
    row_of = {rec["req"].uid: rec["row"] for rec in records}
    protect = row_of[0]  # grow the priority-1 request's row
    preempted = []
    _, seq0, _ = backend.seqs[protect]
    for _ in range(6):  # grow the protected row by 6 tokens
        while True:
            try:
                backend.pool.append_token(seq0)
                break
            except OutOfPages:
                victim = sched.choose_victim(
                    backend.victim_candidates(), protect=protect
                )
                assert victim is not None and victim != protect
                preempted.append(victim)
                backend.release(victim)
        assert backend.pool.free_pages >= 0
    # Victims: the prio-0 rows first (newest of them first), the prio-2
    # row only after every weaker row is gone; the protected row never.
    assert preempted == [row_of[3], row_of[1], row_of[2]]


# --- occupancy cap ------------------------------------------------------------


def test_occupancy_cap_binds_on_declining_throughput_model():
    """A modeled tokens/s curve that peaks at batch 3 must cap admission
    at 3 rows even with 8 rows and pages to spare — NUMA occupancy as
    admission policy."""

    def concave(batch):  # tok/s: 1, 1.25, 1.33, 1.14... peak at 3
        times = {1: 1.0, 2: 1.6, 3: 2.25, 4: 3.5, 5: 5.0, 6: 7.0, 7: 9.0,
                 8: 12.0}
        return times[batch]

    sched = Scheduler(decode_time_model=concave)
    backend = FakeBackend(rows=8, num_pages=64)
    assert sched.occupancy_cap(backend) == 3
    for uid in range(6):
        sched.add(make_req(uid))
    records = []
    sched.schedule(backend, records)
    assert len(records) == 3
    assert sched.num_waiting == 3


def test_occupancy_cap_open_under_linear_model():
    """The default bandwidth-bound linear model keeps aggregate tokens/s
    flat: the cap must stay at the row count (continuous batching intact)."""
    sched = Scheduler()
    backend = FakeBackend(rows=8, num_pages=64)
    assert sched.occupancy_cap(backend) == 8
    for uid in range(8):
        sched.add(make_req(uid, n_tokens=2))
    records = []
    sched.schedule(backend, records)
    assert len(records) == 8


def test_real_backends_expose_monotone_models():
    """The perf_model-backed decode_time_model hooks the real backends
    expose are positive and non-decreasing in batch (sanity for the cap)."""
    from repro.core import perf_model
    from repro.core.numa import MI300X

    for fn in (
        lambda b: perf_model.estimate_dense_decode(
            batch=b, num_q_heads=8, num_kv_heads=4, capacity=2048,
            head_dim=64, dtype_bytes=2, topo=MI300X).time,
        lambda b: perf_model.estimate_paged_decode(
            batch=b, num_q_heads=8, num_kv_heads=4, mean_len=1024,
            page_size=16, head_dim=64, dtype_bytes=2, topo=MI300X).time,
    ):
        times = [fn(b) for b in range(1, 9)]
        assert all(t > 0 for t in times)
        assert all(b <= a * (1 + 1e-9) for a, b in zip(times[1:], times))


# --- misc ---------------------------------------------------------------------


def test_deferred_sentinel_stops_round_without_consuming():
    class DeferringBackend(FakeBackend):
        def try_admit(self, req, resume_tokens=(), pending_hashes=()):
            if req.uid == 1:
                return DEFERRED
            return super().try_admit(req, resume_tokens, pending_hashes)

    sched = Scheduler()
    backend = DeferringBackend(rows=4, num_pages=64)
    for uid in range(3):
        sched.add(make_req(uid))
    records = []
    sched.schedule(backend, records)
    assert [r["req"].uid for r in records] == [0]
    assert sched.num_waiting == 2  # the deferred request stays queued
    records = []
    sched.schedule(backend, records)  # uid 1 still deferred next round
    assert [r["req"].uid for r in records] == []


def test_poison_request_is_ejected_and_raises():
    class RaisingBackend(FakeBackend):
        def try_admit(self, req, resume_tokens=(), pending_hashes=()):
            if req.uid == 0:
                raise ValueError("bad prompt")
            return super().try_admit(req, resume_tokens, pending_hashes)

    sched = Scheduler()
    backend = RaisingBackend(rows=2, num_pages=16)
    sched.add(make_req(0))
    sched.add(make_req(1))
    records = []
    with pytest.raises(ValueError, match="bad prompt"):
        sched.schedule(backend, records)
    assert sched.num_waiting == 1  # the poison request is gone
    records = []
    sched.schedule(backend, records)
    assert [r["req"].uid for r in records] == [1]
