"""Fused multi-step decode: ``steps_per_sync=N`` vs N single steps.

PR-8 acceptance criteria covered here:
  * a fused N-step sync bit-matches N single-step syncs for BOTH kv
    layouts — greedy rows, per-request-seeded stochastic rows, a stop
    token firing mid-scan, and ``N > remaining max_tokens`` all included;
  * the bit-match holds across preemption/resume under page pressure;
  * page accounting stays exact under the shadow-pool sanitizer
    (``conftest.py`` auto-attaches it to this module) and teardown
    proves zero leaked pages;
  * the scan launcher's jit keys are O(1) per engine: the retrace
    counter (``backend.stats["decode_traces"]``) is FLAT after warmup;
  * ``PagePool.reserve_tokens`` / ``trim_tokens`` — the host-side half
    of the fused sync — keep COW and partial-progress semantics
    identical to N single-step appends.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache.pool import OutOfPages, PagePool
from repro.configs import registry
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams


@pytest.fixture(scope="module")
def llama():
    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    return cfg, params


def direct_greedy(cfg, params, prompt, n_new, cache_len=256):
    lg, caches = transformer.prefill(
        params, cfg, jnp.asarray(prompt)[None], cache_len=cache_len
    )
    toks, lengths = [], jnp.array([len(prompt)], jnp.int32)
    nxt = int(jnp.argmax(lg[0]))
    for _ in range(n_new):
        toks.append(nxt)
        lengths = lengths + 1
        lg, caches = transformer.decode_step(
            params, cfg, jnp.asarray([nxt]), caches, lengths
        )
        nxt = int(jnp.argmax(lg[0]))
    return toks


def toks_of(out):
    return [int(t) for t in out.tokens]


LAYOUTS = {
    "dense": dict(kv_layout="dense", max_batch=3, cache_len=256,
                  prompt_buckets=(32, 64)),
    "paged": dict(kv_layout="paged", max_batch=3, num_pages=96,
                  page_size=16, max_pages_per_seq=8,
                  prompt_buckets=(16, 32, 64)),
}


def run_at(cfg, params, reqs, n, kw):
    eng = LLMEngine(cfg, params, steps_per_sync=n, **kw)
    return eng, {r.uid: r for r in eng.generate([r.clone() for r in reqs])}


# --- bit-match: fused N steps == N single steps -------------------------------


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_fused_bit_matches_single_step(llama, layout):
    """One scan of 8 ticks produces exactly the tokens of 8 one-tick
    syncs: greedy rows, a seeded stochastic row, and a row whose
    ``max_tokens`` (3) is smaller than the scan length (the done mask
    parks it mid-scan without a host round-trip)."""
    cfg, params = llama
    rng = np.random.default_rng(30)
    prompts = [rng.integers(1, 400, size=(L,)) for L in (8, 20, 33)]
    reqs = [
        Request(uid=0, prompt=prompts[0], max_new_tokens=9),
        Request(uid=1, prompt=prompts[1],
                sampling=SamplingParams(temperature=0.9, top_k=25,
                                        max_tokens=7, seed=3)),
        Request(uid=2, prompt=prompts[2], max_new_tokens=3),  # < N=8
    ]
    kw = LAYOUTS[layout]
    _, base = run_at(cfg, params, reqs, 1, kw)
    _, fused = run_at(cfg, params, reqs, 8, kw)
    assert sorted(fused) == [0, 1, 2]
    for uid in (0, 1, 2):
        assert toks_of(fused[uid]) == toks_of(base[uid]), (layout, uid)
        assert fused[uid].finish_reason == base[uid].finish_reason
    for uid in (0, 2):  # greedy rows also equal the direct oracle
        want = direct_greedy(cfg, params, prompts[uid],
                             reqs[uid].sampling.max_tokens)
        assert toks_of(fused[uid]) == want, (layout, uid)


@pytest.mark.parametrize("layout", ["dense", "paged"])
def test_fused_stop_token_mid_scan(llama, layout):
    """On-device stop detection: a stop token sampled at tick i < N
    terminates the row inside the scan — same tokens (stop included) and
    ``finish_reason`` as the single-step engine."""
    cfg, params = llama
    prompt = np.random.default_rng(31).integers(1, 400, size=(12,))
    ref_toks = direct_greedy(cfg, params, prompt, 8, cache_len=128)
    i = next(k for k in range(1, 8) if ref_toks[k] not in ref_toks[:k])
    kw = dict(LAYOUTS[layout], max_batch=1)
    eng = LLMEngine(cfg, params, steps_per_sync=8, **kw)
    res = eng.generate([Request(uid=0, prompt=prompt, max_new_tokens=8,
                                eos_id=int(ref_toks[i]))])
    assert toks_of(res[0]) == ref_toks[: i + 1]
    assert res[0].finish_reason == "stop"
    # The FED token can be the stop too (first generated token): the
    # fed-stop mask outranks everything, still inside the scan.
    res0 = eng.generate([Request(uid=1, prompt=prompt, max_new_tokens=8,
                                 eos_id=int(ref_toks[0]))])
    assert toks_of(res0[0]) == [ref_toks[0]]
    assert res0[0].finish_reason == "stop"


def test_fused_bit_matches_across_preemption(llama):
    """Page pressure mid-sync: the scan's pre-reservation preempts the
    lowest-priority row, it resumes later, and every output still equals
    the direct greedy decode — the bit-match survives evict/replay with
    N > 1."""
    cfg, params = llama
    rng = np.random.default_rng(32)
    prompts = [rng.integers(1, 400, size=(20,)) for _ in range(3)]
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=12,
                    page_size=16, max_batch=3, max_pages_per_seq=4,
                    prompt_buckets=(16, 32), steps_per_sync=4)
    reqs = [Request(uid=i, prompt=p, max_new_tokens=30, priority=i)
            for i, p in enumerate(prompts)]
    results = eng.generate(reqs)
    assert sorted(r.uid for r in results) == [0, 1, 2]
    stats = eng.stats()
    assert stats.preemptions >= 1
    assert stats.resumed_tokens > 0
    for r in results:
        want = direct_greedy(cfg, params, prompts[r.uid], 30)
        assert toks_of(r) == want, r.uid


# --- zero steady-state retraces ----------------------------------------------


def test_retrace_counter_flat_after_warmup(llama):
    """The scan launcher's jit key is (N, stop-width bucket, codebooks) —
    constant for a given engine + workload shape — so after the first
    sync compiles, later waves of requests add ZERO decode traces."""
    cfg, params = llama
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16,), steps_per_sync=4)
    rng = np.random.default_rng(33)

    def wave(uid0):
        return [Request(uid=uid0 + i,
                        prompt=rng.integers(1, 400, size=(8 + i,)),
                        max_new_tokens=6) for i in range(2)]

    eng.generate(wave(0))
    warm = eng.backend.stats["decode_traces"]
    assert warm >= 1
    for k in (10, 20, 30):
        eng.generate(wave(k))
        assert eng.backend.stats["decode_traces"] == warm


# --- page accounting under the sanitizer -------------------------------------


def test_fused_page_accounting_zero_leak(llama):
    """Reserve-then-trim page accounting over a full fused run: shared
    prefixes, early stops (trim), and teardown all balance — the shadow
    sanitizer (auto-attached by conftest) re-verifies every refcount."""
    cfg, params = llama
    rng = np.random.default_rng(34)
    system = rng.integers(1, 400, size=(32,))
    eng = LLMEngine(cfg, params, kv_layout="paged", num_pages=64,
                    page_size=16, max_batch=3, max_pages_per_seq=8,
                    prompt_buckets=(16, 64), steps_per_sync=8)
    for i in range(3):
        tail = rng.integers(1, 400, size=(6 + i,))
        eng.add_request(Request(uid=i, prompt=np.concatenate([system, tail]),
                                max_new_tokens=5 + i))
    # First sync may already finish the shortest request (5 tokens < N=8).
    done = [o for o in eng.step() if o.finished]
    assert eng.backend.check_leaks() == {}
    done += eng.generate([])
    assert sorted(r.uid for r in done) == [0, 1, 2]
    assert eng.backend.check_leaks() == {}
    eng.close()
    assert eng.backend.pool.used_pages == 0
    assert eng.backend.pool.check_leaks() == {}


# --- PagePool reserve/trim primitives ----------------------------------------


def test_pool_reserve_and_trim_tokens():
    pool = PagePool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(5)              # 2 pages
    cows = pool.reserve_tokens(seq, 6)           # 5 -> 11 tokens, 3 pages
    assert cows == []                            # nothing shared, no COW
    assert seq.length == 11 and len(seq.pages) == 3
    freed = pool.trim_tokens(seq, 6)             # back to 2 pages
    assert freed == 1
    assert seq.length == 6 and len(seq.pages) == 2
    with pytest.raises(ValueError):
        pool.trim_tokens(seq, 7)                 # can't trim upward
    pool.release(seq)
    assert pool.check_leaks() == {}


def test_pool_reserve_tokens_cow_on_shared_tail():
    """Reserving into a forked sequence's shared partial tail emits the
    (src, dst) copy exactly as a single-step append would."""
    pool = PagePool(num_pages=8, page_size=4)
    seq = pool.allocate_sequence(6)              # partial tail (2/4 used)
    fork = pool.fork(seq)
    cows = pool.reserve_tokens(fork, 2)
    assert len(cows) == 1
    src, dst = cows[0]
    assert src == seq.pages[-1] and dst == fork.pages[-1] and src != dst
    assert seq.length == 6 and fork.length == 8
    pool.release(fork)
    pool.release(seq)
    assert pool.check_leaks() == {}


def test_pool_reserve_tokens_partial_progress_on_exhaustion():
    """OutOfPages mid-reservation keeps the partial growth (the engine
    frees room and re-requests the remainder) instead of unwinding it."""
    pool = PagePool(num_pages=4, page_size=4)    # 3 usable pages
    seq = pool.allocate_sequence(4)              # 1 page
    cows = []
    with pytest.raises(OutOfPages):
        pool.reserve_tokens(seq, 12, cows)       # needs a 4th page
    assert seq.length == 12 and len(seq.pages) == 3  # progress kept
    assert cows == []
    freed = pool.trim_tokens(seq, 4)
    assert freed == 2
    pool.release(seq)
    assert pool.check_leaks() == {}
