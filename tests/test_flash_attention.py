"""Per-kernel allclose sweeps: Pallas FA2 (fwd/bwd) vs the pure-jnp oracle.

Every kernel runs in interpret mode on CPU (the kernel body executes in
Python) across shapes x dtypes x mask/softcap flags x mapping orders.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import (
    BLOCK_FIRST, HEAD_FIRST, MappingConfig, flash_attention_fwd,
    hbm_block_fetches,
)
from repro.kernels.flash_attention_bwd import flash_attention_bwd


def mk(b, hq, hkv, sq, skv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, skv, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, skv, d), dtype)
    do = jax.random.normal(ks[3], (b, hq, sq, d), dtype)
    return q, k, v, do


SHAPES = [
    # b, hq, hkv, sq, skv, d
    (1, 2, 2, 256, 256, 64),
    (2, 4, 2, 256, 256, 128),   # GQA g=2
    (1, 4, 1, 128, 384, 64),    # MQA, rectangular
    (1, 2, 2, 256, 256, 256),   # gemma-sized head
]
FLAGS = [
    dict(causal=True, window=None, softcap=None),
    dict(causal=True, window=128, softcap=None),
    dict(causal=True, window=None, softcap=30.0),
    dict(causal=False, window=None, softcap=None),
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("flags", FLAGS)
@pytest.mark.parametrize("order,resident", [
    (HEAD_FIRST, True), (HEAD_FIRST, False), (BLOCK_FIRST, False),
])
def test_fwd_vs_oracle(shape, flags, order, resident):
    b, hq, hkv, sq, skv, d = shape
    if flags["causal"] and sq != skv:
        pytest.skip("causal requires square for this oracle comparison")
    q, k, v, _ = mk(*shape, jnp.float32)
    mc = MappingConfig(order=order, kv_resident=resident)
    o, lse = flash_attention_fwd(q, k, v, mapping=mc, interpret=True, **flags)
    o_ref = ref.attention(q, k, v, **flags)
    lse_ref = ref.attention_lse(q, k, v, **flags)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5
    assert jnp.max(jnp.abs(lse - lse_ref)) < 2e-5


@pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5), (jnp.bfloat16, 3e-2)])
def test_fwd_dtypes(dtype, tol):
    q, k, v, _ = mk(1, 4, 2, 256, 256, 64, dtype)
    o = flash_attention_fwd(q, k, v, mapping=MappingConfig(), interpret=True)[0]
    o_ref = ref.attention(q, k, v)
    assert jnp.max(jnp.abs(o.astype(jnp.float32) - o_ref.astype(jnp.float32))) < tol


@pytest.mark.parametrize("shape", SHAPES[:3])
@pytest.mark.parametrize("flags", FLAGS)
@pytest.mark.parametrize("order", [HEAD_FIRST, BLOCK_FIRST])
def test_bwd_vs_grad_of_oracle(shape, flags, order):
    b, hq, hkv, sq, skv, d = shape
    if flags["causal"] and sq != skv:
        pytest.skip("square-only comparison")
    q, k, v, do = mk(*shape, jnp.float32, seed=1)
    mc = MappingConfig(order=order)
    o, lse = flash_attention_fwd(q, k, v, mapping=mc, interpret=True, **flags)
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, mapping=mc, interpret=True, **flags
    )

    def loss(q, k, v):
        return jnp.sum(ref.attention(q, k, v, **flags) * do)

    dq_r, dk_r, dv_r = jax.grad(loss, (0, 1, 2))(q, k, v)
    for got, want, name in [(dq, dq_r, "dq"), (dk, dk_r, "dk"), (dv, dv_r, "dv")]:
        assert jnp.max(jnp.abs(got - want)) < 5e-5, name


def test_custom_vjp_path():
    """ops.flash_attention(pallas) is differentiable end to end."""
    q, k, v, do = mk(1, 4, 2, 256, 256, 64, jnp.float32, seed=2)

    def f(impl):
        return jax.grad(
            lambda q: jnp.sum(ops.flash_attention(q, k, v, impl=impl) * do)
        )(q)

    g_pallas = f("pallas")
    g_ref = f("ref")
    assert jnp.max(jnp.abs(g_pallas - g_ref)) < 5e-5


def test_xla_flash_impls_match_ref():
    q, k, v, _ = mk(1, 4, 2, 2048, 2048, 64, jnp.float32, seed=3)
    o_ref = ref.attention(q, k, v, causal=True, window=512)
    for impl in ("xla_flash", "xla_flash_tri"):
        o = ops.flash_attention(q, k, v, causal=True, window=512, impl=impl)
        assert jnp.max(jnp.abs(o - o_ref)) < 2e-5, impl


def test_padding_path():
    """Non-block-multiple sequence lengths go through the padding wrapper."""
    q, k, v, _ = mk(1, 2, 2, 200, 200, 64, jnp.float32, seed=4)
    o = ops.flash_attention(q, k, v, causal=True, impl="pallas")
    o_ref = ref.attention(q, k, v, causal=True)
    assert o.shape == (1, 2, 200, 64)
    assert jnp.max(jnp.abs(o - o_ref)) < 2e-5


# --- HBM traffic model: the TPU analogue of the paper's hit rates -----------


def test_hbm_traffic_head_first_resident_is_ideal():
    common = dict(batch=1, num_q_heads=16, num_kv_heads=4, seq_q=4096,
                  seq_kv=4096, head_dim=128)
    res_hf = hbm_block_fetches(
        mapping=MappingConfig(order=HEAD_FIRST, kv_resident=True), **common)
    res_bf = hbm_block_fetches(
        mapping=MappingConfig(order=BLOCK_FIRST, kv_resident=True), **common)
    stream = hbm_block_fetches(
        mapping=MappingConfig(order=HEAD_FIRST, kv_resident=False), **common)
    # Head-first + resident fetches each ACC's KV exactly once => ideal.
    assert res_hf["reuse_efficiency"] == pytest.approx(1.0)
    # Block-first destroys residency: every (kv head, q-block) refetches KV
    # (consecutive q-heads of a group still share the revisited block).
    num_m = 4096 // 128
    assert res_bf["kv_bytes"] == num_m * res_hf["kv_bytes"]
    assert res_bf["kv_bytes"] > 10 * res_hf["kv_bytes"]
    # Streaming refetches the full tile sweep per (q-head, q-block): worse
    # than even thrashing residency by the GQA group factor.
    assert stream["kv_bytes"] == 4 * res_bf["kv_bytes"]
