"""Domain-purity access tracer (repro.analysis.access_trace).

The tracer replays the kernels' exported BlockSpec index maps — the same
functions ``pallas_call`` receives — so these tests prove the NUMA claims
about what the kernels *touch*, independent of their numeric output.
"""

import numpy as np
import pytest

from repro.analysis import access_trace as at
from repro.cache import layout
from repro.kernels import plan as plan_lib


def _table(b, mp, start=1):
    """Distinct physical ids per row (no sharing, no nulls)."""
    return np.arange(start, start + b * mp).reshape(b, mp)


# --- paged decode -------------------------------------------------------------


def test_one_pass_trace_touches_every_table_slot():
    pt = _table(2, 6)
    tr = at.trace_paged_decode(pt, [48, 20], num_kv_heads=4, page_size=8)
    assert len(tr.cells) == 2 * 4
    for c in tr.cells:
        b = c.cell[0]
        assert c.touched == tuple(pt[b])          # every slot DMA'd
        live = -(-(48 if b == 0 else 20) // 8)
        assert c.live == tuple(pt[b, :live])      # compute gated by length
    tr.assert_domain_local()                      # head-major pool


def test_split_trace_matches_decode_split_ranges():
    pt = _table(1, 12)
    tr = at.trace_paged_decode(pt, [96], num_kv_heads=2, page_size=8,
                               num_splits=4)
    ranges = layout.decode_split_ranges(12, 4)
    per_head = {}
    for c in tr.cells:
        per_head.setdefault(c.head, []).append(c)
    for head, cells in per_head.items():
        assert [c.live_logical for c in cells] == \
            [tuple(range(s, e)) for s, e in ranges]
    tr.assert_domain_local()


def test_split_trace_clamps_tail_overhang():
    # 10 pages over 4 splits -> pps=3, last split covers (9, 10): two
    # overhang steps clamp to slot 9, recorded as touched but not live.
    pt = _table(1, 10)
    tr = at.trace_paged_decode(pt, [80], num_kv_heads=1, page_size=8,
                               num_splits=4)
    tail = tr.cells[-1]
    assert tail.touched == (pt[0, 9], pt[0, 9], pt[0, 9])
    assert tail.live == (pt[0, 9],)
    assert tail.live_logical == (9,)


def test_window_gates_live_pages():
    pt = _table(1, 8)
    full = at.trace_paged_decode(pt, [64], num_kv_heads=1, page_size=8)
    windowed = at.trace_paged_decode(pt, [64], num_kv_heads=1, page_size=8,
                                     window=16)
    assert full.live_pages == 8
    assert windowed.live_pages == 2   # only the last two pages attend
    assert windowed.touched_pages == 8  # DMAs still issue, compute skips


def test_interleaved_straddle_fails_purity():
    """The tracer agrees with split_ranges_domain_aligned: an identity
    page table under INTERLEAVED straddles exactly when the analytic
    check says a range does."""
    mp, splits, hkv, doms = 8, 2, 2, 2
    pt = np.tile(np.arange(mp), (1, 1))  # logical == physical
    ranges = layout.decode_split_ranges(mp, splits)
    assert not layout.split_ranges_domain_aligned(
        ranges, head=0, policy=layout.INTERLEAVED,
        num_kv_heads=hkv, num_domains=doms)
    tr = at.trace_paged_decode(pt, [mp * 8], num_kv_heads=hkv, page_size=8,
                               num_splits=splits,
                               policy=layout.INTERLEAVED, num_domains=doms)
    with pytest.raises(at.DomainPurityError):
        tr.assert_domain_pure()
    # and HEAD_ALIGNED over the same ranges is certified by both
    assert layout.split_ranges_domain_aligned(
        ranges, head=0, policy=layout.HEAD_ALIGNED,
        num_kv_heads=hkv, num_domains=doms)
    at.trace_paged_decode(pt, [mp * 8], num_kv_heads=hkv, page_size=8,
                          num_splits=splits).assert_domain_local()


def test_pure_but_not_local_is_distinguished():
    # Single-domain interleaved placement: every page in domain 0, but
    # heads 1.. of a 4-head/2-domain grid execute in domain 1.
    pt = np.zeros((1, 4), dtype=np.int64) + 2  # pid 2 -> 2 % 2 == 0
    tr = at.trace_paged_decode(pt * 0 + 2, [32], num_kv_heads=4, page_size=8,
                               policy=layout.INTERLEAVED, num_domains=2)
    tr.assert_domain_pure()   # one domain per cell: pure
    with pytest.raises(at.DomainPurityError):
        tr.assert_domain_local()  # but heads 2,3 read cross-domain


# --- paged prefill ------------------------------------------------------------


def test_prefill_trace_clamps_tail_sweep():
    pt = _table(2, 3)
    tr = at.trace_paged_prefill(pt, [24, 9], num_kv_heads=2, page_size=8,
                                num_tail=2)
    for c in tr.cells:
        b = c.cell[0]
        # 3 prefix steps + 2 tail steps, tail clamped to the last slot
        assert c.touched == tuple(pt[b]) + (pt[b, 2], pt[b, 2])
        live = -(-(24 if b == 0 else 9) // 8)
        assert c.live_logical == tuple(range(live))
    tr.assert_domain_local()


# --- dense split decode -------------------------------------------------------


def test_dense_split_trace_walks_the_partition():
    tr = at.trace_dense_split_decode([300, 100], capacity=512, chunk=64,
                                     num_kv_heads=4, num_splits=4)
    ranges = layout.decode_split_ranges(512 // 64, 4)
    for c in tr.cells:
        b, _, s = c.cell
        start, end = ranges[s]
        length = 300 if b == 0 else 100
        live_chunks = -(-length // 64)
        expect = tuple(p for p in range(start, end) if p < live_chunks)
        assert c.live_logical == expect
    tr.assert_domain_local()


# --- plan-level entry point ---------------------------------------------------


def test_trace_plan_for_a_real_split_plan():
    """The acceptance-bar path: resolve a real paged DECODE plan with
    num_splits > 1 and trace it end to end."""
    shape = (1, 4, 1, 1, 32768, 64)
    plan = plan_lib.plan_attention(
        shape, phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED,
        page_size=32, backend="cpu", dtype_bytes=4, impl="pallas",
    )
    assert plan.num_splits > 1
    assert plan.placement == layout.HEAD_ALIGNED
    mp = 32768 // 32
    pt = _table(1, mp)
    tr = at.trace_plan(plan, pt, [32768], num_kv_heads=1, num_domains=2)
    tr.assert_domain_local()
    assert tr.kernel == "paged_flash_decode_split"
    assert {c.cell[2] for c in tr.cells} == \
        set(range(len(layout.decode_split_ranges(mp, plan.num_splits))))
