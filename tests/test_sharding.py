"""Sharding-rule tests: naming convention, divisibility repair, placement."""

import jax
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep: requirements-dev.txt
from hypothesis import given, settings, strategies as st
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import placement
from repro.distributed import sharding as shlib


def host_mesh(shape=(2, 2), axes=("data", "model")):
    n = int(np.prod(shape))
    devs = np.array(jax.devices()[:1] * n).reshape(shape)  # fake for spec math
    # fix_spec only reads mesh.shape, so a trivial mesh suffices:
    return jax.sharding.Mesh(
        np.array(jax.devices() * n)[:n].reshape(shape), axes
    )


MESH = host_mesh()


class FakeLeaf:
    def __init__(self, shape):
        self.shape = shape
        self.ndim = len(shape)


def spec_of(key, shape):
    from jax.tree_util import DictKey
    return shlib.spec_for_path((DictKey(key),), FakeLeaf(shape))


def test_suffix_rules():
    assert spec_of("wq_dm", (64, 64)) == P(None, "model")
    assert spec_of("wo_md", (64, 64)) == P("model")
    assert spec_of("table_vd", (512, 64)) == P("model")
    assert spec_of("wi_gate_edm", (8, 64, 128)) == P("model")
    assert spec_of("scale_r", (64,)) == P()
    assert spec_of("router_de", (64, 8)) == P()


def test_stacked_right_alignment():
    # scan-stacked params carry a leading period dim.
    assert spec_of("wq_dm", (4, 64, 64)) == P(None, None, "model")
    assert spec_of("wo_md", (4, 64, 64)) == P(None, "model")


def test_fix_spec_rehomes_vocab():
    # 50280 % 2 == 0 so a 2-way axis fits; force failure with an odd vocab.
    s = shlib.fix_spec(P("model", None), (32001, 64), MESH)
    assert s == P(None, "model")  # moved to d_model


def test_fix_spec_rehomes_expert_dim():
    big = host_mesh((1, 16))
    s = shlib.fix_spec(P(None, "model", None, None), (32, 8, 64, 14336), big)
    assert s == P(None, None, None, "model")


def test_fix_spec_replicates_when_hopeless():
    s = shlib.fix_spec(P("model",), (7,), host_mesh((1, 16)))
    assert s == P()


@settings(max_examples=60, deadline=None)
@given(
    dims=st.lists(st.integers(1, 4096), min_size=1, max_size=4),
    which=st.integers(0, 3),
    msize=st.sampled_from([2, 4, 16]),
)
def test_fix_spec_always_valid(dims, which, msize):
    mesh = host_mesh((1, msize))
    spec = P(*[("model" if i == which % len(dims) else None) for i in range(len(dims))])
    fixed = shlib.fix_spec(spec, tuple(dims), mesh)
    for i, ax in enumerate(tuple(fixed)):
        if ax is None:
            continue
        sz = msize if ax == "model" else 1
        assert dims[i] % sz == 0


def test_batch_spec_degenerate_batch():
    assert shlib.batch_spec(MESH, 1) == P(None)
    assert shlib.batch_spec(MESH, 8) == P("data")


# --- ACC-aligned placement (the paper's technique at mesh level) -------------


@pytest.mark.parametrize("hq,hkv,n", [(128, 8, 8), (32, 8, 4), (128, 8, 16), (16, 16, 4)])
def test_acc_aligned_never_duplicates(hq, hkv, n):
    pl = placement.plan(hq, hkv, n, placement.ACC_ALIGNED)
    if n <= hkv or hkv % n == 0 or n % hkv == 0:
        assert pl.kv_duplication == pytest.approx(max(1.0, n / hkv) if n > hkv else 1.0)


def test_striped_duplicates_gqa():
    pl = placement.plan(128, 8, 8, placement.STRIPED)
    assert pl.kv_duplication > 1.0
    aligned = placement.plan(128, 8, 8, placement.ACC_ALIGNED)
    assert aligned.kv_duplication == 1.0
    extra = placement.kv_collective_bytes_per_layer(
        pl, seq_len=4096, head_dim=128, batch=4
    )
    assert extra > 0
    assert placement.kv_collective_bytes_per_layer(
        aligned, seq_len=4096, head_dim=128, batch=4
    ) == 0.0


def test_placement_permutations_are_permutations():
    for strat in (placement.ACC_ALIGNED, placement.STRIPED):
        pl = placement.plan(32, 8, 4, strat)
        assert sorted(pl.q_perm) == list(range(32))
        assert sorted(pl.kv_perm) == list(range(8))
