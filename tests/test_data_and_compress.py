"""Data-pipeline determinism/sharding + gradient-compression tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep: requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DataConfig, MemmapLM, SyntheticLM, make_pipeline
from repro.optim import grad_compress as gc


# --- data ---------------------------------------------------------------------


def test_batches_deterministic():
    cfg = DataConfig(seq_len=32, global_batch=8, seed=1, vocab=100)
    p1, p2 = SyntheticLM(cfg), SyntheticLM(cfg)
    for step in (0, 3, 17):
        a, b = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(p1.batch_at(0)["tokens"], p1.batch_at(1)["tokens"])


def test_targets_are_shifted_tokens():
    cfg = DataConfig(seq_len=32, global_batch=4, vocab=100)
    b = SyntheticLM(cfg).batch_at(0)
    # inputs[t+1] == targets[t] by construction of the (S+1) window
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_sharding_partitions_batch():
    cfg = DataConfig(seq_len=16, global_batch=8, seed=2, vocab=50)
    shards = [SyntheticLM(cfg, shard=i, num_shards=4) for i in range(4)]
    batches = [s.batch_at(5)["tokens"] for s in shards]
    assert all(b.shape[0] == 2 for b in batches)
    # distinct shards produce distinct streams
    assert not np.array_equal(batches[0], batches[1])


def test_codebook_batches():
    cfg = DataConfig(seq_len=16, global_batch=2, vocab=50, num_codebooks=4)
    b = SyntheticLM(cfg).batch_at(0)
    assert b["tokens"].shape == (2, 16, 4)


def test_memmap_pipeline(tmp_path):
    data = np.arange(10_000, dtype=np.int32) % 777
    path = tmp_path / "tokens.bin"
    data.tofile(path)
    cfg = DataConfig(seq_len=64, global_batch=4, vocab=777, path=str(path))
    p = make_pipeline(cfg)
    b1, b2 = p.batch_at(0), p.batch_at(0)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


# --- gradient compression ------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(10, 5000),
    scale=st.floats(1e-4, 1e3),
    seed=st.integers(0, 100),
)
def test_quantize_error_bound(n, scale, seed):
    x = np.random.default_rng(seed).normal(size=(n,)).astype(np.float32) * scale
    c = gc.quantize(jnp.asarray(x))
    back = gc.dequantize(c, x.shape)
    blockmax = np.abs(x).max() if n <= gc.BLOCK else None
    err = np.abs(np.asarray(back) - x)
    # per-block error <= scale/2 = max/254 per block
    per_block = np.abs(x[: (n // gc.BLOCK) * gc.BLOCK or n]).max()
    assert err.max() <= np.abs(x).max() / 127.0 + 1e-6


def test_error_feedback_accumulates():
    grads = {"w": jnp.full((100,), 1e-6)}  # below quantization resolution
    ef = gc.init_error_feedback(grads)
    total = jnp.zeros((100,))
    for _ in range(400):
        deq, ef = gc.compress_with_feedback(grads, ef)
        total = total + deq["w"]
    # with EF, the long-run mean of delivered grads matches the true grad
    assert abs(float(jnp.mean(total)) / (400 * 1e-6) - 1.0) < 0.05


def test_compressed_psum_shard_map():
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.linspace(-3, 3, 4096, dtype=jnp.float32)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    f = shard_map(
        lambda v: gc.compressed_psum(v, "data"), mesh=mesh,
        in_specs=P(), out_specs=P(),
    )
    out = f(x)
    assert jnp.max(jnp.abs(out - x)) < float(jnp.max(jnp.abs(x))) / 126.0
