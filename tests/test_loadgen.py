"""Load-harness tests (``repro.launch.loadgen``, PR 7).

Workload construction is deterministic and pure, so it gets exact tests;
the end-to-end drive runs one small dense load and checks the artifact
contract (envelope JSON + Perfetto-loadable Chrome trace + SLO
percentiles + drift table) the CI smoke also enforces at full size.
"""

import argparse
import json

import jax
import numpy as np
import pytest

from repro.configs import registry
from repro.launch import loadgen
from repro.models import transformer


def _cfg():
    return registry.get_smoke_config("llama3-8b")


def test_build_workload_poisson_arrivals_sorted_and_seeded():
    cfg = _cfg()
    w1 = loadgen.build_workload(cfg, np.random.default_rng(7), 32, rate=10.0)
    w2 = loadgen.build_workload(cfg, np.random.default_rng(7), 32, rate=10.0)
    arrivals = [t for t, _ in w1]
    assert arrivals == sorted(arrivals)
    assert all(t > 0 for t in arrivals)
    # Same seed -> same trace (arrivals and prompts).
    assert arrivals == [t for t, _ in w2]
    for (_, a), (_, b) in zip(w1, w2):
        assert np.array_equal(a.prompt, b.prompt)
        assert a.sampling.max_tokens == b.sampling.max_tokens
    # Mean inter-arrival ~ 1/rate (loose: 32 samples).
    gaps = np.diff([0.0] + arrivals)
    assert 0.3 / 10.0 < gaps.mean() < 3.0 / 10.0


def test_build_workload_shared_prefix_population():
    cfg = _cfg()
    w = loadgen.build_workload(
        cfg, np.random.default_rng(0), 40, rate=10.0,
        shared_prefix_len=16, shared_fraction=0.5,
    )
    prompts = [r.prompt for _, r in w]
    heads = [tuple(np.asarray(p[:16])) for p in prompts if len(p) > 16]
    shared = max(heads.count(h) for h in set(heads))
    # ~half the population starts with the one system prefix.
    assert shared >= 10
    # And the mix produces several distinct prompt lengths.
    assert len({len(p) for p in prompts}) >= 3

    none = loadgen.build_workload(
        cfg, np.random.default_rng(0), 8, rate=10.0, shared_fraction=0.0,
    )
    lens = {len(r.prompt) for _, r in none}
    assert lens <= {v for v, _ in loadgen.PROMPT_MIX}


def test_percentiles():
    vals = [float(i) for i in range(1, 101)]
    p = loadgen.percentiles(vals)
    assert p["p50"] == pytest.approx(50.5)
    assert p["p90"] == pytest.approx(90.1)
    assert p["p99"] == pytest.approx(99.01)
    assert loadgen.percentiles([]) == {"p50": None, "p90": None, "p99": None}


def test_run_one_dense_end_to_end(tmp_path):
    """One small measured load: every request finishes, SLO percentiles
    and drift rows exist, and both artifacts land in --out-dir with the
    documented schemas."""
    args = argparse.Namespace(
        arch="llama3-8b", smoke=True, kv_layout="dense", requests=4,
        rate=200.0, max_batch=2, cache_len=128, num_pages=96, page_size=16,
        shared_prefix=16, shared_fraction=0.5, temperature=0.0, seed=0,
        out_dir=str(tmp_path),
    )
    payload = loadgen.run_one(args, "dense")
    loadgen._smoke_check(payload)

    assert payload["kv_layout"] == "dense"
    assert payload["finished"] == 4
    assert payload["ttft_s"]["p99"] >= payload["ttft_s"]["p50"] > 0
    assert payload["measured_tok_s"] > 0
    assert payload["prefix"]["prefix_hit_rate"] is None  # dense: n/a
    assert payload["drift"]["rows"]
    for row in payload["drift"]["rows"]:
        assert row["samples"] > 0 and row["measured_p50_s"] > 0

    env = json.load(open(tmp_path / "loadgen_dense.json"))
    assert env["schema"] == "repro.obs/v1"
    assert env["kind"] == "loadgen"
    assert env["metrics"]["serving_finished_total"]["value"] == 4.0
    trace = json.load(open(tmp_path / "loadgen_dense_trace.json"))
    tids = {e["tid"] for e in trace["traceEvents"] if e["ph"] == "b"}
    assert len(tids) == 4  # one async track per measured request


def test_warmup_resets_measurement():
    """Warmup pilots compile but never pollute measured telemetry: after
    reset, counters and drift restart from zero while the instruments
    stay bound."""
    from repro.obs import Telemetry
    from repro.serving import LLMEngine, Request, SamplingParams

    cfg = _cfg()
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    tel = Telemetry.create()
    eng = LLMEngine(cfg, params, kv_layout="dense", max_batch=2,
                    cache_len=128, prompt_buckets=(16, 32, 64),
                    telemetry=tel)
    rng = np.random.default_rng(0)
    workload = loadgen.build_workload(cfg, rng, 3, rate=1000.0)
    loadgen._warmup(eng, cfg, rng, workload)
    assert tel.metrics.snapshot()["serving_steps_total"]["value"] == 0.0
    assert tel.tracer.spans == []
    assert tel.drift.num_samples == 0
    assert eng.stats().tokens_generated == 0

    eng.generate([Request(uid=0, prompt=rng.integers(1, 400, size=(8,)),
                          sampling=SamplingParams(max_tokens=2))])
    assert tel.metrics.snapshot()["serving_steps_total"]["value"] > 0
    eng.close()
