"""Unit + property tests for the paper's mapping strategies (core/swizzle)."""

import numpy as np
import pytest
pytest.importorskip("hypothesis")  # dev-only dep: requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.core import swizzle
from repro.core.swizzle import AttentionGrid


def grid_8h_128b():
    return AttentionGrid(batch=1, num_q_heads=8, blocks_per_head=128)


# --- Paper figures 7-10: exact head->XCD assignments ------------------------


def test_fig7_naive_block_first():
    sets = swizzle.heads_per_domain_sets(swizzle.NAIVE_BLOCK_FIRST, grid_8h_128b(), 4)
    assert [sorted(s) for s in sets] == [[0, 4], [1, 5], [2, 6], [3, 7]]


def test_fig8_swizzled_block_first():
    sets = swizzle.heads_per_domain_sets(swizzle.SWIZZLED_BLOCK_FIRST, grid_8h_128b(), 4)
    assert [sorted(s) for s in sets] == [[0, 1], [2, 3], [4, 5], [6, 7]]


def test_fig9_naive_head_first():
    sets = swizzle.heads_per_domain_sets(swizzle.NAIVE_HEAD_FIRST, grid_8h_128b(), 4)
    assert all(sorted(s) == list(range(8)) for s in sets)


def test_fig10_swizzled_head_first():
    sets = swizzle.heads_per_domain_sets(swizzle.SWIZZLED_HEAD_FIRST, grid_8h_128b(), 4)
    assert [sorted(s) for s in sets] == [[0, 1], [2, 3], [4, 5], [6, 7]]


# --- Co-location property: swizzled head-first serves one ACC at a time -----


@pytest.mark.parametrize("h,g,d", [(128, 16, 8), (128, 1, 8), (32, 4, 8), (16, 2, 4)])
def test_swizzled_head_first_acc_colocation(h, g, d):
    grid = AttentionGrid(batch=1, num_q_heads=h, blocks_per_head=64, group_size=g)
    sets = swizzle.heads_per_domain_sets(swizzle.SWIZZLED_HEAD_FIRST, grid, d)
    # Each domain's q-heads form a contiguous range covering whole KV groups.
    for s in sets:
        lo, hi = min(s), max(s)
        assert sorted(s) == list(range(lo, hi + 1))
        if len(s) >= g:
            assert lo % g == 0 and (hi + 1) % g == 0
    # Disjoint cover of all heads.
    all_heads = sorted(x for s in sets for x in s)
    assert all_heads == list(range(h))


def test_concurrent_acc_counts_order():
    """The quantity driving L2 behaviour: distinct ACCs per dispatch window.

    swizzled_head_first must be minimal, block-first maximal (paper Fig 2)."""
    grid = AttentionGrid(batch=1, num_q_heads=64, blocks_per_head=128, group_size=1)
    w = 38
    counts = {
        m: swizzle.accs_per_domain_concurrent(m, grid, 8, w)
        for m in swizzle.ALL_MAPPINGS
    }
    assert counts[swizzle.SWIZZLED_HEAD_FIRST] <= 2.0
    # block-first interleaves all H/D of a domain's heads within one window:
    assert counts[swizzle.NAIVE_BLOCK_FIRST] >= min(w, 64 // 8) * 0.9
    assert counts[swizzle.SWIZZLED_BLOCK_FIRST] > counts[swizzle.SWIZZLED_HEAD_FIRST]
    # striped but head-coherent: a window spans ~w*D/blocks head boundaries
    assert counts[swizzle.NAIVE_HEAD_FIRST] <= 4.0


# --- Bijectivity (hypothesis): decode is a permutation of the grid ----------


@settings(max_examples=60, deadline=None)
@given(
    mapping=st.sampled_from(swizzle.ALL_MAPPINGS),
    batch=st.integers(1, 3),
    log_h=st.integers(0, 5),
    blocks=st.integers(1, 64),
    log_d=st.integers(0, 4),
    log_g=st.integers(0, 3),
)
def test_decode_is_bijective(mapping, batch, log_h, blocks, log_d, log_g):
    h = 2 ** log_h
    g = 2 ** min(log_g, log_h)
    d = 2 ** log_d
    if h % max(d, 1) and "swizzled" in mapping:
        # paper formulas assume H % D == 0; generalized fallback wraps, which
        # is surjective on heads but we only assert the aligned regime here.
        h = max(h, d)
    grid = AttentionGrid(batch=batch, num_q_heads=h, blocks_per_head=blocks,
                         group_size=g)
    wids = np.arange(grid.total_wgs)
    b, hh, m = swizzle.decode(mapping, wids, grid, d)
    cells = set(zip(b.tolist(), hh.tolist(), m.tolist()))
    assert len(cells) == grid.total_wgs
    assert all(0 <= x < h for x in hh)
    assert all(0 <= x < blocks for x in m)


@settings(max_examples=40, deadline=None)
@given(
    mapping=st.sampled_from(swizzle.ALL_MAPPINGS),
    log_h=st.integers(2, 5),
    blocks=st.sampled_from([16, 64, 128]),
    log_d=st.integers(0, 3),
)
def test_encode_inverts_decode(mapping, log_h, blocks, log_d):
    h, d = 2 ** log_h, 2 ** log_d
    # Paper formulas assume H % D == 0 (H >= D); the wrapped fallback for
    # H < D is surjective-on-heads but not bijective, so invertibility is
    # only asserted in the aligned regime.
    h = max(h, d)
    grid = AttentionGrid(batch=2, num_q_heads=h, blocks_per_head=blocks)
    wids = np.arange(grid.total_wgs)
    b, hh, m = swizzle.decode(mapping, wids, grid, d)
    back = swizzle.encode(mapping, b, hh, m, grid, d)
    np.testing.assert_array_equal(back, wids)
