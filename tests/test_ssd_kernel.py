"""Pallas SSD intra-chunk kernel sweeps vs the jnp oracle (interpret mode)."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ssd as ssd_kernel
from repro.models import ssm


def make_inputs(b, l, h, p, g, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    return x, dt, a, bm, cm


@pytest.mark.parametrize("b,l,h,p,g,n,chunk", [
    (1, 256, 2, 64, 1, 128, 128),   # mamba2-1.3b-like tile
    (2, 256, 4, 64, 2, 16, 128),    # hymba-like (small state)
    (1, 384, 2, 128, 2, 128, 128),  # wider head
    (1, 200, 2, 64, 1, 128, 128),   # padding path (l % chunk != 0)
])
def test_pallas_ssd_matches_jnp(b, l, h, p, g, n, chunk):
    x, dt, a, bm, cm = make_inputs(b, l, h, p, g, n)
    y_pl, h_pl = ssd_kernel.ssd_chunked_pallas(
        x, dt, a, bm, cm, chunk, interpret=True
    )
    y_jnp, h_jnp = ssm.ssd_chunked(x, dt, a, bm, cm, chunk)
    assert jnp.max(jnp.abs(y_pl - y_jnp)) < 1e-3
    assert jnp.max(jnp.abs(h_pl - h_jnp)) < 1e-3


def test_pallas_ssd_vs_recurrent_oracle():
    x, dt, a, bm, cm = make_inputs(1, 256, 2, 64, 1, 32, seed=3)
    y_pl, h_pl = ssd_kernel.ssd_chunked_pallas(
        x, dt, a, bm, cm, 128, interpret=True
    )
    y_ref, h_ref = ssm.ssd_recurrent_ref(x, dt, a, bm, cm)
    assert jnp.max(jnp.abs(y_pl - y_ref)) < 2e-3
    assert jnp.max(jnp.abs(h_pl - h_ref)) < 2e-3


def test_initial_state_handoff():
    x, dt, a, bm, cm = make_inputs(1, 256, 2, 64, 1, 32, seed=4)
    y_full, h_full = ssd_kernel.ssd_chunked_pallas(
        x, dt, a, bm, cm, 128, interpret=True
    )
    y1, h1 = ssd_kernel.ssd_chunked_pallas(
        x[:, :128], dt[:, :128], a, bm[:, :128], cm[:, :128], 128,
        interpret=True,
    )
    y2, h2 = ssd_kernel.ssd_chunked_pallas(
        x[:, 128:], dt[:, 128:], a, bm[:, 128:], cm[:, 128:], 128,
        h0=h1, interpret=True,
    )
    assert jnp.max(jnp.abs(jnp.concatenate([y1, y2], 1) - y_full)) < 1e-3
    assert jnp.max(jnp.abs(h2 - h_full)) < 1e-3
