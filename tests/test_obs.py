"""Tier-1 tests for the PR-7 telemetry subsystem (``repro.obs``).

Covers the acceptance bar from the issue: exact quantiles on known
distributions and bucket-boundary edges, merge associativity, Prometheus
rendering, the zero-alloc null path, span nesting, monotone request
lifecycles, Chrome trace JSON round-tripping, and the drift collector's
near-zero-model discipline.
"""

import json

import pytest

from repro.obs import NULL_TELEMETRY, Telemetry
from repro.obs.drift import DriftCollector, NullDriftCollector, context_bucket
from repro.obs.metrics import (
    NULL_COUNTER,
    NULL_GAUGE,
    NULL_HISTOGRAM,
    Counter,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    write_json_artifact,
)
from repro.obs.tracing import NULL_SPAN, NullTracer, Tracer


class FakeClock:
    """Deterministic monotone clock for tracer tests."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def tick(self, dt=1.0):
        self.t += dt
        return self.t


# -- histogram ----------------------------------------------------------------


def test_histogram_exact_quantiles_uniform():
    h = Histogram("h", boundaries=tuple(float(b) for b in range(1, 101)))
    for v in range(1, 101):  # uniform 1..100, one per bucket
        h.observe(float(v))
    # With one observation per unit bucket, quantiles are exact to within
    # one bucket width.
    assert h.quantile(0.5) == pytest.approx(50.0, abs=1.0)
    assert h.quantile(0.9) == pytest.approx(90.0, abs=1.0)
    assert h.quantile(0.99) == pytest.approx(99.0, abs=1.0)
    assert h.quantile(0.0) == pytest.approx(h.min)
    assert h.quantile(1.0) == pytest.approx(h.max)
    assert h.mean == pytest.approx(50.5)


def test_histogram_single_value_is_exact():
    h = Histogram("h")
    for _ in range(7):
        h.observe(0.42)
    # min == max clamps interpolation: every quantile is the value itself.
    for q in (0.0, 0.5, 0.9, 0.99, 1.0):
        assert h.quantile(q) == pytest.approx(0.42)


def test_histogram_bucket_boundary_edges():
    h = Histogram("h", boundaries=(1.0, 2.0, 5.0))
    h.observe(1.0)   # exactly on a boundary: le="1" bucket (v <= le)
    h.observe(2.0)
    h.observe(7.0)   # overflow
    snap = h.snapshot()
    assert snap["buckets"][repr(1.0)] == 1
    assert snap["buckets"][repr(2.0)] == 2
    assert snap["buckets"][repr(5.0)] == 2
    assert snap["buckets"]["+Inf"] == 3
    assert snap["min"] == 1.0 and snap["max"] == 7.0


def test_histogram_overflow_clamps_to_observed_max():
    h = Histogram("h", boundaries=(1.0,))
    h.observe(50.0)
    h.observe(100.0)
    assert h.quantile(1.0) == pytest.approx(100.0)
    assert 50.0 <= h.quantile(0.5) <= 100.0


def test_histogram_merge_matches_union_and_is_associative():
    bs = (0.01, 0.1, 1.0, 10.0)
    data = ([0.005, 0.05, 0.5], [5.0, 50.0, 0.02], [0.3, 0.09])

    def build(vals):
        h = Histogram("h", boundaries=bs)
        for v in vals:
            h.observe(v)
        return h

    union = build([v for vs in data for v in vs])
    a_bc = build(data[0]).merge(build(data[1]).merge(build(data[2])))
    ab_c = build(data[0]).merge(build(data[1])).merge(build(data[2]))
    for merged in (a_bc, ab_c):
        assert merged.counts == union.counts
        assert merged.count == union.count
        assert merged.sum == pytest.approx(union.sum)
        assert merged.min == union.min and merged.max == union.max


def test_histogram_merge_requires_matching_boundaries():
    with pytest.raises(ValueError, match="boundary mismatch"):
        Histogram("a", boundaries=(1.0,)).merge(
            Histogram("b", boundaries=(2.0,)))


def test_histogram_rejects_bad_inputs():
    with pytest.raises(ValueError):
        Histogram("h", boundaries=(2.0, 1.0))
    with pytest.raises(ValueError):
        Histogram("h").quantile(1.5)
    assert Histogram("h").quantile(0.5) == 0.0  # empty


# -- registry -----------------------------------------------------------------


def test_registry_idempotent_and_kind_checked():
    reg = MetricsRegistry()
    c1 = reg.counter("requests", "help text")
    c2 = reg.counter("requests")
    assert c1 is c2
    with pytest.raises(TypeError, match="already registered"):
        reg.gauge("requests")


def test_counter_monotone():
    c = Counter("c")
    c.inc()
    c.inc(2.5)
    assert c.value == pytest.approx(3.5)
    with pytest.raises(ValueError):
        c.inc(-1)


def test_registry_reset_preserves_instrument_identity():
    reg = MetricsRegistry()
    c = reg.counter("c")
    h = reg.histogram("h")
    c.inc(5)
    h.observe(0.5)
    reg.reset()
    assert c is reg.counter("c")
    assert c.value == 0.0
    assert h.count == 0
    c.inc()  # the pre-bound reference still records
    assert reg.snapshot()["c"]["value"] == 1.0


def test_prometheus_rendering():
    reg = MetricsRegistry()
    reg.counter("serving_steps_total", "engine ticks").inc(3)
    reg.gauge("serving_running").set(2)
    reg.histogram("step_seconds", boundaries=(0.1, 1.0)).observe(0.05)
    text = reg.render_prometheus()
    assert "# HELP serving_steps_total engine ticks" in text
    assert "# TYPE serving_steps_total counter" in text
    assert "serving_steps_total 3" in text
    assert "serving_running 2" in text
    assert 'step_seconds_bucket{le="0.1"} 1' in text
    assert 'step_seconds_bucket{le="+Inf"} 1' in text
    assert "step_seconds_count 1" in text


def test_null_registry_shares_singletons():
    reg = NullRegistry()
    assert reg.counter("a") is reg.counter("b") is NULL_COUNTER
    assert reg.gauge("a") is NULL_GAUGE
    assert reg.histogram("a") is NULL_HISTOGRAM
    NULL_COUNTER.inc()
    NULL_GAUGE.set(9)
    NULL_HISTOGRAM.observe(1.0)
    assert NULL_COUNTER.value == 0.0
    assert NULL_GAUGE.value == 0.0
    assert NULL_HISTOGRAM.count == 0
    assert reg.snapshot() == {}
    assert reg.render_prometheus() == ""
    assert not reg.enabled


def test_write_json_artifact_envelope(tmp_path):
    reg = MetricsRegistry()
    reg.counter("n").inc(2)
    path = write_json_artifact(
        "probe", {"k": "v"}, metrics=reg, dirpath=str(tmp_path), kind="test",
    )
    doc = json.loads(open(path).read())
    assert doc["schema"] == "repro.obs/v1"
    assert doc["name"] == "probe" and doc["kind"] == "test"
    assert doc["payload"] == {"k": "v"}
    assert doc["metrics"]["n"]["value"] == 2.0


# -- tracer -------------------------------------------------------------------


def test_spans_nest_positionally():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("step"):
        clk.tick()
        with tr.span("schedule"):
            clk.tick()
        with tr.span("decode", batch=3):
            clk.tick(2.0)
        clk.tick()
    by_name = {s.name: s for s in tr.spans}
    assert by_name["step"].depth == 0
    assert by_name["schedule"].depth == 1
    assert by_name["decode"].depth == 1
    assert by_name["decode"].args == {"batch": 3}
    # Children close before the parent and lie inside its interval.
    assert tr.spans[-1].name == "step"
    for child in ("schedule", "decode"):
        assert by_name["step"].t0 <= by_name[child].t0
        assert by_name[child].t1 <= by_name["step"].t1
    assert by_name["decode"].duration == pytest.approx(2.0)


def test_request_lifecycle_monotone_and_latencies():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    tr.request_event(7, "arrival")
    clk.tick(2.0)
    tr.request_event(7, "admitted")
    clk.tick(1.0)
    tr.request_event(7, "first_token")
    tr.request_event(7, "tokens", n=1)
    clk.tick(0.5)
    tr.request_event(7, "tokens", n=1)
    clk.tick(1.0)
    tr.request_event(7, "tokens", n=2)  # a 2-token tick amortizes
    tr.request_event(7, "finish", reason="length")
    events = tr.request_lifecycle(7)
    times = [t for _, t, _ in events]
    assert times == sorted(times), "lifecycle must be monotone"
    assert [e for e, _, _ in events][0] == "arrival"
    assert [e for e, _, _ in events][-1] == "finish"
    lat = tr.request_latencies()[7]
    assert lat["queue"] == pytest.approx(2.0)
    assert lat["ttft"] == pytest.approx(3.0)
    assert lat["e2e"] == pytest.approx(4.5)
    # itl: 0.5 then two amortized 0.5s from the 1.0s 2-token emission.
    assert lat["itl"] == pytest.approx([0.5, 0.5, 0.5])
    assert lat["preemptions"] == 0


def test_request_latencies_partial_lifecycle():
    tr = Tracer(clock=FakeClock())
    tr.request_event(1, "arrival")
    lat = tr.request_latencies()[1]
    assert lat["ttft"] is None and lat["e2e"] is None
    assert lat["itl"] == []


def test_chrome_trace_round_trips():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("step"):
        clk.tick()
        with tr.span("decode", batch=2):
            clk.tick()
    tr.request_event(0, "arrival")
    clk.tick()
    tr.request_event(0, "first_token")
    tr.request_event(0, "finish", reason="stop")
    doc = json.loads(json.dumps(tr.to_chrome_trace()))
    evs = doc["traceEvents"]
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "i", "b", "e"} <= phases
    xs = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"step", "decode"}
    for e in evs:
        if e["ph"] in ("X", "i", "b", "e"):
            assert e["ts"] >= 0  # all timestamps rebased to trace start
    b = next(e for e in evs if e["ph"] == "b")
    e_ = next(e for e in evs if e["ph"] == "e")
    assert b["id"] == e_["id"] == 0
    assert b["tid"] == e_["tid"] == 1  # request uid+1 track
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"}
    assert "repro.serving.LLMEngine" in names
    assert "request 0" in names


def test_chrome_trace_writes_file(tmp_path):
    tr = Tracer(clock=FakeClock())
    with tr.span("step"):
        pass
    path = tr.write_chrome_trace(str(tmp_path / "sub" / "trace.json"))
    doc = json.loads(open(path).read())
    assert doc["traceEvents"]


def test_tracer_reset_drops_records():
    clk = FakeClock()
    tr = Tracer(clock=clk)
    with tr.span("warmup"):
        clk.tick()
    tr.request_event(0, "arrival")
    tr.reset()
    assert tr.spans == [] and tr.requests == {} and tr.instants == []
    clk.tick()
    with tr.span("measured"):
        clk.tick()
    # Post-reset spans rebase on the reset time, not the construction time.
    assert tr.to_chrome_trace()["traceEvents"][-1]["ts"] >= 0


def test_null_tracer_shares_span():
    tr = NullTracer()
    assert tr.span("a") is tr.span("b") is NULL_SPAN
    with tr.span("a"):
        pass
    tr.request_event(1, "arrival")
    tr.instant("x")
    assert tr.spans == [] and tr.requests == {}


# -- drift --------------------------------------------------------------------


def test_context_bucket_powers_of_two():
    assert context_bucket(0) == 1
    assert context_bucket(1) == 1
    assert context_bucket(3) == 4
    assert context_bucket(4) == 4
    assert context_bucket(5.7) == 8
    assert context_bucket(1000) == 1024


def test_drift_report_ratio_and_cells():
    d = DriftCollector()
    for _ in range(10):
        d.record(batch=2, mean_len=30, seconds=1e-3)
    d.record(batch=4, mean_len=100, seconds=2e-3)
    assert d.num_samples == 11
    report = d.report(lambda batch, mean_len: 1e-4 * batch)
    rows = {(r["batch"], r["ctx_bucket"]): r for r in report.rows}
    assert set(rows) == {(2, 32), (4, 128)}
    r2 = rows[(2, 32)]
    assert r2["samples"] == 10
    assert r2["measured_p50_s"] == pytest.approx(1e-3)
    assert r2["ratio"] == pytest.approx(5.0)
    assert report.worst_ratio() == pytest.approx(rows[(4, 128)]["ratio"])
    assert "Drift" in report.render()


def test_drift_near_zero_model_reports_none_not_inf():
    d = DriftCollector()
    d.record(batch=1, mean_len=8, seconds=1e-3)
    report = d.report(lambda batch, mean_len: 0.0)
    assert report.rows[0]["ratio"] is None
    assert report.worst_ratio() is None
    assert "n/a" in report.render()


def test_drift_reset_and_null():
    d = DriftCollector()
    d.record(1, 8, 1e-3)
    d.reset()
    assert d.num_samples == 0
    assert d.report(lambda b, m: 1.0).rows == []
    n = NullDriftCollector()
    n.record(1, 8, 1e-3)
    assert n.num_samples == 0
    assert not n.enabled
    assert "no decode samples" in n.report(lambda b, m: 1.0).render()


# -- the bundle ---------------------------------------------------------------


def test_telemetry_bundle_and_null():
    tel = Telemetry.create()
    assert tel.enabled
    tel.metrics.counter("c").inc()
    with tel.tracer.span("s"):
        pass
    tel.drift.record(1, 8, 1e-3)
    tel.reset()
    assert tel.metrics.snapshot()["c"]["value"] == 0.0
    assert tel.tracer.spans == []
    assert tel.drift.num_samples == 0

    assert Telemetry.disabled() is NULL_TELEMETRY
    assert not NULL_TELEMETRY.enabled
    assert NULL_TELEMETRY.metrics.counter("x") is NULL_COUNTER
    assert NULL_TELEMETRY.tracer.span("x") is NULL_SPAN
    NULL_TELEMETRY.reset()  # no-op, must not raise
