"""Mamba-2 SSD kernel tests: chunked vs exact recurrence (hypothesis sweeps)."""

import jax
import jax.numpy as jnp
import pytest
pytest.importorskip("hypothesis")  # dev-only dep: requirements-dev.txt
from hypothesis import given, settings, strategies as st

from repro.configs.base import SSMConfig
from repro.models import ssm


def make_inputs(b, l, h, p, g, n, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    return x, dt, a, bm, cm


@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    l=st.sampled_from([17, 32, 96, 128]),
    h=st.sampled_from([2, 4]),
    p=st.sampled_from([8, 16]),
    g_div=st.sampled_from([1, 2]),
    n=st.sampled_from([4, 16]),
    chunk=st.sampled_from([16, 32, 64]),
)
def test_chunked_matches_recurrent(b, l, h, p, g_div, n, chunk):
    g = h // g_div
    x, dt, a, bm, cm = make_inputs(b, l, h, p, g, n)
    y1, h1 = ssm.ssd_chunked(x, dt, a, bm, cm, chunk)
    y2, h2 = ssm.ssd_recurrent_ref(x, dt, a, bm, cm)
    assert jnp.max(jnp.abs(y1 - y2)) < 1e-3
    assert jnp.max(jnp.abs(h1 - h2)) < 1e-3


def test_initial_state_threading():
    x, dt, a, bm, cm = make_inputs(1, 64, 2, 8, 2, 8, seed=1)
    # Split the sequence: running two halves with state handoff == full run.
    y_full, h_full = ssm.ssd_chunked(x, dt, a, bm, cm, 16)
    y1, h1 = ssm.ssd_chunked(x[:, :32], dt[:, :32], a, bm[:, :32], cm[:, :32], 16)
    y2, h2 = ssm.ssd_chunked(x[:, 32:], dt[:, 32:], a, bm[:, 32:], cm[:, 32:], 16, h0=h1)
    assert jnp.max(jnp.abs(jnp.concatenate([y1, y2], axis=1) - y_full)) < 1e-3
    assert jnp.max(jnp.abs(h2 - h_full)) < 1e-3


def test_block_decode_equals_full():
    cfg = SSMConfig(state_dim=16, head_dim=8, expand=2, conv_width=4,
                    chunk=16, num_groups=1)
    d_model = 32
    params = ssm.init_mamba(jax.random.PRNGKey(7), d_model, cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (2, 24, d_model))
    y_full = ssm.mamba_block(params, x, d_model, cfg)
    cache = ssm.init_mamba_cache(d_model, cfg, 2, x.dtype)
    outs = []
    for t in range(24):
        o, cache = ssm.mamba_decode(params, x[:, t : t + 1], d_model, cfg, cache)
        outs.append(o)
    y_dec = jnp.concatenate(outs, axis=1)
    assert jnp.max(jnp.abs(y_full - y_dec)) < 5e-5


def test_decay_bounds():
    """State decay factors must be in (0, 1]: A < 0 and dt > 0."""
    x, dt, a, bm, cm = make_inputs(1, 32, 2, 8, 2, 8, seed=2)
    assert bool(jnp.all(a < 0))
    dec = jnp.exp(dt * a[None, None, :])
    assert bool(jnp.all(dec > 0)) and bool(jnp.all(dec <= 1.0))
