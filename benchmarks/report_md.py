"""Render EXPERIMENTS.md sections from the dry-run/hillclimb artifacts.

Usage: PYTHONPATH=src:. python -m benchmarks.report_md
Replaces the RESULTS_*_PLACEHOLDER markers in EXPERIMENTS.md in place.
"""

from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "artifacts", "dryrun")
HILL = os.path.join(ROOT, "artifacts", "hillclimb")


def _load(directory, pattern):
    out = []
    for p in sorted(glob.glob(os.path.join(directory, pattern))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def _md_table(headers, rows):
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join(["---"] * len(headers)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(x) for x in r) + " |")
    return "\n".join(out)


def dryrun_section() -> str:
    recs = _load(DRY, "*.json")
    n_ok = sum(r["ok"] for r in recs)
    singles = [r for r in recs if r["mesh"] == "single" and r["ok"]]
    multis = [r for r in recs if r["mesh"] == "multi" and r["ok"]]
    rows = []
    for r in singles:
        m = next((x for x in multis if x["arch"] == r["arch"]
                  and x["shape"] == r["shape"]), None)
        rows.append([
            r["arch"], r["shape"], r["step"],
            f"{r['memory']['argument_bytes']/2**30:.2f}",
            f"{r['memory']['peak_bytes']/2**30:.2f}",
            f"{m['memory']['peak_bytes']/2**30:.2f}" if m else "—",
            f"{r['collectives_raw']['total']/2**30:.2f}",
            f"{r['compile_s']:.0f}s",
        ])
    table = _md_table(
        ["arch", "shape", "step", "args GiB/dev", "peak GiB/dev (1-pod)",
         "peak GiB/dev (2-pod)", "coll GiB/dev (raw)", "compile"],
        rows,
    )
    return (
        f"**{n_ok}/{len(recs)} cells compile** (35 cells × single-pod 16×16 "
        f"and multi-pod 2×16×16 meshes; `.lower().compile()` green for every "
        f"assigned architecture × input shape — the multi-pod pass proves the "
        f"pod axis shards).\n\n" + table
    )


def roofline_section() -> str:
    recs = [r for r in _load(DRY, "*__single.json") if r["ok"]]
    rows = []
    for r in recs:
        t = r["roofline"]
        bound = max(t["compute_s"], t["memory_s"], t["collective_s"])
        frac = t["compute_s"] / bound if bound else 0.0
        rows.append([
            r["arch"], r["shape"],
            f"{t['compute_s']:.3f}", f"{t['memory_s']:.3f}",
            f"{t['collective_s']:.3f}", t["dominant"],
            f"{t['useful_flops_ratio']:.2f}", f"{frac:.2f}",
        ])
    return _md_table(
        ["arch", "shape", "compute s", "memory s", "collective s",
         "dominant", "MODEL/HLO flops", "roofline frac"],
        rows,
    )


def hillclimb_section() -> str:
    recs = _load(HILL, "*.json")
    groups = {}
    for r in recs:
        key = (r["arch"], r["shape"])
        groups.setdefault(key, []).append(r)
    parts = []
    for (arch, shape), rs in groups.items():
        rows = []
        for r in rs:
            tag = "+".join(f"{k}={v}" for k, v in r.get("overrides", {}).items()) or "baseline"
            if not r["ok"]:
                rows.append([tag, "FAILED", "", "", "", ""])
                continue
            t = r["roofline"]
            rows.append([
                tag,
                f"{t['compute_s']:.2f}", f"{t['memory_s']:.2f}",
                f"{t['collective_s']:.2f}", t["dominant"],
                f"{r['memory']['peak_bytes']/2**30:.1f}",
            ])
        parts.append(f"#### {arch} × {shape}\n\n" + _md_table(
            ["variant", "compute s", "memory s", "collective s", "dominant",
             "peak GiB"], rows))
    return "\n\n".join(parts)


def main():
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    with open(path) as f:
        text = f.read()
    text = text.replace("RESULTS_DRYRUN_PLACEHOLDER", dryrun_section())
    text = text.replace("RESULTS_ROOFLINE_PLACEHOLDER", roofline_section())
    if "RESULTS_PERF_TABLES" in text and _load(HILL, "*.json"):
        text = text.replace("RESULTS_PERF_TABLES", hillclimb_section())
    with open(path, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
