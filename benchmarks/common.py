"""Shared benchmark plumbing: table rendering + artifact persistence.

Persistence delegates to ``repro.obs.metrics.write_json_artifact`` (PR 7)
so every benchmark and the load harness emit the same envelope:
``{"schema": "repro.obs/v1", "name", "kind", "created_unix", "payload",
"metrics"}``.
"""

from __future__ import annotations

import os
from typing import Dict, List, Optional, Sequence

from repro.obs.metrics import MetricsRegistry, write_json_artifact

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "benchmarks")


def save_result(name: str, payload,
                metrics: Optional[MetricsRegistry] = None) -> str:
    """Write ``artifacts/benchmarks/<name>.json`` in the uniform obs
    envelope; pass a registry to ship its snapshot alongside."""
    return write_json_artifact(
        name, payload, metrics=metrics, dirpath=ARTIFACTS, kind="benchmark",
    )


def render_table(title: str, rows: List[Dict], columns: Sequence[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in columns}
    header = "  ".join(c.ljust(widths[c]) for c in columns)
    lines = [title, "-" * len(header), header, "-" * len(header)]
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in columns))
    return "\n".join(lines)


def fmt(x, nd=3):
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return x
