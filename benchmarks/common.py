"""Shared benchmark plumbing: table rendering + artifact persistence."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Sequence

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "benchmarks")


def save_result(name: str, payload) -> str:
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.abspath(os.path.join(ARTIFACTS, f"{name}.json"))
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def render_table(title: str, rows: List[Dict], columns: Sequence[str]) -> str:
    widths = {c: max(len(c), *(len(str(r.get(c, ""))) for r in rows)) for c in columns}
    header = "  ".join(c.ljust(widths[c]) for c in columns)
    lines = [title, "-" * len(header), header, "-" * len(header)]
    for r in rows:
        lines.append("  ".join(str(r.get(c, "")).ljust(widths[c]) for c in columns))
    return "\n".join(lines)


def fmt(x, nd=3):
    if isinstance(x, float):
        return f"{x:.{nd}f}"
    return x
