"""Paged serving benchmark: prefix sharing + NUMA page placement A/B.

Drives ``LLMEngine(kv_layout="paged")`` (smoke model, CPU-runnable) over a
mixed-length request trace with a shared system prompt, then scores the *final*
page tables under both placement policies with the three model layers:

  * ``cache.layout.decode_page_traffic``  — exact enumerated traffic,
  * ``core.cache_sim.simulate_paged_decode`` — event-driven LRU replay,
  * ``core.perf_model.estimate_paged_decode`` / ``estimate_dense_decode``
    — the O(1) analytic forms ``kernels.ops.resolve_kv_layout`` ranks with.

Reports prefix-cache hit rate (acceptance: > 0 on this trace) and modeled
HBM/fabric traffic for head-aligned vs interleaved placement, plus the
dense-stripe baseline the paged pool replaces, and the modeled
paged-vs-gather cost of the extend-phase prefill the PR-3 kernel replaces.

Run: PYTHONPATH=src python -m benchmarks.paged_serving
  --smoke: CI mode — a short trace that must route prefix-extension
  prefill through the paged Pallas prefill kernel (interpret mode on CPU
  runners; asserts the non-fallback path was taken), skipping the full
  placement sweep.
Artifacts: artifacts/benchmarks/paged_serving.json
"""

from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks import common
from repro.cache import layout
from repro.configs import registry
from repro.core import cache_sim, numa, perf_model
from repro.kernels import ops as kernel_ops
from repro.models import transformer
from repro.serving import LLMEngine, Request

PAGE_SIZE = 16
NUM_PAGES = 160
TOPOS = {"mi300x": numa.MI300X, "tpu_v5p_megacore": numa.TPU_V5P_MEGACORE}


def build_trace(cfg, rng, n_requests=12, system_len=48):
    """Mixed-length trace: most requests share a system prompt."""
    system = rng.integers(1, cfg.vocab, size=(system_len,))
    reqs = []
    for i in range(n_requests):
        tail_len = int(rng.integers(2, 40))
        tail = rng.integers(1, cfg.vocab, size=(tail_len,))
        prompt = np.concatenate([system, tail]) if i % 4 else tail
        reqs.append(Request(uid=i, prompt=prompt,
                            max_new_tokens=int(rng.integers(3, 10))))
    return reqs


def capture_peak_tables(engine):
    """Snapshot live page tables at the engine's fullest decode tick."""
    peak = {"pages": -1, "tables": [], "lengths": []}
    backend = engine.backend
    orig_step = engine.step

    def step():
        live = [
            (list(backend.seqs[r].pages.pages), int(backend.lengths[r]) + 1)
            for r in range(backend.rows)
            if backend.active[r] and backend.seqs[r] is not None
        ]
        total = sum(-(-ln // backend.page_size) for _, ln in live)
        if total > peak["pages"]:
            peak.update(pages=total, tables=[t for t, _ in live],
                        lengths=[ln for _, ln in live])
        return orig_step()

    engine.step = step
    return peak


def smoke():
    """CI smoke: drive the paged engine over a prefix-sharing trace and
    assert the extend phase ran through the paged Pallas prefill kernel
    (plan impl == "pallas"; interpret mode on CPU) — the non-fallback
    route — with outputs completing for every request; then exercise a
    plan-chosen ``num_splits > 1`` split-K decode (interpret mode) and
    check it against the oracle."""
    import jax.numpy as jnp

    from repro.kernels import plan as plan_lib
    from repro.kernels import ref

    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    engine = LLMEngine(
        cfg, params, kv_layout="paged", num_pages=96, page_size=PAGE_SIZE,
        max_batch=4, max_pages_per_seq=8, prompt_buckets=(16, 32, 64),
    )
    reqs = build_trace(cfg, rng, n_requests=6, system_len=32)
    results = engine.generate(reqs)
    stats = engine.backend.prefix_stats()
    assert len(results) == len(reqs), (len(results), len(reqs))
    assert stats["prefix_hit_rate"] > 0, "trace must exercise prefix sharing"
    assert stats["extend_prefills"] > 0, \
        "no request took the paged prefill kernel path"
    # The engine's extend plans must all be the kernel (no gather fallback).
    extend_keys = [k for k in engine.backend._prefill_p if k[1] > 0]
    assert extend_keys, "no extend-phase compilation recorded"
    for bucket, pages, rows in extend_keys:
        plan = plan_lib.plan_for_config(
            cfg,
            (rows, cfg.n_heads, cfg.n_kv_heads, bucket,
             pages * engine.backend.page_size + bucket, cfg.head_dim),
            phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
            page_size=engine.backend.page_size, prefix_pages=pages,
        )
        assert plan.impl == "pallas", plan
    new_tokens = sum(len(r.tokens) for r in results)
    print(
        f"[smoke] {len(results)} requests, {new_tokens} new tokens, "
        f"prefix hit rate {stats['prefix_hit_rate']:.2f}, "
        f"{int(stats['extend_prefills'])} extend prefills via "
        f"paged_flash_prefill (interpret={plan.interpret}), "
        f"{int(stats['batched_prefills'])} batched launches, "
        f"jit keys {sorted(engine.backend._prefill_p)}"
    )
    print(f"[smoke] {engine.stats().summary()}")

    # Split-K decode (PR 4): a long-context B x Hkv = 1 shape must resolve
    # to num_splits > 1 on the scoring topology, and the split kernel must
    # run (interpret mode on CPU runners) to oracle parity.
    b, hq, hkv, smax, hd = 1, 4, 1, 32768, 64
    splan = plan_lib.plan_attention(
        (b, hq, hkv, 1, smax, hd), phase=plan_lib.DECODE, backend="cpu",
        dtype_bytes=4, impl="pallas",
    )
    assert splan.num_splits > 1, splan
    assert splan.interpret, "CI smoke must exercise interpret mode"
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, hq, hd), jnp.float32)
    kc = jax.random.normal(ks[1], (b, hkv, smax, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (b, hkv, smax, hd), jnp.float32)
    lengths = jnp.asarray([smax - 3], jnp.int32)
    o = kernel_ops.decode_attention(q, kc, vc, lengths, plan=splan)
    o_ref = ref.decode_attention(q, kc, vc, lengths)
    err = float(jnp.max(jnp.abs(o - o_ref)))
    assert err < 2e-5, err
    print(
        f"[smoke] split-K decode: plan chose num_splits={splan.num_splits} "
        f"(chunk={splan.chunk}) for B*Hkv={b * hkv} at {smax} tokens; "
        f"kernel parity {err:.2e}"
    )

    # PR 6: the same long-context regime through the *paged* split kernel,
    # with the domain-purity access tracer auditing what the exported
    # BlockSpec index maps touch (repro.analysis.access_trace) — the
    # co-location claim fails CI here instead of silently invalidating the
    # modeled speedups. The page table is a random permutation of the
    # physical pool, so locality must come from the head-major layout, not
    # from accidentally-ordered page ids.
    from repro.analysis import access_trace
    from repro.kernels.paged_decode_attention import paged_flash_decode

    ps = 32
    pplan = plan_lib.plan_attention(
        (b, hq, hkv, 1, smax, hd), phase=plan_lib.DECODE,
        kv_layout=plan_lib.PAGED, page_size=ps, backend="cpu",
        dtype_bytes=4, impl="pallas",
    )
    assert pplan.num_splits > 1, pplan
    assert pplan.interpret, "CI smoke must exercise interpret mode"
    mp = smax // ps
    rng2 = np.random.default_rng(2)
    pt = rng2.permutation(np.arange(1, mp + 1)).reshape(1, mp).astype(np.int32)
    trace = access_trace.trace_plan(
        pplan, pt, [smax - 5], num_kv_heads=hkv, num_domains=2,
    ).assert_domain_local()
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q2 = jax.random.normal(ks[0], (b, hq, hd), jnp.float32)
    kp = jax.random.normal(ks[1], (hkv, mp + 1, ps, hd), jnp.float32)
    vp = jax.random.normal(ks[2], (hkv, mp + 1, ps, hd), jnp.float32)
    lengths2 = jnp.asarray([smax - 5], jnp.int32)
    o2 = paged_flash_decode(q2, kp, vp, jnp.asarray(pt), lengths2,
                            num_splits=pplan.num_splits, interpret=True)
    o2_ref = ref.paged_decode_attention(q2, kp, vp, jnp.asarray(pt), lengths2)
    err2 = float(jnp.max(jnp.abs(o2 - o2_ref)))
    assert err2 < 2e-5, err2
    print(
        f"[smoke] paged split-K: num_splits={pplan.num_splits} over {mp} "
        f"pages; access trace domain-local across {len(trace.cells)} grid "
        f"cells / {trace.live_pages} live page fetches; kernel parity "
        f"{err2:.2e}"
    )
    print("[smoke] OK")


def main():
    from repro.obs import Telemetry

    cfg = registry.get_smoke_config("llama3-8b")
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    telemetry = Telemetry.create()
    engine = LLMEngine(
        cfg, params, kv_layout="paged", num_pages=NUM_PAGES,
        page_size=PAGE_SIZE, max_batch=6, max_pages_per_seq=8,
        prompt_buckets=(16, 32, 64, 96),
        telemetry=telemetry,
    )
    reqs = build_trace(cfg, rng)
    peak = capture_peak_tables(engine)
    results = engine.generate(reqs)
    stats = engine.backend.prefix_stats()
    assert len(results) == len(reqs)
    assert stats["prefix_hit_rate"] > 0, "trace must exercise prefix sharing"

    # The paper-scale attention geometry for the traffic models (the smoke
    # model's tiny heads would make domain counts degenerate).
    hkv, hd = 8, 128
    rows = []
    payload = {
        "page_size": PAGE_SIZE,
        "num_pages": NUM_PAGES,
        "requests": len(reqs),
        "new_tokens": sum(len(r.tokens) for r in results),
        "prefix": stats,
        "peak_tick": {"tables": peak["tables"], "lengths": peak["lengths"]},
        "model_geometry": {"num_kv_heads": hkv, "head_dim": hd},
        "placement": {},
    }
    for tname, topo in TOPOS.items():
        entry = {}
        for policy in layout.PAGE_POLICIES:
            traffic = layout.decode_page_traffic(
                peak["tables"], peak["lengths"], num_kv_heads=hkv,
                page_size=PAGE_SIZE, head_dim=hd, topo=topo, policy=policy)
            sim = cache_sim.simulate_paged_decode(
                peak["tables"], peak["lengths"], num_kv_heads=hkv,
                page_size=PAGE_SIZE, head_dim=hd, topo=topo, policy=policy)
            entry[policy] = {
                "total_bytes": traffic.total_bytes,
                "unique_bytes": traffic.unique_bytes,
                "local_fraction": traffic.local_fraction,
                "reuse_rate": traffic.reuse_rate,
                "sim_hit_rate": sim.hit_rate,
                "sim_hbm_bytes": sim.hbm_bytes,
                "sim_remote_bytes": sim.remote_bytes,
                "time_model_s": traffic.time(topo),
                "sim_elapsed_s": sim.elapsed,
            }
            rows.append({
                "topo": tname, "policy": policy,
                "local%": f"{100*traffic.local_fraction:.0f}",
                "reuse%": f"{100*traffic.reuse_rate:.0f}",
                "HBM MiB": f"{traffic.unique_bytes/2**20:.2f}",
                "remote MiB": f"{sim.remote_bytes/2**20:.2f}",
                "t_model us": f"{1e6*traffic.time(topo):.2f}",
            })
        # dense-stripe baseline + analytic layout ranking
        batch = len(peak["tables"])
        mean_len = int(np.mean(peak["lengths"])) if peak["lengths"] else 1
        capacity = engine.backend.cache_len
        dense = perf_model.estimate_dense_decode(
            batch=batch, num_q_heads=4 * hkv, num_kv_heads=hkv,
            capacity=capacity, head_dim=hd, dtype_bytes=2, topo=topo)
        entry["dense_baseline"] = {
            "capacity": capacity,
            "hbm_bytes": dense.hbm_bytes,
            "time_s": dense.time,
        }
        entry["resolved_layout"] = kernel_ops.resolve_kv_layout(
            (batch, 4 * hkv, hkv, mean_len, hd), capacity=capacity,
            page_size=PAGE_SIZE, backend="tpu" if "tpu" in tname else "gpu")
        payload["placement"][tname] = entry

    # Extend-phase prefill: modeled cost of the PR-3 paged prefill kernel
    # vs the gather-to-dense route it replaces, at this trace's mean
    # prefix/tail split.
    mean_prefix = int(
        PAGE_SIZE * stats["pages_reused"] / max(stats["extend_prefills"], 1)
    )
    extend_kw = dict(
        batch=1, num_q_heads=4 * hkv, num_kv_heads=hkv,
        prefix_len=max(mean_prefix, PAGE_SIZE), tail_len=32,
        page_size=PAGE_SIZE, head_dim=hd, dtype_bytes=2,
        topo=numa.MI300X,
    )
    paged_est = perf_model.estimate_extend_prefill(**extend_kw)
    gather_est = perf_model.estimate_extend_prefill(gather=True, **extend_kw)
    payload["extend_prefill"] = {
        "mean_prefix_len": extend_kw["prefix_len"],
        "paged_kernel_time_s": paged_est.time,
        "gather_dense_time_s": gather_est.time,
        "paged_vs_gather_ratio": gather_est.time / paged_est.time,
        "extend_prefills": stats["extend_prefills"],
        "resumed_tokens": stats["resumed_tokens"],
    }

    # Split-K decode (PR 4): plan-resolved num_splits and the modeled
    # decode-throughput win at long-context, small-batch shapes — the
    # occupancy regime (B*Hkv < num_domains) the split axis exists for.
    from repro.kernels import plan as plan_lib

    split_rows = []
    payload["split_k"] = {}
    long_ctx = 32768
    for b, hq_, hkv_ in [(1, 8, 1), (1, 32, 4), (1, 32, 8), (8, 32, 8)]:
        plan = plan_lib.plan_attention(
            (b, hq_, hkv_, 1, long_ctx, hd), phase=plan_lib.DECODE,
            backend="gpu", dtype_bytes=2,
        )
        est = perf_model.estimate_decode_splits(
            batch=b, num_q_heads=hq_, num_kv_heads=hkv_, seq_kv=long_ctx,
            granule=plan.chunk or 512, head_dim=hd, dtype_bytes=2,
            topo=numa.MI300X,
        )
        assert plan.num_splits == est.num_splits  # the plan IS the model
        payload["split_k"][f"b{b}_hq{hq_}_hkv{hkv_}"] = {
            "cells": b * hkv_,
            "num_domains": numa.MI300X.num_domains,
            "num_splits": plan.num_splits,
            "chunk": plan.chunk,
            "modeled_speedup": est.speedup,
            # Aggregate: one tick decodes one token per sequence.
            "tokens_per_s_one_pass": b / est.base_time,
            "tokens_per_s_split": b / est.time,
            "sweep": {str(s): t for s, t in est.times},
        }
        split_rows.append({
            "B": b, "Hq": hq_, "Hkv": hkv_,
            "cells": b * hkv_,
            "splits": plan.num_splits,
            "speedup": f"{est.speedup:.2f}x",
            "t_1 us": f"{1e6 * est.base_time:.1f}",
            "t_split us": f"{1e6 * est.time:.1f}",
        })
    lonely = payload["split_k"]["b1_hq8_hkv1"]
    assert lonely["num_splits"] > 1 and lonely["modeled_speedup"] > 1.0, \
        "B*Hkv < num_domains long-context decode must split"

    aligned = payload["placement"]["mi300x"][layout.HEAD_ALIGNED]
    naive = payload["placement"]["mi300x"][layout.INTERLEAVED]
    engine_stats = engine.stats()
    payload["measured"] = {
        "tokens_generated": engine_stats.tokens_generated,
        "measured_tok_s": engine_stats.measured_tok_s,
        "modeled_tok_s": engine_stats.modeled_tok_s,
        "decode_elapsed_s": engine_stats.decode_elapsed_s,
    }
    payload["headline"] = {
        "prefix_hit_rate": stats["prefix_hit_rate"],
        "aligned_vs_naive_time_ratio":
            naive["time_model_s"] / aligned["time_model_s"],
        "extend_paged_vs_gather_ratio":
            payload["extend_prefill"]["paged_vs_gather_ratio"],
        "split_k_speedup_b1_hkv1": lonely["modeled_speedup"],
        "split_k_num_splits_b1_hkv1": lonely["num_splits"],
    }

    print(common.render_table(
        "Paged decode tick: NUMA-aligned vs naive page placement",
        rows, ("topo", "policy", "local%", "reuse%", "HBM MiB",
               "remote MiB", "t_model us")))
    print(common.render_table(
        f"Split-K decode (mi300x, {long_ctx}-token context, plan-chosen "
        "splits)",
        split_rows, ("B", "Hq", "Hkv", "cells", "splits", "speedup",
                     "t_1 us", "t_split us")))
    print(f"\nprefix-cache hit rate: {stats['prefix_hit_rate']:.2f} "
          f"({int(stats['pages_reused'])}/{int(stats['prompt_pages'])} prompt pages)")
    print(f"aligned vs naive modeled speedup (mi300x): "
          f"{payload['headline']['aligned_vs_naive_time_ratio']:.2f}x")
    print(f"extend prefill, paged kernel vs gather+dense (modeled): "
          f"{payload['headline']['extend_paged_vs_gather_ratio']:.2f}x")
    print(f"split-K decode speedup at B*Hkv=1, {long_ctx} ctx (modeled): "
          f"{payload['headline']['split_k_speedup_b1_hkv1']:.2f}x "
          f"(num_splits={payload['headline']['split_k_num_splits_b1_hkv1']})")
    for tname in TOPOS:
        print(f"resolve_kv_layout[{tname}]: "
              f"{payload['placement'][tname]['resolved_layout']}")
    # The telemetry snapshot (step/flush/decode histograms, lifecycle
    # counters) rides in the artifact's "metrics" envelope slot.
    path = common.save_result("paged_serving", payload,
                              metrics=telemetry.metrics)
    print(f"\nsaved {path}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI: short trace, assert the paged prefill kernel "
                         "path, skip the placement sweep")
    args = ap.parse_args()
    smoke() if args.smoke else main()
