"""TPU-side mapping benchmark: HBM traffic per mapping (dry-run analogue of
the paper's L2 hit rates) + mesh-level KV-duplication from head placement.

On TPU there is no L2 counter to read: the analogue quantity is how many
HBM->VMEM block copies the Pallas pipeline performs, which is *fully
determined* by grid order + index maps (kernels.flash_attention.
hbm_block_fetches), plus — at pod level — how many chips must hold each KV
head under a placement (core.placement)."""

from __future__ import annotations

from repro.core import placement
from repro.kernels import plan as plan_lib
from repro.kernels.flash_attention import (
    BLOCK_FIRST, HEAD_FIRST, MappingConfig, hbm_block_fetches,
)

from benchmarks.common import fmt, render_table, save_result

MAPPINGS = {
    "swizzled_head_first": MappingConfig(order=HEAD_FIRST, kv_resident=True),
    "naive_head_first": MappingConfig(order=HEAD_FIRST, kv_resident=False),
    "swizzled_block_first": MappingConfig(order=BLOCK_FIRST, kv_resident=True),
    "naive_block_first": MappingConfig(order=BLOCK_FIRST, kv_resident=False),
}

CONFIGS = [
    # name, hq, hkv, seq, d
    ("llama3-8b", 32, 8, 8192, 128),
    ("llama3-405b", 128, 8, 8192, 128),
    ("llama3-405b-32k", 128, 8, 32768, 128),
    ("gemma2-2b", 8, 4, 8192, 256),
    ("musicgen-medium(MHA)", 24, 24, 8192, 64),
]


def kernel_reuse_table():
    rows = []
    for name, hq, hkv, seq, d in CONFIGS:
        row = {"config": name}
        for mname, mc in MAPPINGS.items():
            r = hbm_block_fetches(
                batch=1, num_q_heads=hq, num_kv_heads=hkv,
                seq_q=seq, seq_kv=seq, head_dim=d, mapping=mc,
            )
            row[mname] = fmt(r["reuse_efficiency"] * 100, 1)
        rows.append(row)
    print(render_table(
        "TPU kernel HBM reuse efficiency (%, 100 = each ACC fetched once)",
        rows, ["config"] + list(MAPPINGS),
    ))
    save_result("tpu_kernel_reuse", rows)
    return rows


def resolver_table(batch: int = 8):
    """What the plan layer (``kernels.plan.plan_attention``) auto-selects
    per model config and phase — the schedule every workload now gets by
    default (mapping policy "auto"), side by side with the prefill plan's
    predicted reuse efficiency."""
    rows = []
    for name, hq, hkv, seq, d in CONFIGS:
        p = plan_lib.plan_attention((batch, hq, hkv, seq, seq, d))
        dec = plan_lib.plan_attention(
            (batch, hq, hkv, 1, seq, d), phase=plan_lib.DECODE
        )
        mc = p.mapping
        eff = hbm_block_fetches(
            batch=batch, num_q_heads=hq, num_kv_heads=hkv,
            seq_q=seq, seq_kv=seq, head_dim=d, mapping=mc,
        )["reuse_efficiency"]
        rows.append({
            "config": name,
            "order": mc.order,
            "kv_resident": str(mc.kv_resident),
            "blocks": f"{mc.block_m}x{mc.block_n}",
            "decode_chunk": str(dec.chunk),
            "reuse_%": fmt(eff * 100, 1),
        })
    print(render_table(
        "Auto-resolved attention plans per config (kernels.plan)",
        rows,
        ["config", "order", "kv_resident", "blocks", "decode_chunk", "reuse_%"],
    ))
    save_result("tpu_resolver", rows)
    return rows


def placement_table(model_shards: int = 16):
    rows = []
    for name, hq, hkv, seq, d in CONFIGS:
        aligned = placement.plan(hq, hkv, model_shards, placement.ACC_ALIGNED)
        striped = placement.plan(hq, hkv, model_shards, placement.STRIPED)
        extra = placement.kv_collective_bytes_per_layer(
            striped, seq_len=seq, head_dim=d, batch=8)
        rows.append({
            "config": name,
            "aligned_dup": fmt(aligned.kv_duplication, 2),
            "striped_dup": fmt(striped.kv_duplication, 2),
            "striped_extra_GB_per_layer": fmt(extra / 1e9, 3),
        })
    print(render_table(
        f"Mesh-level KV duplication under {model_shards}-way head sharding",
        rows,
        ["config", "aligned_dup", "striped_dup", "striped_extra_GB_per_layer"],
    ))
    save_result("tpu_placement", rows)
    return rows
