"""Reproductions of the paper's evaluation figures (12-16).

Each figure function sweeps the paper's configuration grid through the
calibrated MI300X cache simulator (core/cache_sim.py) and reports the same
normalized quantities the paper plots:

  Fig. 12 — MHA relative performance vs Swizzled Head-first
  Fig. 13 — MHA L2 hit rates
  Fig. 14 — GQA (8 KV heads; H_Q = 32/64/128 = Llama-3 8B/70B/405B)
  Fig. 15 — DeepSeek-V3 prefill (MHA H=128, D_HEAD=56)
  Fig. 16 — FA2 backward-pass speedup vs Naive Block-first

Quick mode trims the grid (batch 1, three head counts) so the full suite
runs in minutes on one CPU core; --full sweeps the paper's complete grid.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import cache_sim, numa, swizzle
from repro.core.cache_sim import AttentionWorkload
from repro.core.swizzle import AttentionGrid

from benchmarks.common import fmt, render_table, save_result

TOPO = numa.MI300X
BUDGET_QUICK = 800_000
BUDGET_FULL = 3_000_000

SHORT = {
    swizzle.NAIVE_BLOCK_FIRST: "naiveBF",
    swizzle.SWIZZLED_BLOCK_FIRST: "swizBF",
    swizzle.NAIVE_HEAD_FIRST: "naiveHF",
    swizzle.SWIZZLED_HEAD_FIRST: "swizHF",
}


def _sweep(configs, *, pass_="fwd", budget=BUDGET_QUICK,
           baseline=swizzle.SWIZZLED_HEAD_FIRST, head_dim=128) -> List[Dict]:
    rows = []
    for h, g, n, b in configs:
        wl = AttentionWorkload(
            grid=AttentionGrid(batch=b, num_q_heads=h, blocks_per_head=0,
                               group_size=g),
            seq_len=n, head_dim=head_dim, pass_=pass_,
        )
        res = cache_sim.compare_mappings(wl, TOPO, budget_accesses=budget)
        base = res[baseline].throughput
        row = {"H_Q": h, "H_KV": h // g, "N_CTX": n, "B": b}
        for m, r in res.items():
            row[f"perf:{SHORT[m]}"] = fmt(r.throughput / base)
            row[f"l2:{SHORT[m]}"] = fmt(r.hit_rate * 100, 1)
        rows.append(row)
    return rows


def fig12_13_mha(full: bool = False):
    """MHA sensitivity: relative perf (Fig. 12) + L2 hit rates (Fig. 13)."""
    heads = [8, 16, 32, 64, 128] if full else [8, 32, 128]
    seqs = [8192, 32768, 131072] if full else [8192, 32768, 131072]
    batches = [1, 2, 4, 8] if full else [1]
    configs = [(h, 1, n, b) for h in heads for n in seqs for b in batches]
    rows = _sweep(configs, budget=BUDGET_FULL if full else BUDGET_QUICK)
    perf_cols = ["H_Q", "N_CTX", "B"] + [f"perf:{v}" for v in SHORT.values()]
    l2_cols = ["H_Q", "N_CTX", "B"] + [f"l2:{v}" for v in SHORT.values()]
    print(render_table("Fig.12 — MHA relative performance (vs Swizzled Head-first)",
                       rows, perf_cols))
    print()
    print(render_table("Fig.13 — MHA L2 hit rates (%)", rows, l2_cols))
    save_result("fig12_13_mha", rows)
    return rows


def fig14_gqa(full: bool = False):
    """GQA with 8 KV heads: H_Q = 32/64/128 (Llama-3 8B/70B/405B)."""
    hqs = [32, 64, 128]
    seqs = [8192, 32768, 131072] if full else [8192, 131072]
    batches = [1, 4, 8] if full else [1]
    configs = [(h, h // 8, n, b) for h in hqs for n in seqs for b in batches]
    rows = _sweep(configs, budget=BUDGET_FULL if full else BUDGET_QUICK)
    cols = (["H_Q", "H_KV", "N_CTX", "B"]
            + [f"perf:{v}" for v in SHORT.values()]
            + [f"l2:{v}" for v in SHORT.values()])
    print(render_table("Fig.14 — GQA (8 KV heads) relative performance", rows, cols))
    save_result("fig14_gqa", rows)
    return rows


def fig15_deepseek(full: bool = False):
    """DeepSeek-V3 prefill: MHA, 128 q-heads == 128 kv-heads, D_HEAD=56."""
    seqs = [2048, 8192, 32768, 131072] if full else [8192, 131072]
    batches = [1, 4, 8] if full else [1]
    configs = [(128, 1, n, b) for n in seqs for b in batches]
    rows = _sweep(configs, head_dim=56,
                  budget=BUDGET_FULL if full else BUDGET_QUICK)
    cols = ["H_Q", "N_CTX", "B"] + [f"perf:{v}" for v in SHORT.values()]
    print(render_table(
        "Fig.15 — DeepSeek-V3 prefill (MHA 128 heads, D_HEAD=56)", rows, cols))
    save_result("fig15_deepseek", rows)
    return rows


def fig16_backward(full: bool = False):
    """FA2 backward pass, H_Q=128: speedup vs Naive Block-first."""
    seqs = [8192, 32768, 131072] if full else [8192, 131072]
    batches = [1, 2] if full else [1]
    configs = [(128, 1, n, b) for n in seqs for b in batches]
    rows = _sweep(configs, pass_="bwd", baseline=swizzle.NAIVE_BLOCK_FIRST,
                  budget=BUDGET_FULL if full else BUDGET_QUICK)
    cols = ["H_Q", "N_CTX", "B"] + [f"perf:{v}" for v in SHORT.values()]
    print(render_table(
        "Fig.16 — FA2 backward speedup (vs Naive Block-first)", rows, cols))
    save_result("fig16_backward", rows)
    return rows


def validate_paper_claims(rows12) -> Dict[str, bool]:
    """The paper's headline numbers, checked against our reproduction."""
    checks = {}
    extreme = [r for r in rows12 if r["H_Q"] == 128 and r["N_CTX"] == 131072]
    if extreme:
        r = extreme[0]
        swiz_hit = float(r["l2:swizHF"])
        bf_hit = float(r["l2:naiveBF"])
        bf_perf = float(r["perf:naiveBF"])
        checks["swizzled hit rate 80-97% at H=128/N=128K"] = 80.0 <= swiz_hit <= 99.5
        checks["block-first hit collapse (~1%)"] = bf_hit < 10.0
        checks["up to ~50% perf gain (block-first <= 0.8x)"] = bf_perf <= 0.80
    for k, v in checks.items():
        print(f"  [{'PASS' if v else 'FAIL'}] {k}")
    return checks
