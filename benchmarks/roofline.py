"""Roofline report: aggregates the dry-run artifacts into the §Roofline table.

Reads artifacts/dryrun/<arch>__<shape>__<mesh>.json (produced by
``python -m repro.launch.dryrun``) and prints, per (arch x shape):

  compute / memory / collective terms in seconds, the dominant term,
  MODEL_FLOPS (6*N_active*D or 2*N_active*D), the useful-flops ratio, and
  per-device peak bytes.

Single-pod only, per the assignment (the multi-pod pass proves the pod axis
shards; its artifacts are listed separately as a fits-check).
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import fmt, render_table, save_result

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load(mesh: str = "single"):
    recs = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_table(mesh: str = "single"):
    recs = load(mesh)
    if not recs:
        print(f"No dry-run artifacts for mesh={mesh}. "
              "Run: PYTHONPATH=src python -m repro.launch.dryrun --all")
        return []
    rows = []
    for r in recs:
        if not r.get("ok"):
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "dominant": f"FAILED: {r.get('error', '?')[:40]}"})
            continue
        t = r["roofline"]
        rows.append({
            "arch": r["arch"],
            "shape": r["shape"],
            "compute_ms": fmt(t["compute_s"] * 1e3, 1),
            "memory_ms": fmt(t["memory_s"] * 1e3, 1),
            "collective_ms": fmt(t["collective_s"] * 1e3, 1),
            "dominant": t["dominant"],
            "useful_ratio": fmt(t["useful_flops_ratio"], 2),
            "peak_GiB": fmt(r["memory"]["peak_bytes"] / 2**30, 2),
            "fits_16G": "yes" if r["memory"]["peak_bytes"] < 16 * 2**30 else "NO",
        })
    print(render_table(
        f"Roofline terms per (arch x shape), mesh={mesh} "
        "(per chip: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI)",
        rows,
        ["arch", "shape", "compute_ms", "memory_ms", "collective_ms",
         "dominant", "useful_ratio", "peak_GiB", "fits_16G"],
    ))
    save_result(f"roofline_{mesh}", rows)
    return rows


def pick_hillclimb_candidates(rows):
    """Worst roofline fraction / most collective-bound / most representative."""
    ok = [r for r in rows if "compute_ms" in r]
    if not ok:
        return []

    def frac(r):  # compute / bound: closeness to the compute roofline
        bound = max(float(r["compute_ms"]), float(r["memory_ms"]),
                    float(r["collective_ms"]))
        return float(r["compute_ms"]) / bound if bound else 1.0

    worst = min(ok, key=frac)
    coll = max(ok, key=lambda r: float(r["collective_ms"])
               / max(float(r["compute_ms"]), 1e-9))
    rep = next((r for r in ok
                if r["arch"] == "llama3-405b" and r["shape"] == "prefill_32k"),
               ok[0])
    out = {"worst_roofline": worst, "most_collective_bound": coll,
           "paper_representative": rep}
    for k, v in out.items():
        print(f"  hillclimb candidate [{k}]: {v['arch']} x {v['shape']}")
    return out
