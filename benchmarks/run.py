"""Benchmark entrypoint: ``PYTHONPATH=src python -m benchmarks.run [--full]``.

Runs, in order:
  1. the paper-figure reproductions (Figs. 12-16) through the MI300X cache
     simulator, with the paper-claim validation checklist,
  2. the TPU-port reuse benchmarks (kernel HBM traffic + mesh placement),
  3. the roofline report over any existing dry-run artifacts.

Quick mode (default) trims sweep grids to run in minutes on one CPU core;
``--full`` sweeps the paper's complete grids.
"""

from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale sweeps")
    ap.add_argument("--skip-figures", action="store_true")
    args = ap.parse_args(argv)

    t0 = time.time()
    print("=" * 78)
    print("NUMA-aware attention scheduling — benchmark suite")
    print("=" * 78)

    from benchmarks import paper_figures, roofline, tpu_reuse

    ok = True
    if not args.skip_figures:
        print("\n### Paper evaluation reproduction (MI300X cache simulator)\n")
        rows12 = paper_figures.fig12_13_mha(full=args.full)
        print()
        paper_figures.fig14_gqa(full=args.full)
        print()
        paper_figures.fig15_deepseek(full=args.full)
        print()
        paper_figures.fig16_backward(full=args.full)
        print("\n### Paper-claim validation")
        checks = paper_figures.validate_paper_claims(rows12)
        ok = all(checks.values()) if checks else ok

    print("\n### TPU port: static reuse / placement analysis\n")
    tpu_reuse.kernel_reuse_table()
    print()
    tpu_reuse.resolver_table()
    print()
    tpu_reuse.placement_table()

    print("\n### Roofline (from dry-run artifacts)\n")
    rows = roofline.roofline_table("single")
    if rows:
        roofline.pick_hillclimb_candidates(rows)

    print(f"\nDone in {time.time() - t0:.0f}s")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
