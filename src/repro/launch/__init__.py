"""repro subpackage."""
