"""Serving launcher: batched continuous-batching engine over a request file
or a synthetic request stream.

Example:
  python -m repro.launch.serve --arch llama3-8b --smoke --requests 16 \
      --max-new-tokens 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving.engine import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config if args.smoke else registry.get_config)(args.arch)
    params = transformer.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = ServingEngine(
        cfg, params, num_slots=args.slots, cache_len=args.cache_len,
        prompt_buckets=(args.prompt_len, 2 * args.prompt_len),
    )
    rng = np.random.default_rng(args.seed)
    shape = (args.prompt_len,) if cfg.num_codebooks == 1 else (
        args.prompt_len, cfg.num_codebooks)
    reqs = [
        Request(
            uid=i,
            prompt=rng.integers(1, cfg.vocab, size=shape),
            max_new_tokens=args.max_new_tokens,
            temperature=args.temperature,
        )
        for i in range(args.requests)
    ]
    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {total_new} tokens "
          f"in {dt:.2f}s ({total_new/dt:.1f} tok/s)")
    for r in sorted(results, key=lambda r: r.uid)[:4]:
        toks = [int(np.asarray(t).reshape(-1)[0]) for t in r.tokens]
        print(f"  uid={r.uid} prompt_len={r.prompt_len} out={toks}")


if __name__ == "__main__":
    main()
