"""Serving launcher: the LLMEngine facade over a synthetic request stream.

The KV layout is a flag, not a class choice: ``--kv-layout auto`` lets the
plan layer's NUMA decode model pick dense stripes vs the paged pool (and
falls back to dense for models the paged subsystem cannot hold);
``dense`` / ``paged`` pin it. Per-request sampling flags drive the
on-device batched sampler. SchedulerStats print at exit.

Examples:
  python -m repro.launch.serve --arch llama3-8b --smoke --requests 16 \
      --max-new-tokens 12
  python -m repro.launch.serve --arch llama3-8b --smoke --kv-layout paged \
      --temperature 0.8 --top-k 40 --top-p 0.95
  python -m repro.launch.serve --arch llama3-8b --smoke --mesh 4 \
      --steps-per-sync auto
  python -m repro.launch.serve --arch llama3-8b --smoke --kv-dtype int8 \
      --host-pool-bytes 1048576
"""

from __future__ import annotations

import argparse
import dataclasses
import math

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.serving import LLMEngine, Request, SamplingParams


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--kv-layout", choices=("auto", "dense", "paged"),
                    default="auto")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4,
                    help="decode rows (max_batch)")
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--num-pages", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="tokens of system prompt shared by every request "
                         "(exercises paged prefix sharing)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard serving over N devices (1-D 'model' mesh, "
                         "head-sharded KV; 0 = single-device)")
    ap.add_argument("--steps-per-sync", default="1",
                    help="fused decode ticks per host sync: an int, or "
                         "'auto' to let the scheduler pick from the live "
                         "batch's modeled tick time")
    ap.add_argument("--kv-dtype", choices=("fp32", "int8", "fp8"),
                    default="fp32",
                    help="paged pool storage dtype; int8/fp8 store "
                         "quantized codes + per-page-per-head scales")
    ap.add_argument("--host-pool-bytes", type=int, default=0,
                    help="host-DRAM KV tier budget in bytes (0 = off): "
                         "cold pages demote host-side under pool "
                         "pressure and promote back on prefix match")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config if args.smoke else registry.get_config)(args.arch)
    if args.mesh > 1 and args.smoke and cfg.n_kv_heads % args.mesh:
        # Smoke configs keep tiny head counts; widen KV heads to the
        # smallest multiple the mesh divides so the head-sharded pool has
        # an even split (smoke-only — real configs must divide as-is).
        factor = args.mesh // math.gcd(cfg.n_kv_heads, args.mesh)
        cfg = dataclasses.replace(
            cfg, n_kv_heads=cfg.n_kv_heads * factor,
            n_heads=cfg.n_heads * factor,
        )
        print(f"smoke mesh fit: widened heads x{factor} -> "
              f"Hq={cfg.n_heads} Hkv={cfg.n_kv_heads}")
    steps = (args.steps_per_sync if args.steps_per_sync == "auto"
             else int(args.steps_per_sync))
    params = transformer.init_model(jax.random.PRNGKey(args.seed), cfg)
    engine = LLMEngine(
        cfg, params,
        kv_layout=args.kv_layout,
        max_batch=args.slots,
        cache_len=args.cache_len,
        num_pages=args.num_pages,
        page_size=args.page_size,
        prompt_buckets=(args.prompt_len, 2 * args.prompt_len),
        mesh=args.mesh if args.mesh > 1 else None,
        steps_per_sync=steps,
        kv_dtype=args.kv_dtype,
        host_pool_bytes=args.host_pool_bytes or None,
    )
    print(f"kv_layout={engine.kv_layout} (requested {args.kv_layout}) "
          f"devices={engine.backend.num_devices}")
    rng = np.random.default_rng(args.seed)
    shape = (args.prompt_len,) if cfg.num_codebooks == 1 else (
        args.prompt_len, cfg.num_codebooks)
    system = rng.integers(1, cfg.vocab, size=(args.shared_prefix,)) \
        if args.shared_prefix else None
    reqs = []
    for i in range(args.requests):
        prompt = rng.integers(1, cfg.vocab, size=shape)
        if system is not None and cfg.num_codebooks == 1:
            prompt = np.concatenate(
                [system, prompt[: args.prompt_len - args.shared_prefix]]
            )
        reqs.append(Request(
            uid=i, prompt=prompt,
            sampling=SamplingParams(
                temperature=args.temperature, top_k=args.top_k,
                top_p=args.top_p, max_tokens=args.max_new_tokens,
            ),
        ))
    results = engine.generate(reqs)
    for r in sorted(results, key=lambda r: r.uid)[:4]:
        toks = [int(np.asarray(t).reshape(-1)[0]) for t in r.tokens]
        print(f"  uid={r.uid} prompt_len={r.prompt_len} "
              f"finish={r.finish_reason} out={toks}")
    print(engine.stats().summary())


if __name__ == "__main__":
    main()
