import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: hypothesis -> change -> re-lower -> record.

Runs the three selected (arch x shape) cells through a sequence of variants,
each a single explicit change over the previous best, and writes
artifacts/hillclimb/<cell>__<variant>.json with the full roofline record.
EXPERIMENTS.md §Perf narrates these numbers.

The variants encode the napkin math in their descriptions — predicted deltas
are stated up front so confirmation/refutation is visible in the artifacts.

Usage:
  PYTHONPATH=src python -m repro.launch.hillclimb [--cell paper|collective|memory]
"""

import argparse
import json

from repro.configs import registry
from repro.configs.base import DECODE_32K, PREFILL_32K, TRAIN_4K
from repro.launch.dryrun import run_cell

OUT = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "hillclimb"))

# Each experiment: the paper-representative cell, the most collective-bound
# cell, and the worst-roofline-fraction cell (selection rationale in
# EXPERIMENTS.md §Perf, from the baseline table).
EXPERIMENTS = {
    "paper": {
        "arch": "llama3-405b",
        "shape": PREFILL_32K,
        "variants": [
            ("baseline", {},
             "paper-faithful: ACC-aligned heads, xla_flash scan attention"),
            ("striped_placement", {"head_placement": "striped"},
             "ABLATION (paper's naive baseline): striped head placement "
             "must ADD cross-shard KV/Q movement -> collective term up"),
            ("tri_attention", {"attn_impl": "xla_flash_tri"},
             "beyond-paper: causal-triangular attention skips the "
             "above-diagonal half -> predict ~2x less attention compute"),
        ],
    },
    "collective": {
        "arch": "mixtral-8x7b",
        "shape": TRAIN_4K,
        "variants": [
            ("baseline", {},
             "most collective-bound cell of the baseline table (185s "
             "collective term): MoE dispatch buffers shard on one axis only"),
            ("ep_dp_buffers", {"moe_sharding": "ep_dp"},
             "shard expert capacity over the data axes too: predict expert "
             "GEMM compute /16 (every data replica currently redoes all "
             "expert work) and dispatch all-reduces become all-to-alls"),
            ("ep_dp_mb16", {"moe_sharding": "ep_dp", "microbatches": 16},
             "round 2: halve per-step dispatch buffers (C per microbatch) — "
             "predict peak HBM down, collective roughly flat (same totals)"),
            ("ep_dp_dots", {"moe_sharding": "ep_dp", "remat_policy": "dots"},
             "round 2: save matmul outputs — predict fewer recomputed "
             "dispatch collectives in backward at the cost of peak bytes"),
        ],
    },
    "decode": {
        "arch": "llama3-8b",
        "shape": DECODE_32K,
        "variants": [
            ("baseline", {},
             "2D fully-sharded serving weights: per-layer weight all-gather "
             "dominates single-token decode"),
            ("model_only_weights", {"serve_sharding": "model_only"},
             "8B bf16 fits the 16-way model axis (1GB/chip): predict the "
             "collective term collapses to the attention/output reductions"),
        ],
    },
    "memory": {
        "arch": "llama3-405b",
        "shape": TRAIN_4K,
        "variants": [
            ("baseline", {},
             "megatron-only state sharding (model axis): 405B f32 params + "
             "moments live on 16 shards -> ~300GB/chip, hopeless"),
            ("fsdp_2d", {"train_sharding": "2d"},
             "ZeRO-3: shard params+moments over (data x model) = 256 ways: "
             "predict state bytes /16 -> ~19GB/chip; weight all-gathers "
             "appear per layer (collective term up)"),
            ("fsdp_bf16_moments", {"train_sharding": "2d",
                                   "moment_dtype": "bfloat16"},
             "moments bf16: state 12 -> 8 bytes/param: predict ~12.7GB/chip "
             "+ activations — single-pod 405B residency"),
            ("fsdp_more_microbatches", {"train_sharding": "2d",
                                        "moment_dtype": "bfloat16",
                                        "microbatches": 16},
             "halve live activation footprint per accumulation step"),
        ],
    },
}


def run(which: str):
    exp = EXPERIMENTS[which]
    os.makedirs(OUT, exist_ok=True)
    print(f"== hillclimb: {which} — {exp['arch']} x {exp['shape'].name} ==")
    for name, ov, hypothesis in exp["variants"]:
        print(f"\n--- variant {name}: {hypothesis}")
        rec = run_cell(exp["arch"], exp["shape"], "single", OUT,
                       overrides=ov, tag=name)
        if rec["ok"]:
            r = rec["roofline"]
            print(f"    compute={r['compute_s']*1e3:.1f}ms "
                  f"memory={r['memory_s']*1e3:.1f}ms "
                  f"collective={r['collective_s']*1e3:.1f}ms "
                  f"dominant={r['dominant']} "
                  f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB")
        else:
            print(f"    FAILED: {rec['error']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default="all",
                    choices=["paper", "collective", "memory", "decode", "all"])
    args = ap.parse_args()
    for which in (EXPERIMENTS if args.cell == "all" else [args.cell]):
        run(which)


if __name__ == "__main__":
    main()
