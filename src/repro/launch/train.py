"""Training launcher.

Runs the fault-tolerant trainer on whatever devices this host exposes (the
production meshes come from ``mesh.make_production_mesh``; on a dev box the
host mesh is used). Sharding, checkpointing, resume, and the data pipeline
are the same code paths the dry-run lowers for 512 chips.

Examples:
  python -m repro.launch.train --arch llama3-8b --smoke --steps 200 \
      --seq-len 256 --global-batch 16 --ckpt-dir /tmp/run1
  python -m repro.launch.train --arch mixtral-8x7b --smoke --steps 50
"""

from __future__ import annotations

import argparse
import dataclasses
import logging

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import registry
from repro.data.pipeline import DataConfig, make_pipeline
from repro.distributed import sharding as shlib
from repro.launch.mesh import make_host_mesh
from repro.optim.adamw import AdamWConfig
from repro.training.train_step import TrainConfig, init_train_state, make_train_step
from repro.training.trainer import Trainer, TrainerConfig


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", help="reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--grad-compression", default="none",
                    choices=["none", "int8_ef"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO, format="%(message)s")
    cfg = (registry.get_smoke_config if args.smoke else registry.get_config)(args.arch)
    mesh = make_host_mesh()
    tcfg = TrainConfig(
        optimizer=AdamWConfig(lr=args.lr, warmup_steps=args.warmup,
                              total_steps=args.steps),
        microbatches=args.microbatches,
        grad_compression=args.grad_compression,
    )
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, tcfg)
    state_sh = shlib.param_shardings(mesh, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))
    state = jax.tree.map(jax.device_put, state, state_sh)

    with mesh:
        step_fn = jax.jit(
            make_train_step(cfg, tcfg,
                            shard_moe=shlib.shard_moe_buffers(mesh, "ep_dp")),
            donate_argnums=(0,),
        )
        dcfg = DataConfig(
            seq_len=args.seq_len, global_batch=args.global_batch,
            seed=args.seed, vocab=cfg.vocab, num_codebooks=cfg.num_codebooks,
        )
        pipe = make_pipeline(dcfg)
        bspec = shlib.batch_spec(mesh, args.global_batch)

        def put(b):
            out = {}
            for k, v in b.items():
                spec = shlib.fix_spec(
                    jax.sharding.PartitionSpec(
                        bspec[0] if len(bspec) else None,
                        *([None] * (v.ndim - 1))),
                    v.shape, mesh)
                out[k] = jax.device_put(v, NamedSharding(mesh, spec))
            return out

        trainer = Trainer(
            step_fn, state, pipe,
            TrainerConfig(
                total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, log_every=args.log_every,
            ),
            put_batch=put,
        )
        trainer.try_resume()
        metrics = trainer.run()
    print("final metrics:", {k: round(v, 4) for k, v in metrics.items()})


if __name__ == "__main__":
    main()
