import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each assigned architecture x input shape this builds the real step
function (train_step / prefill / decode_step), with the production sharding
rules, lowers it against ShapeDtypeStruct inputs (no allocation), compiles
for the single-pod (16x16) and multi-pod (2x16x16) meshes, and records:

  * memory_analysis()  — per-device argument/output/temp bytes (fits-check),
  * cost_analysis()    — per-device FLOPs + bytes accessed,
  * collective bytes   — parsed from the compiled HLO (hlo_analysis.py),
  * roofline terms     — compute / memory / collective seconds + dominant.

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed
benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --all
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh both
  python -m repro.launch.dryrun --list
"""

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import InputShape, ModelConfig
from repro.distributed import sharding as shlib
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import transformer
from repro.optim import adamw
from repro.training import train_step as ts_lib

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")


# -----------------------------------------------------------------------------
# Abstract inputs
# -----------------------------------------------------------------------------


def _sds(shape, dtype, mesh=None, spec=None):
    sh = NamedSharding(mesh, spec) if mesh is not None and spec is not None else None
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sh)


def input_specs(arch: str, shape: InputShape, mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of one cell."""
    cfg = registry.get_config(arch)
    b, s = shape.global_batch, shape.seq_len
    bspec = shlib.batch_spec(mesh, b) if mesh is not None else None
    bax = (bspec[0] if mesh is not None and len(bspec) else None)
    tok_shape = (b, s) if cfg.num_codebooks == 1 else (b, s, cfg.num_codebooks)
    tok_spec = P(bax, *([None] * (len(tok_shape) - 1))) if mesh is not None else None

    if shape.step == "train":
        out = {
            "tokens": _sds(tok_shape, jnp.int32, mesh, tok_spec),
            "targets": _sds(tok_shape, jnp.int32, mesh, tok_spec),
            "mask": _sds((b, s), jnp.float32, mesh, P(bax, None) if mesh else None),
        }
        if cfg.vision_tokens:
            out["image_embeds"] = _sds(
                (b, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16,
                mesh, P(bax, None, None) if mesh else None,
            )
        return out
    if shape.step == "prefill":
        out = {"tokens": _sds(tok_shape, jnp.int32, mesh, tok_spec)}
        if cfg.vision_tokens:
            out["image_embeds"] = _sds(
                (b, cfg.vision_tokens, cfg.vision_dim), jnp.bfloat16,
                mesh, P(bax, None, None) if mesh else None,
            )
        return out
    # decode: one new token against a seq_len cache
    tshape = (b,) if cfg.num_codebooks == 1 else (b, cfg.num_codebooks)
    out = {
        "token": _sds(tshape, jnp.int32, mesh, P(bax, *([None] * (len(tshape) - 1))) if mesh else None),
        "lengths": _sds((b,), jnp.int32, mesh, P(bax) if mesh else None),
    }
    return out


def _abstract_params(cfg: ModelConfig, dtype=None):
    shapes = jax.eval_shape(
        lambda k: transformer.init_model(k, cfg), jax.random.PRNGKey(0)
    )
    if dtype is None:
        return shapes
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(
            x.shape, dtype if jnp.issubdtype(x.dtype, jnp.floating) else x.dtype
        ),
        shapes,
    )


def _serve_param_specs(params_shape, mesh: Mesh):
    """2-D fully-sharded serving weights: big matrices over (data, model).

    Required for llama3-405b-class models at inference (811 GB bf16 cannot
    live on a 16-way model axis alone); smaller models also benefit from the
    extra HBM headroom. Expert tensors keep experts on "model" and shard the
    expert-ff dim on "data"."""
    both = ("data", "model")

    def spec(path, leaf):
        base = shlib.spec_for_path(path, leaf)
        rank = leaf.ndim
        key = ""
        for entry in reversed(path):
            if hasattr(entry, "key"):
                key = str(entry.key)
                break
        if key.endswith("_edm"):
            base = shlib._right_align(("model", None, "data"), rank)
        elif key.endswith("_emd"):
            base = shlib._right_align(("model", "data", None), rank)
        elif key.endswith("_dm"):
            base = shlib._right_align((None, both), rank)
        elif key.endswith(("_md", "_vd")):
            base = shlib._right_align((both, None), rank)
        elif key.endswith("_kvd"):
            base = shlib._right_align((None, both, None), rank)
        return shlib.fix_spec(base, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(spec, params_shape)


# -----------------------------------------------------------------------------
# Step builders: (fn, example_args (SDS w/ shardings), donate_argnums)
# -----------------------------------------------------------------------------


def _data_shards(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


#: keys build_cell understands in ``overrides`` (the Perf hillclimb levers):
#:   attn_impl        xla_flash | xla_flash_tri
#:   microbatches     int
#:   remat_policy     nothing | dots
#:   head_placement   acc_aligned | striped (paper-technique A/B)
#:   moment_dtype     float32 | bfloat16 (optimizer HBM)
#:   serve_sharding   2d | model_only (inference weight layout)
def build_cell(arch: str, shape: InputShape, mesh: Mesh,
               cfg: Optional[ModelConfig] = None, attn_impl: str = "xla_flash",
               microbatches: Optional[int] = None,
               overrides: Optional[Dict[str, Any]] = None):
    ov = dict(overrides or {})
    if cfg is None:
        cfg = registry.get_config(arch)
    # Dry-run lowers the XLA flash path (Mosaic does not target host CPU);
    # the Pallas kernels carry their own cost model and are exercised by the
    # kernel test suite.
    cfg = dataclasses.replace(
        cfg,
        attn_impl=ov.get("attn_impl", attn_impl),
        remat_policy=ov.get("remat_policy", cfg.remat_policy),
        head_placement=ov.get("head_placement", cfg.head_placement),
    )
    microbatches = ov.get("microbatches", microbatches)
    shard_moe = shlib.shard_moe_buffers(mesh, ov.get("moe_sharding", "ep"))
    batch = input_specs(arch, shape, mesh)

    if shape.step == "train":
        # Microbatch so each accumulation step carries ~2 sequences per data
        # shard — decouples the 256-sequence global batch from HBM.
        if microbatches is None:
            per_shard = max(1, shape.global_batch // _data_shards(mesh))
            microbatches = max(1, per_shard // 2)
        tcfg = ts_lib.TrainConfig(
            optimizer=adamw.AdamWConfig(
                moment_dtype=ov.get("moment_dtype", "float32")
            ),
            microbatches=microbatches,
            remat=True,
        )
        params_shape = _abstract_params(cfg)
        state_shape = {
            "params": params_shape,
            "opt": jax.eval_shape(lambda p: adamw.init(p, tcfg.optimizer),
                                  params_shape),
        }
        if ov.get("train_sharding") == "2d":
            # FSDP/ZeRO-3 posture: parameters AND optimizer moments sharded
            # over (data x model); XLA all-gathers weights per layer.
            state_specs = _serve_param_specs(state_shape, mesh)
        else:
            state_specs = shlib.param_specs(state_shape, mesh)
        state_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), state_specs)
        state_sds = jax.tree.map(
            lambda x, sh: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=sh),
            state_shape, state_sh,
        )
        step = ts_lib.make_train_step(cfg, tcfg, shard_moe=shard_moe)
        fn = jax.jit(step, donate_argnums=(0,))
        return fn, (state_sds, batch), cfg

    params_shape = _abstract_params(cfg, jnp.bfloat16)
    if ov.get("serve_sharding", "2d") == "model_only":
        pspecs = shlib.param_specs(params_shape, mesh)
    else:
        pspecs = _serve_param_specs(params_shape, mesh)
    params_sds = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
        params_shape, pspecs,
    )

    if shape.step == "prefill":
        def prefill_fn(params, batch_):
            return transformer.prefill(
                params, cfg, batch_["tokens"], cache_len=shape.seq_len,
                image_embeds=batch_.get("image_embeds"), shard_moe=shard_moe,
            )
        fn = jax.jit(prefill_fn)
        return fn, (params_sds, batch), cfg

    # decode
    shard_seq = shape.name == "long_500k"
    caches_shape = jax.eval_shape(
        lambda: transformer.init_caches(
            None, cfg, shape.global_batch, shape.seq_len,
            image_len=cfg.vision_tokens or 0,
        )
    )
    cspecs = shlib.cache_specs(cfg, mesh, caches_shape, shard_seq=shard_seq,
                               global_batch=shape.global_batch)
    caches_sds = jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=NamedSharding(mesh, s)),
        caches_shape, cspecs,
    )

    def decode_fn(params, token, caches, lengths):
        return transformer.decode_step(
            params, cfg, token, caches, lengths, shard_moe=shard_moe
        )

    fn = jax.jit(decode_fn, donate_argnums=(2,))
    return fn, (params_sds, batch["token"], caches_sds, batch["lengths"]), cfg


# -----------------------------------------------------------------------------
# Roofline bookkeeping
# -----------------------------------------------------------------------------


def _cell_costs(arch, shape, mesh, cfg, *, attn_impl="xla_flash", microbatches=None,
                overrides=None):
    """(flops, bytes_accessed, collective_bytes) per device for one config."""
    fn, args, _ = build_cell(arch, shape, mesh, cfg=cfg, attn_impl=attn_impl,
                             microbatches=microbatches, overrides=overrides)
    with mesh:
        compiled = fn.lower(*args).compile()
    ca = compiled.cost_analysis() or {}
    coll = hlo_analysis.collective_bytes(compiled.as_text())
    return (
        float(ca.get("flops", 0.0)),
        float(ca.get("bytes accessed", 0.0)),
        float(coll["total"]),
    )


def probe_corrected_costs(arch: str, shape: InputShape, mesh: Mesh,
                          *, attn_impl="xla_flash", microbatches=None,
                          overrides=None):
    """Depth-probe correction for XLA's count-while-body-once cost analysis.

    ``lax.scan`` over layer periods compiles to a while loop whose body the
    HLO cost model counts ONCE (verified experimentally), so the real cell's
    flops/bytes are undercounted by ~n_periods. We compile two shallow
    *unrolled* variants — 1 period and 2 periods (scan_unroll = trip count,
    so no while loop remains) — and extrapolate linearly in depth:

        cost(L) = cost_1p + (cost_2p - cost_1p) * (L - P) / P

    which is exact for per-layer-homogeneous stacks (all of ours are, within
    a period) and includes the depth-independent base (embedding, vocab head,
    loss) via the intercept.
    """
    base_cfg = registry.get_config(arch)
    plen = len(base_cfg.layer_pattern)
    # attn_chunk_unroll: the xla_flash KV-chunk scan is an inner while loop
    # that cost analysis would also count once — unroll it in the probes.
    cfg1 = dataclasses.replace(base_cfg, n_layers=plen, scan_unroll=1,
                               attn_chunk_unroll=True)
    cfg2 = dataclasses.replace(base_cfg, n_layers=2 * plen, scan_unroll=2,
                               attn_chunk_unroll=True)
    # microbatches=1: the grad-accumulation scan is ALSO a while loop that
    # the cost model counts once. Total flops/collectives are microbatch-
    # invariant, so the unaccumulated probe measures them exactly (weight
    # re-reads across microbatches are the one term this under-counts).
    ov = dict(overrides or {})
    ov["microbatches"] = 1
    c1 = _cell_costs(arch, shape, mesh, cfg1, attn_impl=attn_impl, overrides=ov)
    c2 = _cell_costs(arch, shape, mesh, cfg2, attn_impl=attn_impl, overrides=ov)
    L = base_cfg.n_layers
    return tuple(a + (b - a) * (L - plen) / plen for a, b in zip(c1, c2))


def model_flops(cfg: ModelConfig, shape: InputShape, num_devices: int) -> float:
    """6*N_active*D (train) / 2*N_active*D (inference), per device."""
    n = cfg.active_param_count()
    if shape.step == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n * tokens
    elif shape.step == "prefill":
        total = 2.0 * n * shape.global_batch * shape.seq_len
    else:
        total = 2.0 * n * shape.global_batch
    return total / num_devices


def run_cell(arch: str, shape: InputShape, mesh_kind: str, out_dir: str,
             overrides: Optional[Dict[str, Any]] = None,
             tag: Optional[str] = None) -> Dict:
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    num_devices = int(np.prod(list(mesh.shape.values())))
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape.name, "step": shape.step,
        "mesh": mesh_kind, "devices": num_devices, "ok": False,
        "overrides": overrides or {},
    }
    t0 = time.time()
    try:
        fn, args, cfg = build_cell(arch, shape, mesh, overrides=overrides)
        with mesh:
            lowered = fn.lower(*args)
            rec["lower_s"] = round(time.time() - t0, 1)
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
            "peak_bytes": int(
                ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes - ma.alias_size_in_bytes
            ),
        }
        ca = compiled.cost_analysis() or {}
        flops = float(ca.get("flops", 0.0))
        bytes_accessed = float(ca.get("bytes accessed", 0.0))
        rec["cost_raw"] = {
            "flops": flops, "bytes_accessed": bytes_accessed,
            "note": "while(scan) bodies counted once by XLA — see cost",
        }
        coll = hlo_analysis.collective_bytes(compiled.as_text())
        # HLO is SPMD: one program per device => bytes are per-device.
        rec["collectives_raw"] = coll
        # Depth-probe corrected costs (scan bodies re-multiplied by depth).
        t2 = time.time()
        cflops, cbytes, ccoll = probe_corrected_costs(arch, shape, mesh,
                                                       overrides=overrides)
        # Floor at the raw (counted-once) measurement: extrapolation noise
        # between the two probe compiles must never go below it.
        cflops = max(cflops, flops)
        cbytes = max(cbytes, bytes_accessed)
        ccoll = max(ccoll, float(coll["total"]))
        rec["probe_s"] = round(time.time() - t2, 1)
        rec["cost"] = {"flops": cflops, "bytes_accessed": cbytes,
                       "collective_bytes": ccoll}
        terms = hlo_analysis.roofline_terms(cflops, cbytes, ccoll)
        mf = model_flops(cfg, shape, num_devices)
        terms["model_flops"] = mf
        terms["useful_flops_ratio"] = (mf / cflops) if cflops else 0.0
        rec["roofline"] = terms
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record and continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch}__{shape.name}__{mesh_kind}" + (f"__{tag}" if tag else "")
    path = os.path.join(out_dir, stem + ".json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    cells = registry.all_cells()
    if args.list:
        for a, s in cells:
            print(f"{a:24s} {s.name}")
        return
    if args.arch:
        cells = [(a, s) for a, s in cells if a == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s.name == args.shape]
    if not cells:
        raise SystemExit("no cells selected")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(args.out, f"{arch}__{shape.name}__{mk}.json")
            if args.skip_existing and os.path.exists(path):
                rec = json.load(open(path))
                if rec.get("ok"):
                    print(f"SKIP  {arch:24s} {shape.name:12s} {mk}")
                    n_ok += 1
                    continue
            rec = run_cell(arch, shape, mk, args.out)
            if rec["ok"]:
                n_ok += 1
                r = rec["roofline"]
                print(
                    f"OK    {arch:24s} {shape.name:12s} {mk:6s} "
                    f"compile={rec['compile_s']:.0f}s "
                    f"peak={rec['memory']['peak_bytes']/2**30:.2f}GiB/dev "
                    f"compute={r['compute_s']*1e3:.1f}ms mem={r['memory_s']*1e3:.1f}ms "
                    f"coll={r['collective_s']*1e3:.1f}ms dom={r['dominant']}"
                )
            else:
                n_fail += 1
                print(f"FAIL  {arch:24s} {shape.name:12s} {mk:6s} {rec['error']}")
    print(f"\n{n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
