"""Trace-driven SLO load harness over the ``LLMEngine`` facade (PR 7).

Open-loop load generation with the full telemetry stack attached:

  * **Arrivals** are Poisson — exponential inter-arrival gaps at
    ``--rate`` requests/s, cumulative-summed into a wall-clock schedule.
    The driver releases each request when its arrival time passes, steps
    the engine continuously while it has work, and sleeps to the next
    arrival when idle — so queueing delay is *measured*, not simulated.
  * **Workload mix**: prompt lengths and output budgets are drawn from
    weighted mixes, and a configurable fraction of requests shares a
    system-prompt prefix (page-aligned, so the paged backend's prefix
    cache gets real hits).
  * **Warmup**: a pilot batch runs to completion first (compiling every
    prefill bucket the mix can hit), ``jax.block_until_ready`` drains the
    device, and ``engine.reset_metrics()`` zeroes telemetry — measured
    numbers never include compilation.
  * **SLO metrics**: TTFT / ITL p50/p90/p99 from the tracer's lifecycle
    events (exact per-request timestamps, not averages), measured
    decode tok/s vs the analytic model's prediction, preemption and
    prefix-hit counters.
  * **Artifacts**: ``artifacts/benchmarks/loadgen_<layout>.json`` (the
    ``repro.obs`` envelope, with the full metrics snapshot riding along)
    and ``loadgen_<layout>_trace.json`` — a Chrome ``trace_event`` file;
    load it at https://ui.perfetto.dev. The model-vs-measured drift
    table (ROADMAP 5(b)) prints and lands in the JSON payload.

Run:
  PYTHONPATH=src python -m repro.launch.loadgen --smoke
      # CI: both KV layouts on the smoke model (Pallas in interpret
      # mode on CPU), asserts artifacts + latency coverage
  PYTHONPATH=src python -m repro.launch.loadgen --arch llama3-8b \
      --kv-layout paged --requests 64 --rate 32
  XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
      python -m repro.launch.loadgen --smoke --mesh-sweep 1,2,4
      # sharded scaling sweep: head-sharded paged pool, global batch
      # scaled as devices * per-device rows, writes loadgen_sharded.json
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.obs import Telemetry
from repro.obs.metrics import write_json_artifact
from repro.serving import LLMEngine, Request, SamplingParams

#: (value, weight) mixes the smoke/default workload draws from.
PROMPT_MIX: Tuple[Tuple[int, float], ...] = ((8, 0.5), (24, 0.3), (44, 0.2))
OUTPUT_MIX: Tuple[Tuple[int, float], ...] = ((4, 0.6), (8, 0.3), (12, 0.1))


def _draw(rng, mix) -> int:
    vals, weights = zip(*mix)
    w = np.asarray(weights, np.float64)
    return int(rng.choice(np.asarray(vals), p=w / w.sum()))


def build_workload(
    cfg,
    rng,
    n_requests: int,
    *,
    rate: float,
    prompt_mix=PROMPT_MIX,
    output_mix=OUTPUT_MIX,
    shared_prefix_len: int = 16,
    shared_fraction: float = 0.5,
    temperature: float = 0.0,
) -> List[Tuple[float, Request]]:
    """Poisson-arrival request trace: ``[(arrival_s, Request), ...]``
    sorted by arrival. ``shared_fraction`` of requests start with one
    common system prefix of ``shared_prefix_len`` tokens (page-align it
    to the backend's page size so prefix sharing can actually hit)."""
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    system = rng.integers(1, cfg.vocab, size=(shared_prefix_len,))
    out: List[Tuple[float, Request]] = []
    for i in range(n_requests):
        tail_len = _draw(rng, prompt_mix)
        tail = rng.integers(1, cfg.vocab, size=(tail_len,))
        if shared_prefix_len and rng.random() < shared_fraction:
            prompt = np.concatenate([system, tail])
        else:
            prompt = tail
        out.append((float(arrivals[i]), Request(
            uid=i, prompt=prompt,
            sampling=SamplingParams(
                temperature=temperature,
                max_tokens=_draw(rng, output_mix),
            ),
        )))
    return out


def safe_div(num: float, den: float) -> float:
    return num / den if den else 0.0


def percentiles(values, qs=(50, 90, 99)) -> Dict[str, Optional[float]]:
    if not values:
        return {f"p{q}": None for q in qs}
    arr = np.asarray(values, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


def _per_device_accounting(engine, cfg, done, peak_pages: int):
    """Per-device bandwidth + page-occupancy accounting for mesh runs.

    The pool is head-sharded: every page holds one KV-head slice per
    device, so page occupancy is identical on every device and the
    per-device footprint is ``pages * page_slice_bytes``. Decode reads
    the whole resident context once per step on every device (its head
    slice of it), so the implied per-device HBM demand is the per-token
    slice read times the measured aggregate token rate."""
    num_devices = engine.backend.num_devices
    if num_devices <= 1:
        return None
    from repro.distributed import sharding as sharding_lib

    b = engine.backend
    itemsize = jax.tree_util.tree_leaves(b.caches)[0].dtype.itemsize
    heads_per_dev = -(-cfg.n_kv_heads // num_devices)
    mean_ctx = float(np.mean(
        [o.prompt_len + len(o.tokens) for o in done])) if done else 0.0
    # One decode step reads each active row's resident KV once per
    # device (the head slice); the step yields one token per row, so
    # per-token-per-device bytes is independent of batch.
    kv_read = (2 * cfg.n_layers * heads_per_dev * mean_ctx
               * cfg.head_dim * itemsize)
    out = {
        "num_devices": num_devices,
        "kv_head_shards": [
            list(s) for s in
            sharding_lib.kv_head_shards(cfg.n_kv_heads, num_devices)
        ],
        "kv_read_bytes_per_token_per_device": kv_read,
        "implied_hbm_bw_per_device":
            kv_read * engine.stats().measured_tok_s,
    }
    pool = getattr(b, "pool", None)
    if pool is not None:
        slice_bytes = b._page_slice_bytes(
            cfg, b.page_size, num_devices, b.kv_dtype)
        out.update({
            "page_slice_bytes": slice_bytes,
            "pool_pages": pool.num_pages,
            "peak_pages_used": peak_pages,
            "peak_kv_bytes_per_device": peak_pages * slice_bytes,
            "page_budgets": b.device_page_budgets(),
        })
    return out


def _warmup(engine: LLMEngine, cfg, rng, workload) -> None:
    """Compile every prefill bucket the mix can hit (shared-prefix and
    bare variants), drain the device, zero telemetry."""
    pilots = []
    seen = set()
    for i, (_, req) in enumerate(workload):
        key = len(req.prompt)
        if key in seen:
            continue
        seen.add(key)
        pilots.append(Request(
            uid=10_000_000 + i, prompt=np.array(req.prompt),
            sampling=SamplingParams(max_tokens=2),
        ))
    engine.generate(pilots)
    jax.block_until_ready(engine.backend.caches)
    # Warmup requests stay in the completion history (uids >= 10_000_000)
    # but every measured counter/span/drift sample restarts here.
    engine.reset_metrics()


def drive(engine: LLMEngine, workload, *, idle_sleep_cap: float = 0.01,
          on_step=None):
    """Open-loop drive: release requests at their arrival times, step
    while the engine has work, sleep to the next arrival when idle.
    Returns the finished ``RequestOutput`` list. ``on_step(engine)`` is
    called after every step (occupancy sampling for the mesh sweep)."""
    pending = sorted(workload, key=lambda a: a[0])
    done = []
    i = 0
    t0 = time.perf_counter()
    while i < len(pending) or engine.backend.active.any() \
            or engine.scheduler.has_work():
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i][0] <= now:
            engine.add_request(pending[i][1])
            i += 1
        if not engine.backend.active.any() and not engine.scheduler.has_work():
            # Idle with future arrivals only: sleep toward the next one.
            time.sleep(min(max(pending[i][0] - now, 0.0), idle_sleep_cap))
            continue
        done.extend(o for o in engine.step() if o.finished)
        if on_step is not None:
            on_step(engine)
    return done


def run_one(args, kv_layout: str, *, cfg=None) -> Dict:
    """One full load run on one KV layout; returns the summary payload
    (also written to ``artifacts/benchmarks/loadgen_<kv_layout>.json``).
    ``cfg`` overrides the registry lookup (the mesh sweep pins one
    mesh-divisible config so runs are comparable across device counts)."""
    if cfg is None:
        get_cfg = (registry.get_smoke_config if args.smoke
                   else registry.get_config)
        cfg = get_cfg(args.arch)
    # getattr throughout: programmatic callers hand-build the namespace
    # and may predate newer flags (tests/test_loadgen.py does).
    mesh_n = int(getattr(args, "mesh", 0) or 0)
    steps = getattr(args, "steps_per_sync", 1)
    if steps != "auto":
        steps = int(steps)
    kv_dtype = getattr(args, "kv_dtype", "fp32") or "fp32"
    host_pool = int(getattr(args, "host_pool_bytes", 0) or 0)
    params = transformer.init_model(jax.random.PRNGKey(args.seed), cfg)
    telemetry = Telemetry.create()
    engine = LLMEngine(
        cfg, params,
        kv_layout=kv_layout,
        max_batch=args.max_batch,
        cache_len=args.cache_len,
        num_pages=args.num_pages,
        page_size=args.page_size,
        prompt_buckets=(16, 32, 64),
        telemetry=telemetry,
        mesh=mesh_n if mesh_n > 1 else None,
        steps_per_sync=steps,
        kv_dtype=kv_dtype,
        host_pool_bytes=host_pool or None,
    )
    rng = np.random.default_rng(args.seed)
    workload = build_workload(
        cfg, rng, args.requests, rate=args.rate,
        shared_prefix_len=args.shared_prefix,
        shared_fraction=args.shared_fraction,
        temperature=args.temperature,
    )
    _warmup(engine, cfg, rng, workload)
    traces_warm = engine.backend.stats.get("decode_traces", 0)

    # Peak page occupancy, sampled after every step: with the
    # head-sharded pool each page spans all devices (one head-slice per
    # device), so pool occupancy IS the per-device occupancy.
    peak = {"pages": 0}

    def _sample(eng):
        pool = getattr(eng.backend, "pool", None)
        if pool is not None:
            peak["pages"] = max(peak["pages"], int(pool.used_pages))

    t0 = time.perf_counter()
    done = drive(engine, workload, on_step=_sample)
    wall = time.perf_counter() - t0
    retraces = engine.backend.stats.get("decode_traces", 0) - traces_warm

    lat = telemetry.tracer.request_latencies()
    measured = {uid: d for uid, d in lat.items() if uid < 10_000_000}
    ttft = [d["ttft"] for d in measured.values() if d["ttft"] is not None]
    queue = [d["queue"] for d in measured.values() if d["queue"] is not None]
    itl = [x for d in measured.values() for x in d["itl"]]
    stats = engine.stats()
    prefix = engine.backend.prefix_stats()
    drift = telemetry.drift.report(engine.drift_model_fn())

    from repro.core import perf_model

    # Per-token host overhead: the per-step residual (step wall minus its
    # schedule / flush / decode phases) over the tokens produced — output
    # sync, bookkeeping, span plumbing. This is the once-per-sync tax the
    # fused N-step scan amortizes; flush is excluded because prefill cost
    # (and any in-run compilation) is per-request, not per-token.
    snap = telemetry.metrics.snapshot()
    host_overhead = safe_div(
        stats.elapsed_s - stats.decode_elapsed_s
        - snap["serving_flush_seconds"]["sum"]
        - snap["serving_schedule_seconds"]["sum"],
        stats.tokens_generated,
    )
    payload = {
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "kv_layout": engine.kv_layout,
        "requests": args.requests,
        "finished": len(done),
        "rate_req_s": args.rate,
        "wall_s": wall,
        "steps_per_sync": engine.steps_per_sync,
        "tokens_generated": stats.tokens_generated,
        "measured_tok_s": stats.measured_tok_s,
        "modeled_tok_s": stats.modeled_tok_s,
        "decode_elapsed_s": stats.decode_elapsed_s,
        "host_overhead_per_token_s": host_overhead,
        "modeled_host_overhead_s": perf_model.amortized_host_overhead(
            engine.steps_per_sync
        ),
        "decode_retraces_after_warmup": retraces,
        "ttft_s": percentiles(ttft),
        "itl_s": percentiles(itl),
        "queue_s": percentiles(queue),
        "preemptions": stats.preemptions,
        "resumed_tokens": stats.resumed_tokens,
        "prefix": prefix,
        "occupancy_cap": stats.occupancy_cap,
        "drift": drift.to_dict(),
        "drift_worst_ratio": drift.worst_ratio(),
        "mesh_devices": engine.backend.num_devices,
        "per_device": _per_device_accounting(engine, cfg, done, peak["pages"]),
    }
    out_dir = args.out_dir or None
    # N > 1 and mesh runs get their own artifact names so sweeps (the
    # smoke host-overhead comparison, the sharded device-count sweep)
    # never clobber the N=1 single-device baseline.
    n = engine.steps_per_sync
    stem = f"loadgen_{engine.kv_layout}" + (f"_n{n}" if n > 1 else "")
    if engine.backend.num_devices > 1:
        stem += f"_d{engine.backend.num_devices}"
    if host_pool:
        # Tiered runs get their own artifact: the demote/promote counters
        # in payload["prefix"] are the demonstration CI reads.
        stem = "loadgen_tiered"
    json_path = write_json_artifact(
        stem, payload,
        metrics=telemetry.metrics,
        dirpath=out_dir, kind="loadgen",
    )
    trace_dir = out_dir or os.path.dirname(json_path)
    trace_path = telemetry.tracer.write_chrome_trace(
        os.path.join(trace_dir, f"{stem}_trace.json")
    )
    payload["_artifacts"] = {"json": json_path, "trace": trace_path}

    def ms(d):
        return " / ".join(
            "n/a" if d[f"p{q}"] is None else f"{d[f'p{q}'] * 1e3:.1f}ms"
            for q in (50, 90, 99)
        )

    print(f"[loadgen:{engine.kv_layout}] {len(done)}/{args.requests} "
          f"finished in {wall:.2f}s at rate {args.rate}/s "
          f"(steps_per_sync={engine.steps_per_sync})")
    print(f"  host overhead {host_overhead * 1e6:.1f}us/token "
          f"(modeled {payload['modeled_host_overhead_s'] * 1e6:.1f}us), "
          f"{retraces} decode retraces after warmup")
    print(f"  TTFT p50/p90/p99: {ms(payload['ttft_s'])}")
    print(f"  ITL  p50/p90/p99: {ms(payload['itl_s'])}")
    print(f"  measured {stats.measured_tok_s:.1f} tok/s (decode wall "
          f"{stats.decode_elapsed_s:.2f}s), modeled "
          f"{stats.modeled_tok_s:.0f} tok/s")
    hit = prefix.get("prefix_hit_rate")
    print(f"  preemptions {stats.preemptions} "
          f"({stats.resumed_tokens} tokens resumed), prefix hit "
          f"{'n/a' if hit is None else f'{hit:.2f}'}")
    print("  " + drift.render().replace("\n", "\n  "))
    print(f"  wrote {json_path}")
    print(f"  wrote {trace_path} (open in https://ui.perfetto.dev)")
    engine.close()
    return payload


def _smoke_check(payload: Dict) -> None:
    """CI acceptance for one layout's run."""
    import json

    assert payload["finished"] == payload["requests"], payload
    assert payload["ttft_s"]["p50"] is not None, "no TTFT measured"
    assert payload["itl_s"]["p99"] is not None, "no ITL measured"
    assert payload["measured_tok_s"] > 0, "no measured throughput"
    assert payload["drift"]["rows"], "no drift cells recorded"
    with open(payload["_artifacts"]["trace"]) as f:
        trace = json.load(f)
    assert trace["traceEvents"], "empty Chrome trace"
    phases = {e["ph"] for e in trace["traceEvents"]}
    assert {"X", "M", "i", "b", "e"} <= phases, phases
    with open(payload["_artifacts"]["json"]) as f:
        env = json.load(f)
    assert env["schema"] == "repro.obs/v1", env["schema"]
    assert env["metrics"]["serving_steps_total"]["value"] > 0


def run_sharded_sweep(args) -> Dict:
    """Device-count scaling sweep on the paged backend: one load run per
    mesh width with MaxText-style global-batch scaling
    (``max_batch = device_count * per_device_batch``, requests scaled to
    match), modeled + measured aggregate tok/s, per-device page and
    bandwidth accounting. Writes ``loadgen_sharded.json``."""
    counts = sorted({int(x) for x in args.mesh_sweep.split(",") if x})
    if not counts:
        raise ValueError("--mesh-sweep needs a comma-separated list of "
                         "device counts, e.g. 1,2,4")
    avail = len(jax.devices())
    runnable = [d for d in counts if d <= avail]
    if runnable != counts:
        print(f"[loadgen] skipping device counts beyond the "
              f"{avail} available: {sorted(set(counts) - set(runnable))}")
    if not runnable:
        raise RuntimeError(f"no runnable device counts (have {avail})")

    get_cfg = (registry.get_smoke_config if args.smoke
               else registry.get_config)
    cfg = get_cfg(args.arch)
    if args.smoke:
        # Pin ONE mesh-divisible head layout for the whole sweep so the
        # numbers are comparable across device counts (the smoke config's
        # Hkv=2 doesn't divide over 4 devices).
        cfg = dataclasses.replace(cfg, n_heads=8, n_kv_heads=4,
                                  head_dim=16, d_model=128, d_ff=256)
    bad = [d for d in runnable if cfg.n_kv_heads % d]
    if bad:
        raise ValueError(f"n_kv_heads={cfg.n_kv_heads} not divisible by "
                         f"device counts {bad}")

    per_dev_batch = int(getattr(args, "per_device_batch", 0)
                        or args.max_batch)
    runs: Dict[str, Dict] = {}
    for d in runnable:
        ns = argparse.Namespace(**vars(args))
        ns.mesh = d
        ns.max_batch = per_dev_batch * d
        ns.requests = args.requests * d
        print(f"[loadgen] sharded sweep: {d} device(s), "
              f"max_batch={ns.max_batch}, requests={ns.requests}")
        p = run_one(ns, "paged", cfg=cfg)
        if args.smoke:
            assert p["finished"] == p["requests"], p
            assert p["measured_tok_s"] > 0, p
        runs[str(d)] = {k: p[k] for k in (
            "requests", "finished", "wall_s", "steps_per_sync",
            "tokens_generated", "measured_tok_s", "modeled_tok_s",
            "decode_elapsed_s", "decode_retraces_after_warmup",
            "mesh_devices", "per_device",
        )}
        runs[str(d)]["max_batch"] = ns.max_batch

    base = runs[str(runnable[0])]
    payload = {
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "kv_layout": "paged",
        "per_device_batch": per_dev_batch,
        "device_counts": runnable,
        "runs": runs,
        # Aggregate throughput relative to the smallest mesh — the
        # artifact the acceptance criterion reads (modeled AND measured
        # tok/s scaling vs device count).
        "scaling": {
            "baseline_devices": runnable[0],
            "measured_tok_s": {
                str(d): safe_div(runs[str(d)]["measured_tok_s"],
                                 base["measured_tok_s"])
                for d in runnable
            },
            "modeled_tok_s": {
                str(d): safe_div(runs[str(d)]["modeled_tok_s"],
                                 base["modeled_tok_s"])
                for d in runnable
            },
        },
    }
    path = write_json_artifact("loadgen_sharded", payload,
                               dirpath=args.out_dir or None,
                               kind="loadgen")
    print("[loadgen] sharded scaling (vs "
          f"{runnable[0]} device(s)):")
    for d in runnable:
        r = runs[str(d)]
        print(f"  {d}dev: measured {r['measured_tok_s']:.1f} tok/s "
              f"(x{payload['scaling']['measured_tok_s'][str(d)]:.2f}), "
              f"modeled {r['modeled_tok_s']:.0f} tok/s "
              f"(x{payload['scaling']['modeled_tok_s'][str(d)]:.2f})")
    print(f"[loadgen] wrote {path}")
    payload["_artifacts"] = {"json": path}
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: smoke model, both KV layouts, assert "
                         "artifacts + latency coverage")
    ap.add_argument("--kv-layout", choices=("auto", "dense", "paged"),
                    default="auto")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--num-pages", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="system-prompt tokens (page-aligned) shared by "
                         "--shared-fraction of requests")
    ap.add_argument("--shared-fraction", type=float, default=0.5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--steps-per-sync", default="1",
                    help="fused decode scan length N: the host syncs "
                         "(flush/schedule/telemetry) once per N tokens; "
                         "'auto' lets the scheduler pick from the live "
                         "batch's modeled tick time")
    ap.add_argument("--mesh", type=int, default=0,
                    help="shard one load run over N devices (1-D 'model' "
                         "mesh, head-sharded KV; 0 = single-device)")
    ap.add_argument("--mesh-sweep", default="",
                    help="comma-separated device counts (e.g. 1,2,4): "
                         "paged scaling sweep with per-device batch "
                         "scaling, writes loadgen_sharded.json")
    ap.add_argument("--per-device-batch", type=int, default=0,
                    help="mesh sweep: decode rows per device "
                         "(max_batch = devices * this; default "
                         "--max-batch)")
    ap.add_argument("--kv-dtype", choices=("fp32", "int8", "fp8"),
                    default="fp32",
                    help="paged pool storage dtype (quantized codes + "
                         "per-page-per-head scales for int8/fp8)")
    ap.add_argument("--host-pool-bytes", type=int, default=0,
                    help="host-DRAM KV tier budget (0 = off); a tiered "
                         "run writes loadgen_tiered.json")
    ap.add_argument("--smoke-tiered", action="store_true",
                    help="CI: one paged run with a device pool too small "
                         "for the workload plus a host tier; asserts "
                         "demotions > 0 with zero preemptions and a "
                         "leak-free close")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default "
                         "artifacts/benchmarks)")
    args = ap.parse_args(argv)

    if args.mesh_sweep:
        run_sharded_sweep(args)
        return

    if args.smoke_tiered:
        # Tiered acceptance: the device pool is (deliberately) too small
        # for the workload's working set, the host tier takes the spill.
        # The run must finish everything, demote real pages, and reclaim
        # capacity through demotion INSTEAD of preemption — then prove
        # the pool drained leak-free (run_one's engine.close()).
        if not args.host_pool_bytes:
            args.host_pool_bytes = 1 << 20
        payload = run_one(args, "paged")
        _smoke_check(payload)
        pf = payload["prefix"]
        assert pf["demoted_pages"] > 0, pf
        assert payload["preemptions"] == 0, (
            "capacity pressure should resolve by demotion, not preemption",
            payload["preemptions"])
        print(f"[loadgen] tiered smoke OK: {int(pf['demoted_pages'])} "
              f"demoted / {int(pf['promoted_pages'])} promoted, "
              f"0 preemptions, leak-free close")
        return

    if args.smoke:
        # Both layouts x N in {1, 8}: the fused-decode acceptance sweep.
        # Per (layout, N) run the standard smoke checks apply; across N
        # the N=8 run must hold the tentpole's guarantees — zero decode
        # retraces after warmup and strictly lower per-token host
        # overhead than the N=1 baseline.
        sweep: Dict[str, Dict[int, Dict]] = {}
        for layout in ("dense", "paged"):
            sweep[layout] = {}
            for n in (1, 8):
                args.steps_per_sync = n
                payload = run_one(args, layout)
                _smoke_check(payload)
                if n > 1:
                    assert payload["decode_retraces_after_warmup"] == 0, (
                        layout, n, payload["decode_retraces_after_warmup"])
                sweep[layout][n] = payload
            base, fused = sweep[layout][1], sweep[layout][8]
            assert (fused["host_overhead_per_token_s"]
                    < base["host_overhead_per_token_s"]), (
                layout, base["host_overhead_per_token_s"],
                fused["host_overhead_per_token_s"])
        overhead = {
            layout: {
                f"n{n}": {
                    "host_overhead_per_token_s":
                        p["host_overhead_per_token_s"],
                    "modeled_host_overhead_s": p["modeled_host_overhead_s"],
                    "measured_tok_s": p["measured_tok_s"],
                    "tokens_generated": p["tokens_generated"],
                    "decode_retraces_after_warmup":
                        p["decode_retraces_after_warmup"],
                }
                for n, p in by_n.items()
            }
            for layout, by_n in sweep.items()
        }
        path = write_json_artifact(
            "loadgen_host_overhead", overhead,
            dirpath=args.out_dir or None, kind="loadgen",
        )
        print(f"[loadgen] wrote {path}")
        print("[loadgen] smoke OK (dense + paged, N in {1, 8})")
    else:
        run_one(args, args.kv_layout)


if __name__ == "__main__":
    main()
