"""Trace-driven SLO load harness over the ``LLMEngine`` facade (PR 7).

Open-loop load generation with the full telemetry stack attached:

  * **Arrivals** are Poisson — exponential inter-arrival gaps at
    ``--rate`` requests/s, cumulative-summed into a wall-clock schedule.
    The driver releases each request when its arrival time passes, steps
    the engine continuously while it has work, and sleeps to the next
    arrival when idle — so queueing delay is *measured*, not simulated.
  * **Workload mix**: prompt lengths and output budgets are drawn from
    weighted mixes, and a configurable fraction of requests shares a
    system-prompt prefix (page-aligned, so the paged backend's prefix
    cache gets real hits).
  * **Warmup**: a pilot batch runs to completion first (compiling every
    prefill bucket the mix can hit), ``jax.block_until_ready`` drains the
    device, and ``engine.reset_metrics()`` zeroes telemetry — measured
    numbers never include compilation.
  * **SLO metrics**: TTFT / ITL p50/p90/p99 from the tracer's lifecycle
    events (exact per-request timestamps, not averages), measured
    decode tok/s vs the analytic model's prediction, preemption and
    prefix-hit counters.
  * **Artifacts**: ``artifacts/benchmarks/loadgen_<layout>.json`` (the
    ``repro.obs`` envelope, with the full metrics snapshot riding along)
    and ``loadgen_<layout>_trace.json`` — a Chrome ``trace_event`` file;
    load it at https://ui.perfetto.dev. The model-vs-measured drift
    table (ROADMAP 5(b)) prints and lands in the JSON payload.

Run:
  PYTHONPATH=src python -m repro.launch.loadgen --smoke
      # CI: both KV layouts on the smoke model (Pallas in interpret
      # mode on CPU), asserts artifacts + latency coverage
  PYTHONPATH=src python -m repro.launch.loadgen --arch llama3-8b \
      --kv-layout paged --requests 64 --rate 32
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import registry
from repro.models import transformer
from repro.obs import Telemetry
from repro.obs.metrics import write_json_artifact
from repro.serving import LLMEngine, Request, SamplingParams

#: (value, weight) mixes the smoke/default workload draws from.
PROMPT_MIX: Tuple[Tuple[int, float], ...] = ((8, 0.5), (24, 0.3), (44, 0.2))
OUTPUT_MIX: Tuple[Tuple[int, float], ...] = ((4, 0.6), (8, 0.3), (12, 0.1))


def _draw(rng, mix) -> int:
    vals, weights = zip(*mix)
    w = np.asarray(weights, np.float64)
    return int(rng.choice(np.asarray(vals), p=w / w.sum()))


def build_workload(
    cfg,
    rng,
    n_requests: int,
    *,
    rate: float,
    prompt_mix=PROMPT_MIX,
    output_mix=OUTPUT_MIX,
    shared_prefix_len: int = 16,
    shared_fraction: float = 0.5,
    temperature: float = 0.0,
) -> List[Tuple[float, Request]]:
    """Poisson-arrival request trace: ``[(arrival_s, Request), ...]``
    sorted by arrival. ``shared_fraction`` of requests start with one
    common system prefix of ``shared_prefix_len`` tokens (page-align it
    to the backend's page size so prefix sharing can actually hit)."""
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    system = rng.integers(1, cfg.vocab, size=(shared_prefix_len,))
    out: List[Tuple[float, Request]] = []
    for i in range(n_requests):
        tail_len = _draw(rng, prompt_mix)
        tail = rng.integers(1, cfg.vocab, size=(tail_len,))
        if shared_prefix_len and rng.random() < shared_fraction:
            prompt = np.concatenate([system, tail])
        else:
            prompt = tail
        out.append((float(arrivals[i]), Request(
            uid=i, prompt=prompt,
            sampling=SamplingParams(
                temperature=temperature,
                max_tokens=_draw(rng, output_mix),
            ),
        )))
    return out


def safe_div(num: float, den: float) -> float:
    return num / den if den else 0.0


def percentiles(values, qs=(50, 90, 99)) -> Dict[str, Optional[float]]:
    if not values:
        return {f"p{q}": None for q in qs}
    arr = np.asarray(values, np.float64)
    return {f"p{q}": float(np.percentile(arr, q)) for q in qs}


def _warmup(engine: LLMEngine, cfg, rng, workload) -> None:
    """Compile every prefill bucket the mix can hit (shared-prefix and
    bare variants), drain the device, zero telemetry."""
    pilots = []
    seen = set()
    for i, (_, req) in enumerate(workload):
        key = len(req.prompt)
        if key in seen:
            continue
        seen.add(key)
        pilots.append(Request(
            uid=10_000_000 + i, prompt=np.array(req.prompt),
            sampling=SamplingParams(max_tokens=2),
        ))
    engine.generate(pilots)
    jax.block_until_ready(engine.backend.caches)
    # Warmup requests stay in the completion history (uids >= 10_000_000)
    # but every measured counter/span/drift sample restarts here.
    engine.reset_metrics()


def drive(engine: LLMEngine, workload, *, idle_sleep_cap: float = 0.01):
    """Open-loop drive: release requests at their arrival times, step
    while the engine has work, sleep to the next arrival when idle.
    Returns the finished ``RequestOutput`` list."""
    pending = sorted(workload, key=lambda a: a[0])
    done = []
    i = 0
    t0 = time.perf_counter()
    while i < len(pending) or engine.backend.active.any() \
            or engine.scheduler.has_work():
        now = time.perf_counter() - t0
        while i < len(pending) and pending[i][0] <= now:
            engine.add_request(pending[i][1])
            i += 1
        if not engine.backend.active.any() and not engine.scheduler.has_work():
            # Idle with future arrivals only: sleep toward the next one.
            time.sleep(min(max(pending[i][0] - now, 0.0), idle_sleep_cap))
            continue
        done.extend(o for o in engine.step() if o.finished)
    return done


def run_one(args, kv_layout: str) -> Dict:
    """One full load run on one KV layout; returns the summary payload
    (also written to ``artifacts/benchmarks/loadgen_<kv_layout>.json``)."""
    get_cfg = (registry.get_smoke_config if args.smoke
               else registry.get_config)
    cfg = get_cfg(args.arch)
    params = transformer.init_model(jax.random.PRNGKey(args.seed), cfg)
    telemetry = Telemetry.create()
    engine = LLMEngine(
        cfg, params,
        kv_layout=kv_layout,
        max_batch=args.max_batch,
        cache_len=args.cache_len,
        num_pages=args.num_pages,
        page_size=args.page_size,
        prompt_buckets=(16, 32, 64),
        telemetry=telemetry,
        # getattr: programmatic callers hand-build the namespace and may
        # predate the flag (tests/test_loadgen.py does).
        steps_per_sync=getattr(args, "steps_per_sync", 1),
    )
    rng = np.random.default_rng(args.seed)
    workload = build_workload(
        cfg, rng, args.requests, rate=args.rate,
        shared_prefix_len=args.shared_prefix,
        shared_fraction=args.shared_fraction,
        temperature=args.temperature,
    )
    _warmup(engine, cfg, rng, workload)
    traces_warm = engine.backend.stats.get("decode_traces", 0)

    t0 = time.perf_counter()
    done = drive(engine, workload)
    wall = time.perf_counter() - t0
    retraces = engine.backend.stats.get("decode_traces", 0) - traces_warm

    lat = telemetry.tracer.request_latencies()
    measured = {uid: d for uid, d in lat.items() if uid < 10_000_000}
    ttft = [d["ttft"] for d in measured.values() if d["ttft"] is not None]
    queue = [d["queue"] for d in measured.values() if d["queue"] is not None]
    itl = [x for d in measured.values() for x in d["itl"]]
    stats = engine.stats()
    prefix = engine.backend.prefix_stats()
    drift = telemetry.drift.report(engine.drift_model_fn())

    from repro.core import perf_model

    # Per-token host overhead: the per-step residual (step wall minus its
    # schedule / flush / decode phases) over the tokens produced — output
    # sync, bookkeeping, span plumbing. This is the once-per-sync tax the
    # fused N-step scan amortizes; flush is excluded because prefill cost
    # (and any in-run compilation) is per-request, not per-token.
    snap = telemetry.metrics.snapshot()
    host_overhead = safe_div(
        stats.elapsed_s - stats.decode_elapsed_s
        - snap["serving_flush_seconds"]["sum"]
        - snap["serving_schedule_seconds"]["sum"],
        stats.tokens_generated,
    )
    payload = {
        "arch": args.arch,
        "smoke": bool(args.smoke),
        "kv_layout": engine.kv_layout,
        "requests": args.requests,
        "finished": len(done),
        "rate_req_s": args.rate,
        "wall_s": wall,
        "steps_per_sync": engine.steps_per_sync,
        "tokens_generated": stats.tokens_generated,
        "measured_tok_s": stats.measured_tok_s,
        "modeled_tok_s": stats.modeled_tok_s,
        "decode_elapsed_s": stats.decode_elapsed_s,
        "host_overhead_per_token_s": host_overhead,
        "modeled_host_overhead_s": perf_model.amortized_host_overhead(
            engine.steps_per_sync
        ),
        "decode_retraces_after_warmup": retraces,
        "ttft_s": percentiles(ttft),
        "itl_s": percentiles(itl),
        "queue_s": percentiles(queue),
        "preemptions": stats.preemptions,
        "resumed_tokens": stats.resumed_tokens,
        "prefix": prefix,
        "occupancy_cap": stats.occupancy_cap,
        "drift": drift.to_dict(),
        "drift_worst_ratio": drift.worst_ratio(),
    }
    out_dir = args.out_dir or None
    # N > 1 runs get their own artifact name so the N-sweep (smoke's
    # host-overhead comparison) never clobbers the N=1 baseline.
    n = engine.steps_per_sync
    stem = f"loadgen_{engine.kv_layout}" + (f"_n{n}" if n > 1 else "")
    json_path = write_json_artifact(
        stem, payload,
        metrics=telemetry.metrics,
        dirpath=out_dir, kind="loadgen",
    )
    trace_dir = out_dir or os.path.dirname(json_path)
    trace_path = telemetry.tracer.write_chrome_trace(
        os.path.join(trace_dir, f"{stem}_trace.json")
    )
    payload["_artifacts"] = {"json": json_path, "trace": trace_path}

    def ms(d):
        return " / ".join(
            "n/a" if d[f"p{q}"] is None else f"{d[f'p{q}'] * 1e3:.1f}ms"
            for q in (50, 90, 99)
        )

    print(f"[loadgen:{engine.kv_layout}] {len(done)}/{args.requests} "
          f"finished in {wall:.2f}s at rate {args.rate}/s "
          f"(steps_per_sync={engine.steps_per_sync})")
    print(f"  host overhead {host_overhead * 1e6:.1f}us/token "
          f"(modeled {payload['modeled_host_overhead_s'] * 1e6:.1f}us), "
          f"{retraces} decode retraces after warmup")
    print(f"  TTFT p50/p90/p99: {ms(payload['ttft_s'])}")
    print(f"  ITL  p50/p90/p99: {ms(payload['itl_s'])}")
    print(f"  measured {stats.measured_tok_s:.1f} tok/s (decode wall "
          f"{stats.decode_elapsed_s:.2f}s), modeled "
          f"{stats.modeled_tok_s:.0f} tok/s")
    hit = prefix.get("prefix_hit_rate")
    print(f"  preemptions {stats.preemptions} "
          f"({stats.resumed_tokens} tokens resumed), prefix hit "
          f"{'n/a' if hit is None else f'{hit:.2f}'}")
    print("  " + drift.render().replace("\n", "\n  "))
    print(f"  wrote {json_path}")
    print(f"  wrote {trace_path} (open in https://ui.perfetto.dev)")
    engine.close()
    return payload


def _smoke_check(payload: Dict) -> None:
    """CI acceptance for one layout's run."""
    import json

    assert payload["finished"] == payload["requests"], payload
    assert payload["ttft_s"]["p50"] is not None, "no TTFT measured"
    assert payload["itl_s"]["p99"] is not None, "no ITL measured"
    assert payload["measured_tok_s"] > 0, "no measured throughput"
    assert payload["drift"]["rows"], "no drift cells recorded"
    with open(payload["_artifacts"]["trace"]) as f:
        trace = json.load(f)
    assert trace["traceEvents"], "empty Chrome trace"
    phases = {e["ph"] for e in trace["traceEvents"]}
    assert {"X", "M", "i", "b", "e"} <= phases, phases
    with open(payload["_artifacts"]["json"]) as f:
        env = json.load(f)
    assert env["schema"] == "repro.obs/v1", env["schema"]
    assert env["metrics"]["serving_steps_total"]["value"] > 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="CI: smoke model, both KV layouts, assert "
                         "artifacts + latency coverage")
    ap.add_argument("--kv-layout", choices=("auto", "dense", "paged"),
                    default="auto")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="Poisson arrival rate, requests/s")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--num-pages", type=int, default=96)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--shared-prefix", type=int, default=16,
                    help="system-prompt tokens (page-aligned) shared by "
                         "--shared-fraction of requests")
    ap.add_argument("--shared-fraction", type=float, default=0.5)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--steps-per-sync", type=int, default=1,
                    help="fused decode scan length N: the host syncs "
                         "(flush/schedule/telemetry) once per N tokens")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default "
                         "artifacts/benchmarks)")
    args = ap.parse_args(argv)

    if args.smoke:
        # Both layouts x N in {1, 8}: the fused-decode acceptance sweep.
        # Per (layout, N) run the standard smoke checks apply; across N
        # the N=8 run must hold the tentpole's guarantees — zero decode
        # retraces after warmup and strictly lower per-token host
        # overhead than the N=1 baseline.
        sweep: Dict[str, Dict[int, Dict]] = {}
        for layout in ("dense", "paged"):
            sweep[layout] = {}
            for n in (1, 8):
                args.steps_per_sync = n
                payload = run_one(args, layout)
                _smoke_check(payload)
                if n > 1:
                    assert payload["decode_retraces_after_warmup"] == 0, (
                        layout, n, payload["decode_retraces_after_warmup"])
                sweep[layout][n] = payload
            base, fused = sweep[layout][1], sweep[layout][8]
            assert (fused["host_overhead_per_token_s"]
                    < base["host_overhead_per_token_s"]), (
                layout, base["host_overhead_per_token_s"],
                fused["host_overhead_per_token_s"])
        overhead = {
            layout: {
                f"n{n}": {
                    "host_overhead_per_token_s":
                        p["host_overhead_per_token_s"],
                    "modeled_host_overhead_s": p["modeled_host_overhead_s"],
                    "measured_tok_s": p["measured_tok_s"],
                    "tokens_generated": p["tokens_generated"],
                    "decode_retraces_after_warmup":
                        p["decode_retraces_after_warmup"],
                }
                for n, p in by_n.items()
            }
            for layout, by_n in sweep.items()
        }
        path = write_json_artifact(
            "loadgen_host_overhead", overhead,
            dirpath=args.out_dir or None, kind="loadgen",
        )
        print(f"[loadgen] wrote {path}")
        print("[loadgen] smoke OK (dense + paged, N in {1, 8})")
    else:
        run_one(args, args.kv_layout)


if __name__ == "__main__":
    main()
