"""Post-compile HLO introspection: collective bytes, roofline terms.

``cost_analysis()`` gives per-device FLOPs and memory-traffic bytes but no
collective breakdown, so collective bytes are extracted from the compiled
HLO text: for every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op we sum the result-shape bytes (operand-size proxy; for
all-reduce in==out, for all-gather it is the post-gather size — the wire
cost upper bound on a ring).

Roofline terms (per step, per chip — TPU v5e constants from the brief):
    compute    = flops / PEAK_FLOPS
    memory     = bytes_accessed / HBM_BW
    collective = collective_bytes / LINK_BW
"""

from __future__ import annotations

import re
from typing import Dict

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / ICI link

COLLECTIVE_OPS = (
    "all-reduce",
    "all-gather",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(sig: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result bytes per collective kind + grand total."""
    out = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        lhs, rhs = stripped.split("=", 1)
        rhs = rhs.strip()
        for op in COLLECTIVE_OPS:
            # match ' op(' or ' op-start(' after the result signature
            m = re.match(rf"((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s+{op}(?:-start)?\(", rhs)
            if m:
                out[op] += _shape_bytes(m.group(1))
                break
    out["total"] = sum(out[k] for k in COLLECTIVE_OPS)
    return out


def roofline_terms(
    flops: float, bytes_accessed: float, coll_bytes: float
) -> Dict[str, float]:
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_accessed / HBM_BW,
        "collective_s": coll_bytes / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom.replace("_s", "")
    terms["bound_s"] = terms[dom]
    return terms
