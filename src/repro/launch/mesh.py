"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod v5e-256 is (data=16, model=16); multi-pod is
(pod=2, data=16, model=16) = 512 chips, with the pod axis carrying pure DP.

The model axis size 16 divides (or is divided by) every assigned arch's KV
head count under ACC-aligned placement (core/placement.py); elastic.py picks
alternative shapes for other chip counts.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AXIS_AUTO,) * len(axes)
    )


def make_host_mesh() -> Mesh:
    """Whatever this host offers (tests / examples): (data=N, model=1)."""
    n = len(jax.devices())
    return compat.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(compat.AXIS_AUTO, compat.AXIS_AUTO),
    )
