"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): single-pod v5e-256 is (data=16, model=16); multi-pod is
(pod=2, data=16, model=16) = 512 chips, with the pod axis carrying pure DP.

The model axis size 16 divides (or is divided by) every assigned arch's KV
head count under ACC-aligned placement (core/placement.py); elastic.py picks
alternative shapes for other chip counts.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

from repro import compat


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat.make_mesh(
        shape, axes, axis_types=(compat.AXIS_AUTO,) * len(axes)
    )


def make_host_mesh() -> Mesh:
    """Whatever this host offers (tests / examples): (data=N, model=1)."""
    n = len(jax.devices())
    return compat.make_mesh(
        (n, 1), ("data", "model"),
        axis_types=(compat.AXIS_AUTO, compat.AXIS_AUTO),
    )


def make_serving_mesh(num_devices: int | None = None) -> Mesh:
    """1-D serving mesh: ``("model",)`` over the first ``num_devices``
    host devices (all of them by default).

    The serving engine shards the paged pool's KV-head axis (and the dense
    cache's head axis) over this single axis — head-parallel serving, the
    recursive form of the paper's head -> domain placement. There is no
    data axis: a serving batch is one replica whose KV bytes are spread
    over every device's HBM (``sharding.batch_spec`` then resolves batch
    dims to replicated, which is what keeps single-device and sharded
    decode bit-identical)."""
    devs = jax.devices()
    n = len(devs) if num_devices is None else int(num_devices)
    if n < 1:
        raise ValueError(f"num_devices must be >= 1, got {n}")
    if n > len(devs):
        raise ValueError(
            f"requested {n} devices but the host exposes {len(devs)}"
        )
    return compat.make_mesh(
        (n,), ("model",), axis_types=(compat.AXIS_AUTO,),
        devices=devs[:n],
    )
