"""Mamba-2 (SSD, arXiv:2405.21060) block: chunked scan + recurrent decode.

State space:  h_t = exp(dt_t * A_h) h_{t-1} + dt_t * B_t x_t^T,
              y_t = C_t h_t + D_h x_t
with A_h scalar per head, B/C shared across head groups (GVA), x in
(B, L, H, P) heads x head_dim, state (H, P, N).

The chunked (SSD) form splits L into chunks of Q steps: an intra-chunk
quadratic term (masked (C B^T) against decay), a per-chunk state
contribution, and an inter-chunk linear recurrence over chunk states —
``lax.scan`` over L/Q steps (upgradable to ``associative_scan``; see
EXPERIMENTS §Perf). All matmuls are MXU-shaped einsums.

The paper's attention-scheduling technique does not apply here (attention-
free; no K/V ACCs) — this arch is implemented without it, as required by the
assignment (DESIGN.md §Arch-applicability). The *generalized* insight
(iterate so the shared operand stays resident) still shapes the chunk loop:
head-major layout keeps each head's (P, N) state in registers/VMEM across
the whole sequence scan.
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.configs.base import ModelConfig, SSMConfig
from repro.models import layers


def _dims(d_model: int, cfg: SSMConfig):
    d_in = cfg.expand * d_model
    nheads = cfg.num_heads or d_in // cfg.head_dim
    return d_in, nheads, cfg.num_groups, cfg.state_dim, cfg.conv_width


def init_mamba(key, d_model: int, cfg: SSMConfig) -> dict:
    d_in, h, g, n, w = _dims(d_model, cfg)
    conv_ch = d_in + 2 * g * n
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d_model)
    dt_init = jnp.log(jnp.expm1(jnp.linspace(1e-3, 0.1, h)))  # softplus^-1
    return {
        # order: [z(d_in), x(d_in), B(g*n), C(g*n), dt(h)]
        "win_dm": jax.random.normal(
            ks[0], (d_model, 2 * d_in + 2 * g * n + h), layers.default_dtype()
        ) * s,
        "conv_w": jax.random.normal(ks[1], (w, conv_ch), layers.default_dtype()) * 0.1,
        "conv_b_r": jnp.zeros((conv_ch,), layers.default_dtype()),
        "a_log_r": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(layers.default_dtype()),
        "d_skip_r": jnp.ones((h,), layers.default_dtype()),
        "dt_bias_r": dt_init.astype(layers.default_dtype()),
        "norm": layers.init_rmsnorm(d_in),
        "wout_md": jax.random.normal(ks[2], (d_in, d_model), layers.default_dtype())
        * (1.0 / math.sqrt(d_in)),
    }


def _split_proj(proj, d_in, g, n, h):
    z = proj[..., :d_in]
    xbc = proj[..., d_in : 2 * d_in + 2 * g * n]
    dt = proj[..., 2 * d_in + 2 * g * n :]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (B, L, C) with kernel (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xbc.shape[1], :] * w[i][None, None, :] for i in range(width)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssd_chunked(
    x: jnp.ndarray,      # (B, L, H, P) pre-scaled inputs
    dt: jnp.ndarray,     # (B, L, H) positive step sizes
    a: jnp.ndarray,      # (H,) negative decay rates
    b_mat: jnp.ndarray,  # (B, L, G, N)
    c_mat: jnp.ndarray,  # (B, L, G, N)
    chunk: int,
    h0: jnp.ndarray = None,  # (B, H, P, N) initial state
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N))."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        # dt=0 padding: decay exp(0)=1 and zero update leave the state exact.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l_pad = l + pad
    nc = l_pad // q
    f32 = jnp.float32

    xc = x.reshape(bsz, nc, q, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    bc = b_mat.reshape(bsz, nc, q, g, n).astype(f32)
    cc = c_mat.reshape(bsz, nc, q, g, n).astype(f32)
    dtype_in = x.dtype
    bh = jnp.repeat(bc, rep, axis=3)  # (B,nc,q,H,N)
    ch = jnp.repeat(cc, rep, axis=3)

    adt = dtc * a[None, None, None, :]              # (B,nc,q,H) log-decay per step
    acum = jnp.cumsum(adt, axis=2)                  # inclusive cumsum
    xdt = xc * dtc[..., None]                       # dt-scaled input

    # Intra-chunk: Y[i] = sum_{j<=i} C_i.B_j exp(acum_i - acum_j) * xdt_j
    seg = acum[:, :, :, None, :] - acum[:, :, None, :, :]       # (B,nc,i,j,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    # Mask in log space BEFORE exp: above-diagonal seg is positive and
    # exp() overflows to inf, which would poison gradients via inf*0.
    seg = jnp.where(mask[None, None, :, :, None], seg, -1e30)
    decay = jnp.exp(seg)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", ch, bh)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", cb * decay, xdt)

    # Chunk state: S_c = sum_j exp(acum_last - acum_j) * B_j (x) xdt_j
    last = acum[:, :, -1:, :]                                    # (B,nc,1,H)
    decay_to_end = jnp.exp(last - acum)                          # (B,nc,q,H)
    s_c = jnp.einsum("bcjhn,bcjhp->bchpn", bh * decay_to_end[..., None], xdt)

    # Inter-chunk recurrence over chunk states.
    chunk_decay = jnp.exp(last[:, :, 0, :])                      # (B,nc,H)

    def step(hprev, inp):
        dec, s = inp  # dec (B,H), s (B,H,P,N)
        hnew = hprev * dec[:, :, None, None] + s
        return hnew, hprev  # emit state *entering* the chunk

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)
    hT, h_in = jax.lax.scan(
        step, h0.astype(f32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_c, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                              # (B,nc,H,P,N)

    # Inter-chunk output: C_i exp(acum_i) h_in
    y_inter = jnp.einsum("bcihn,bchpn->bcihp", ch * jnp.exp(acum)[..., None], h_in)

    y = (y_intra + y_inter).reshape(bsz, l_pad, h, p)[:, :l]
    return y.astype(dtype_in), hT


def ssd_recurrent_ref(x, dt, a, b_mat, c_mat, h0=None):
    """O(L) exact recurrence — the test oracle for ssd_chunked."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    f32 = jnp.float32
    bh = jnp.repeat(b_mat, rep, axis=2).astype(f32)
    ch = jnp.repeat(c_mat, rep, axis=2).astype(f32)
    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)

    def step(hprev, t):
        dec = jnp.exp(dt[:, t].astype(f32) * a[None, :])         # (B,H)
        upd = jnp.einsum(
            "bhn,bhp->bhpn", bh[:, t], x[:, t].astype(f32) * dt[:, t, :, None].astype(f32)
        )
        hnew = hprev * dec[:, :, None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", ch[:, t], hnew)
        return hnew, y

    hT, ys = jax.lax.scan(step, h0.astype(f32), jnp.arange(l))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), hT


def _ssd(cfg: SSMConfig):
    """Dispatch the chunked SSD implementation per config."""
    impl = cfg.impl
    if impl == "auto":
        impl = "pallas" if compat.on_tpu() else "xla"
    if impl == "pallas":
        from repro.kernels import ssd as ssd_kernel

        def f(x, dt, a, b_mat, c_mat, chunk, h0=None):
            return ssd_kernel.ssd_chunked_pallas(
                x, dt, a, b_mat, c_mat, chunk, h0=h0,
                interpret=compat.use_interpret(),
            )

        return f
    return ssd_chunked


def mamba_block(params: dict, x: jnp.ndarray, d_model: int, cfg: SSMConfig
                ) -> jnp.ndarray:
    """Full-sequence Mamba-2 block. x: (B, L, D) -> (B, L, D)."""
    d_in, h, g, n, w = _dims(d_model, cfg)
    proj = x @ params["win_dm"].astype(x.dtype)
    z, xbc, dt_raw = _split_proj(proj, d_in, g, n, h)
    xbc = _causal_conv(xbc, params["conv_w"].astype(x.dtype), params["conv_b_r"].astype(x.dtype))
    xs = xbc[..., :d_in].reshape(*x.shape[:2], h, d_in // h)
    b_mat = xbc[..., d_in : d_in + g * n].reshape(*x.shape[:2], g, n)
    c_mat = xbc[..., d_in + g * n :].reshape(*x.shape[:2], g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias_r"].astype(jnp.float32))
    a = -jnp.exp(params["a_log_r"].astype(jnp.float32))
    y, _ = _ssd(cfg)(xs, dt, a, b_mat, c_mat, cfg.chunk)
    y = y + xs * params["d_skip_r"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*x.shape[:2], d_in)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["wout_md"].astype(x.dtype)


def init_mamba_cache(d_model: int, cfg: SSMConfig, batch: int, dtype) -> dict:
    d_in, h, g, n, w = _dims(d_model, cfg)
    return {
        "conv": jnp.zeros((batch, w - 1, d_in + 2 * g * n), dtype),
        "ssm": jnp.zeros((batch, h, d_in // h, n), jnp.float32),
    }


def mamba_decode(params: dict, x: jnp.ndarray, d_model: int, cfg: SSMConfig,
                 cache: dict) -> Tuple[jnp.ndarray, dict]:
    """One-token step. x: (B, 1, D)."""
    d_in, h, g, n, w = _dims(d_model, cfg)
    bsz = x.shape[0]
    proj = x[:, 0] @ params["win_dm"].astype(x.dtype)             # (B, ...)
    z, xbc, dt_raw = _split_proj(proj, d_in, g, n, h)
    # conv over [cache, new]
    hist = jnp.concatenate([cache["conv"], xbc[:, None, :]], axis=1)  # (B, w, C)
    wgt = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwc,wc->bc", hist, wgt) + params["conv_b_r"].astype(x.dtype)
    xbc = jax.nn.silu(conv_out)
    xs = xbc[:, :d_in].reshape(bsz, h, d_in // h)
    b_mat = xbc[:, d_in : d_in + g * n].reshape(bsz, g, n)
    c_mat = xbc[:, d_in + g * n :].reshape(bsz, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias_r"].astype(jnp.float32))
    a = -jnp.exp(params["a_log_r"].astype(jnp.float32))
    rep = h // g
    bh = jnp.repeat(b_mat, rep, axis=1).astype(jnp.float32)
    ch = jnp.repeat(c_mat, rep, axis=1).astype(jnp.float32)
    dec = jnp.exp(dt * a[None, :])
    upd = jnp.einsum("bhn,bhp->bhpn", bh, xs.astype(jnp.float32) * dt[..., None])
    hnew = cache["ssm"] * dec[:, :, None, None] + upd
    y = jnp.einsum("bhn,bhpn->bhp", ch, hnew).astype(x.dtype)
    y = y + xs * params["d_skip_r"].astype(y.dtype)[None, :, None]
    y = y.reshape(bsz, 1, d_in)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z[:, None, :]))
    out = y @ params["wout_md"].astype(x.dtype)
    return out, {"conv": hist[:, 1:], "ssm": hnew}
