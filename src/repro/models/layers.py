"""Core layers: norms, rotary embeddings, MLPs, embeddings.

Functional style: every layer is an ``init_*(key, ...) -> params`` plus an
``apply`` function over a plain-dict pytree. No flax dependency — parameters
stack cleanly along a leading axis for ``lax.scan``-over-layers, and
PartitionSpecs attach by tree path (distributed/sharding.py).

Naming convention for sharding rules: weight dict keys end in semantic tags
(``_dm`` model-sharded on dim -1, ``_md`` model-sharded on dim 0, ``_r``
replicated); see ``distributed.sharding.spec_for_path``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def default_dtype():
    return jnp.float32  # params kept in f32; compute dtype set per-model


# -----------------------------------------------------------------------------
# Norms
# -----------------------------------------------------------------------------


def init_rmsnorm(dim: int) -> dict:
    return {"scale_r": jnp.zeros((dim,), default_dtype())}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # Gemma-style (1 + scale): zero-init is identity.
    return (x * (1.0 + params["scale_r"].astype(jnp.float32))).astype(dtype)


# -----------------------------------------------------------------------------
# Rotary position embeddings
# -----------------------------------------------------------------------------


def rotary_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rotary(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0
) -> jnp.ndarray:
    """x: (B, H, S, D); positions: (B, S) or (S,) absolute positions."""
    d = x.shape[-1]
    freqs = rotary_freqs(d, theta)
    if positions.ndim == 1:
        positions = positions[None, :]
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(angles)[:, None]  # (B, 1, S, D/2)
    sin = jnp.sin(angles)[:, None]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -----------------------------------------------------------------------------
# Dense / gated MLP
# -----------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(d_ff)
    return {
        "wi_gate_dm": jax.random.normal(k1, (d_model, d_ff), default_dtype()) * s_in,
        "wi_up_dm": jax.random.normal(k2, (d_model, d_ff), default_dtype()) * s_in,
        "wo_md": jax.random.normal(k3, (d_ff, d_model), default_dtype()) * s_out,
    }


def mlp(params: dict, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    dtype = x.dtype
    gate = x @ params["wi_gate_dm"].astype(dtype)
    up = x @ params["wi_up_dm"].astype(dtype)
    act = _activate(gate, activation)
    return (act * up) @ params["wo_md"].astype(dtype)


def _activate(x, name: str):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu":
        return jax.nn.relu(x)
    raise ValueError(name)


# -----------------------------------------------------------------------------
# Embedding / unembedding
# -----------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int) -> dict:
    # 1/sqrt(d) keeps tied-unembedding logits O(1) at init.
    scale = d_model**-0.5
    return {
        "table_vd": jax.random.normal(key, (vocab, d_model), default_dtype()) * scale
    }


def embed(params: dict, tokens: jnp.ndarray, *, scale_by_dim: bool = False,
          compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    x = jnp.take(params["table_vd"], tokens, axis=0).astype(compute_dtype)
    if scale_by_dim:
        x = x * jnp.asarray(math.sqrt(params["table_vd"].shape[1]), compute_dtype)
    return x


def unembed(params: dict, x: jnp.ndarray, *, softcap: Optional[float] = None
            ) -> jnp.ndarray:
    """Project to vocab logits (tied table). Returns float32 logits."""
    logits = x.astype(jnp.float32) @ params["table_vd"].astype(jnp.float32).T
    if softcap is not None and softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    return logits


def init_linear(key, d_in: int, d_out: int, tag: str = "dm") -> dict:
    s = 1.0 / math.sqrt(d_in)
    return {f"w_{tag}": jax.random.normal(key, (d_in, d_out), default_dtype()) * s}


def linear(params: dict, x: jnp.ndarray, tag: str = "dm") -> jnp.ndarray:
    return x @ params[f"w_{tag}"].astype(x.dtype)


# -----------------------------------------------------------------------------
# Losses
# -----------------------------------------------------------------------------


def softmax_cross_entropy(
    logits: jnp.ndarray,
    targets: jnp.ndarray,
    mask: Optional[jnp.ndarray] = None,
    *,
    z_loss: float = 0.0,
) -> Tuple[jnp.ndarray, dict]:
    """Mean token cross-entropy in f32 with optional z-loss regularizer.

    logits: (..., V) f32; targets: (...) int32; mask: (...) 0/1.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss > 0.0:
        nll = nll + z_loss * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    mask = mask.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll * mask) / denom
    acc = jnp.sum((jnp.argmax(logits, -1) == targets) * mask) / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}
