"""repro subpackage."""
