"""The model stack: scan-over-periods composition of heterogeneous blocks.

Depth is expressed as ``n_periods`` repetitions of ``cfg.layer_pattern`` plus
an unrolled remainder, so compile time is O(|pattern|), not O(n_layers) —
llama3-405b's 126 layers compile one body. Within a period each position has
a static ``LayerSpec`` (attn/mamba/hybrid x mlp/moe x window x cross), so
heterogeneous stacks (gemma3 5:1 local:global, llama-3.2-vision every-5th
cross-attn) scan cleanly with full static shapes.

Three entry points per model: ``forward`` (training), ``prefill`` (builds KV
caches), ``decode_step`` (one token, cache-threaded). MoE aux losses ride
the scan carry.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.models import attention as attn_lib
from repro.models import layers, moe as moe_lib, ssm as ssm_lib

Params = Dict[str, Any]


# -----------------------------------------------------------------------------
# Per-layer init / apply
# -----------------------------------------------------------------------------


def init_layer(key, cfg: ModelConfig, spec: LayerSpec) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"ln1": layers.init_rmsnorm(cfg.d_model)}
    if spec.kind in ("attn", "hybrid"):
        p["attn"] = attn_lib.init_attention(ks[0], cfg)
    if spec.kind in ("mamba", "hybrid"):
        p["mamba"] = ssm_lib.init_mamba(ks[1], cfg.d_model, cfg.ssm)
    if spec.kind == "hybrid":
        p["ln_attn_out"] = layers.init_rmsnorm(cfg.d_model)
        p["ln_mamba_out"] = layers.init_rmsnorm(cfg.d_model)
    if spec.cross_attn:
        p["ln_cross"] = layers.init_rmsnorm(cfg.d_model)
        p["cross"] = attn_lib.init_attention(ks[2], cfg)
        p["cross_gate_r"] = jnp.zeros((), layers.default_dtype())
    if spec.ffn == "mlp":
        p["ln2"] = layers.init_rmsnorm(cfg.d_model)
        p["mlp"] = layers.init_mlp(ks[3], cfg.d_model, cfg.d_ff)
    elif spec.ffn == "moe":
        p["ln2"] = layers.init_rmsnorm(cfg.d_model)
        p["moe"] = moe_lib.init_moe(ks[4], cfg.d_model, cfg.moe)
    return p


def _zero_aux() -> Dict[str, jnp.ndarray]:
    return {
        "moe_lb_loss": jnp.zeros((), jnp.float32),
        "moe_z_loss": jnp.zeros((), jnp.float32),
        "moe_dropped_frac": jnp.zeros((), jnp.float32),
    }


def apply_layer(
    p: Params,
    x: jnp.ndarray,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    mode: str,                   # "train" | "prefill" | "decode"
    cache: Optional[Params] = None,
    lengths: Optional[jnp.ndarray] = None,
    positions: Optional[jnp.ndarray] = None,
    encoder_states: Optional[jnp.ndarray] = None,
    cache_len: int = 0,
    page_table: Optional[jnp.ndarray] = None,
    prefix_len: Optional[jnp.ndarray] = None,
    plan=None,
    shard_moe=lambda t: t,
) -> Tuple[jnp.ndarray, Optional[Params], Dict[str, jnp.ndarray]]:
    """Returns (x, new_cache, aux).

    In decode mode a cache holding ``k_pages`` routes through the paged
    decode path (``page_table`` required). In prefill mode a non-None
    ``cache`` holds the *paged* K/V pool of an already-prefilled shared
    prefix (prefix-extension prefill): ``page_table`` names its pages,
    ``prefix_len`` (B,) its live length, ``lengths`` (B,) the live tail
    length, and ``plan`` the engine-resolved
    :class:`~repro.kernels.plan.AttentionPlan` for the extend phase.
    """
    aux = _zero_aux()
    h = layers.rmsnorm(p["ln1"], x)
    new_cache: Params = {}

    def run_attn():
        if mode == "train":
            return attn_lib.attention_block(
                p["attn"], h, cfg, spec, positions=positions,
            ), None
        if mode == "prefill":
            c = None if cache is None else cache.get("attn")
            if c is not None:
                if "k_pages" not in c:
                    # Falling through to plain prefill would silently drop
                    # the prefix; the dense prefix_kv route was removed in
                    # favor of the paged prefill kernel.
                    raise ValueError(
                        "prefill-mode prefix caches must be paged "
                        "(k_pages/v_pages pools)"
                    )
                return attn_lib.attention_prefill_paged(
                    p["attn"], h, cfg, spec, c, page_table, prefix_len,
                    lengths, cache_len=cache_len, positions=positions,
                    plan=plan,
                )
            return attn_lib.attention_prefill(
                p["attn"], h, cfg, spec, cache_len=cache_len, positions=positions,
            )
        if cache is not None and "k_pages" in cache["attn"]:
            return attn_lib.attention_decode_paged(
                p["attn"], h, cfg, spec, cache["attn"], page_table, lengths,
            )
        return attn_lib.attention_decode(
            p["attn"], h, cfg, spec, cache["attn"], lengths,
        )

    if spec.kind == "attn":
        y, c = run_attn()
        if c is not None:
            new_cache["attn"] = c
        x = x + y
    elif spec.kind == "mamba":
        if mode in ("train", "prefill"):
            y = ssm_lib.mamba_block(p["mamba"], h, cfg.d_model, cfg.ssm)
            if mode == "prefill":
                # Re-run final state via chunked scan is already inside; for
                # prefill we need the cache: recompute cheaply in decode form
                # is wasteful — mamba_block_with_cache returns it.
                y, c = _mamba_with_cache(p["mamba"], h, cfg)
                new_cache["mamba"] = c
        else:
            y, c = ssm_lib.mamba_decode(p["mamba"], h, cfg.d_model, cfg.ssm, cache["mamba"])
            new_cache["mamba"] = c
        x = x + y
    elif spec.kind == "hybrid":
        ya, c = run_attn()
        if c is not None:
            new_cache["attn"] = c
        if mode in ("train",):
            ym = ssm_lib.mamba_block(p["mamba"], h, cfg.d_model, cfg.ssm)
        elif mode == "prefill":
            ym, cm = _mamba_with_cache(p["mamba"], h, cfg)
            new_cache["mamba"] = cm
        else:
            ym, cm = ssm_lib.mamba_decode(p["mamba"], h, cfg.d_model, cfg.ssm, cache["mamba"])
            new_cache["mamba"] = cm
        # Hymba: parallel attention + SSM heads, normalized and averaged.
        x = x + 0.5 * (
            layers.rmsnorm(p["ln_attn_out"], ya) + layers.rmsnorm(p["ln_mamba_out"], ym)
        )
    else:
        raise ValueError(spec.kind)

    if spec.cross_attn:
        hc = layers.rmsnorm(p["ln_cross"], x)
        gate = jnp.tanh(p["cross_gate_r"]).astype(x.dtype)
        if mode == "decode":
            yc, cc = attn_lib.attention_decode(
                p["cross"], hc, cfg, spec, cache["cross"], lengths, is_cross=True,
            )
            new_cache["cross"] = cc
        elif mode == "prefill":
            yc, cc = attn_lib.attention_prefill(
                p["cross"], hc, cfg, spec, cache_len=encoder_states.shape[1],
                encoder_states=encoder_states,
            )
            new_cache["cross"] = cc
        else:
            yc = attn_lib.attention_block(
                p["cross"], hc, cfg, spec, encoder_states=encoder_states,
            )
        x = x + gate * yc

    if spec.ffn == "mlp":
        x = x + layers.mlp(p["mlp"], layers.rmsnorm(p["ln2"], x))
    elif spec.ffn == "moe":
        y, moe_aux = moe_lib.moe_ffn(
            p["moe"], layers.rmsnorm(p["ln2"], x), cfg.moe, shard_buffers=shard_moe
        )
        aux = {k: aux[k] + moe_aux[k] for k in aux}
        x = x + y
    return x, (new_cache or None), aux


def _mamba_with_cache(params, h, cfg: ModelConfig):
    """Prefill path for SSM blocks: full-sequence output + decode cache."""
    d_in, nh, g, n, w = ssm_lib._dims(cfg.d_model, cfg.ssm)
    proj = h @ params["win_dm"].astype(h.dtype)
    z, xbc, dt_raw = ssm_lib._split_proj(proj, d_in, g, n, nh)
    xbc_conv = ssm_lib._causal_conv(
        xbc, params["conv_w"].astype(h.dtype), params["conv_b_r"].astype(h.dtype)
    )
    xs = xbc_conv[..., :d_in].reshape(*h.shape[:2], nh, d_in // nh)
    b_mat = xbc_conv[..., d_in : d_in + g * n].reshape(*h.shape[:2], g, n)
    c_mat = xbc_conv[..., d_in + g * n :].reshape(*h.shape[:2], g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias_r"].astype(jnp.float32))
    a = -jnp.exp(params["a_log_r"].astype(jnp.float32))
    y, h_final = ssm_lib._ssd(cfg.ssm)(xs, dt, a, b_mat, c_mat, cfg.ssm.chunk)
    y = y + xs * params["d_skip_r"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(*h.shape[:2], d_in)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    out = y @ params["wout_md"].astype(h.dtype)
    cache = {
        "conv": xbc[:, -(w - 1):, :],  # pre-activation history
        "ssm": h_final,
    }
    return out, cache


# -----------------------------------------------------------------------------
# Model init
# -----------------------------------------------------------------------------


def init_model(key, cfg: ModelConfig) -> Params:
    pattern, rem = cfg.pattern_for_depth()
    n_periods = cfg.n_periods
    ks = jax.random.split(key, 4 + len(rem))
    params: Params = {}
    if cfg.num_codebooks > 1:
        params["embed"] = {
            "table_kvd": jax.random.normal(
                ks[0], (cfg.num_codebooks, cfg.vocab, cfg.d_model),
                layers.default_dtype(),
            ) * cfg.d_model**-0.5
        }
    else:
        params["embed"] = layers.init_embedding(ks[0], cfg.vocab, cfg.d_model)
    if cfg.vision_tokens:
        params["vision_proj"] = layers.init_linear(ks[1], cfg.vision_dim, cfg.d_model)

    # Scanned period stacks: one stacked tree per pattern position.
    stacks = []
    for j, spec in enumerate(pattern):
        per_period = [
            init_layer(jax.random.fold_in(ks[2], p * len(pattern) + j), cfg, spec)
            for p in range(n_periods)
        ]
        stacks.append(jax.tree.map(lambda *xs: jnp.stack(xs), *per_period))
    params["layers"] = tuple(stacks)
    params["layers_rem"] = tuple(
        init_layer(ks[4 + i], cfg, spec) for i, spec in enumerate(rem)
    )
    params["ln_f"] = layers.init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings:
        params["head"] = layers.init_linear(ks[3], cfg.d_model, cfg.vocab)
    return params


def _embed_tokens(params, cfg: ModelConfig, tokens):
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.num_codebooks > 1:
        # tokens: (B, S, K) -> sum of per-codebook embeddings (MusicGen).
        tab = params["embed"]["table_kvd"]
        x = sum(
            jnp.take(tab[k_], tokens[..., k_], axis=0) for k_ in range(cfg.num_codebooks)
        ).astype(dt)
        return x
    x = layers.embed(params["embed"], tokens, scale_by_dim=cfg.embed_scale, compute_dtype=dt)
    return x


def _logits(params, cfg: ModelConfig, x):
    if cfg.num_codebooks > 1:
        tab = params["embed"]["table_kvd"]  # (K, V, D)
        logits = jnp.einsum(
            "bsd,kvd->bskv", x.astype(jnp.float32), tab.astype(jnp.float32)
        )
    elif cfg.tie_embeddings:
        logits = layers.unembed(params["embed"], x, softcap=cfg.final_softcap)
        return logits
    else:
        logits = x.astype(jnp.float32) @ params["head"]["w_dm"].astype(jnp.float32)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return logits


# -----------------------------------------------------------------------------
# Forward passes
# -----------------------------------------------------------------------------


def _run_stack(
    params, cfg: ModelConfig, x, *, mode, caches=None, lengths=None,
    positions=None, encoder_states=None, cache_len=0, page_table=None,
    prefix_len=None, plan=None, shard_moe=lambda t: t, remat: bool = False,
):
    pattern, rem = cfg.pattern_for_depth()
    aux_tot = _zero_aux()

    def period_body(carry, xs_cache):
        x, aux = carry
        stacked_params, period_caches = xs_cache
        new_caches = []
        for j, spec in enumerate(pattern):
            c_j = None if period_caches is None else period_caches[j]
            x, nc, a = apply_layer(
                stacked_params[j], x, cfg, spec, mode=mode, cache=c_j,
                lengths=lengths, positions=positions,
                encoder_states=encoder_states, cache_len=cache_len,
                page_table=page_table, prefix_len=prefix_len, plan=plan,
                shard_moe=shard_moe,
            )
            new_caches.append(nc)
            aux = {k: aux[k] + a[k] for k in aux}
        out_caches = tuple(new_caches) if any(c is not None for c in new_caches) else None
        return (x, aux), out_caches

    body = period_body
    if remat and mode == "train":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else None  # default: nothing saveable — recompute the period
        )
        body = jax.checkpoint(period_body, policy=policy)

    period_caches = caches["scanned"] if caches else None
    xs = (params["layers"], period_caches)
    (x, aux_tot), new_scanned = jax.lax.scan(
        body, (x, aux_tot), xs, unroll=cfg.scan_unroll
    )

    new_rem = []
    rem_caches = caches["rem"] if caches else None
    for i, spec in enumerate(rem):
        c_i = None if rem_caches is None else rem_caches[i]
        x, nc, a = apply_layer(
            params["layers_rem"][i], x, cfg, spec, mode=mode, cache=c_i,
            lengths=lengths, positions=positions, encoder_states=encoder_states,
            cache_len=cache_len, page_table=page_table, prefix_len=prefix_len,
            plan=plan, shard_moe=shard_moe,
        )
        new_rem.append(nc)
        aux_tot = {k: aux_tot[k] + a[k] for k in aux_tot}
    new_caches = None
    if mode in ("prefill", "decode"):
        new_caches = {"scanned": new_scanned, "rem": tuple(new_rem)}
    return x, new_caches, aux_tot


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    image_embeds: Optional[jnp.ndarray] = None,
    remat: bool = True,
    shard_moe=lambda t: t,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Training forward: tokens (B,S[,K]) -> logits (B,S,V[,K]), aux."""
    x = _embed_tokens(params, cfg, tokens)
    enc = None
    if cfg.vision_tokens and image_embeds is not None:
        enc = layers.linear(params["vision_proj"], image_embeds.astype(x.dtype))
    x, _, aux = _run_stack(
        params, cfg, x, mode="train", encoder_states=enc, shard_moe=shard_moe,
        remat=remat,
    )
    x = layers.rmsnorm(params["ln_f"], x)
    return _logits(params, cfg, x), aux


def prefill(
    params: Params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,
    *,
    cache_len: int,
    image_embeds: Optional[jnp.ndarray] = None,
    last_positions: Optional[jnp.ndarray] = None,
    prefix_caches: Optional[Params] = None,
    page_table: Optional[jnp.ndarray] = None,
    prefix_len: Optional[jnp.ndarray] = None,
    plan=None,
    shard_moe=lambda t: t,
) -> Tuple[jnp.ndarray, Params]:
    """Prefill: returns (logits at the last real position (B,V[,K]), caches).

    ``last_positions`` (B,): per-sequence index of the final prompt token
    (for right-padded prompts); defaults to S-1. Only one position's logits
    are materialized — at prefill_32k scale the full (B, S, V) tensor would
    be hundreds of GB.

    ``prefix_caches`` + ``page_table`` + ``prefix_len``: prefix-extension
    prefill. ``tokens`` holds only the tail; each attention layer
    additionally attends the shared prefix's K/V **in place in its pages**
    (``prefix_caches`` is the paged pool tree, ``page_table`` (B, pages)
    names the prefix's pages, ``prefix_len`` (B,) its live token count —
    dynamic, so one compilation serves every prefix length in a page
    bucket). ``plan`` is the caller-resolved extend-phase
    :class:`~repro.kernels.plan.AttentionPlan` (None lets each layer
    resolve its own). The returned caches cover the tail only — the caller
    owns where tail K/V physically lands
    (``serving.engine.PagedServingEngine`` scatters it into fresh pages).
    """
    x = _embed_tokens(params, cfg, tokens)
    enc = None
    if cfg.vision_tokens and image_embeds is not None:
        enc = layers.linear(params["vision_proj"], image_embeds.astype(x.dtype))
    positions = None
    tail_len = None
    if prefix_caches is not None:
        if page_table is None or prefix_len is None:
            raise ValueError(
                "prefix-extension prefill needs page_table and prefix_len"
            )
        b, s = tokens.shape[:2]
        positions = prefix_len[:, None] + jnp.arange(s)[None, :]
        tail_len = (
            last_positions + 1 if last_positions is not None
            else jnp.full((b,), s, jnp.int32)
        )
    x, caches, _ = _run_stack(
        params, cfg, x, mode="prefill", encoder_states=enc,
        cache_len=cache_len, caches=prefix_caches, lengths=tail_len,
        positions=positions, page_table=page_table, prefix_len=prefix_len,
        plan=plan, shard_moe=shard_moe,
    )
    if last_positions is None:
        x = x[:, -1:]
    else:
        x = jnp.take_along_axis(x, last_positions[:, None, None], axis=1)
    x = layers.rmsnorm(params["ln_f"], x)
    return _logits(params, cfg, x)[:, 0], caches


def decode_step(
    params: Params,
    cfg: ModelConfig,
    token: jnp.ndarray,            # (B,) or (B, K)
    caches: Params,
    lengths: jnp.ndarray,          # (B,) length INCLUDING the new token
    *,
    page_table: Optional[jnp.ndarray] = None,
    shard_moe=lambda t: t,
) -> Tuple[jnp.ndarray, Params]:
    """One decode step: returns (logits (B,V[,K]), updated caches).

    ``page_table`` (B, max_pages): required when ``caches`` are paged
    (``init_paged_caches``); ignored for dense caches."""
    tok = token[:, None] if token.ndim == 1 else token[:, None, :]
    x = _embed_tokens(params, cfg, tok)
    x, new_caches, _ = _run_stack(
        params, cfg, x, mode="decode", caches=caches, lengths=lengths,
        page_table=page_table, shard_moe=shard_moe,
    )
    x = layers.rmsnorm(params["ln_f"], x)
    return _logits(params, cfg, x)[:, 0], new_caches


def init_caches(params: Params, cfg: ModelConfig, batch: int, cache_len: int,
                image_len: int = 0) -> Params:
    """Zero caches with the same tree structure prefill would emit."""
    dt = jnp.dtype(cfg.compute_dtype)
    pattern, rem = cfg.pattern_for_depth()

    def one(spec: LayerSpec):
        c = {}
        if spec.kind in ("attn", "hybrid"):
            c["attn"] = attn_lib.init_cache(cfg, batch, cache_len, dt)
        if spec.kind in ("mamba", "hybrid"):
            c["mamba"] = ssm_lib.init_mamba_cache(cfg.d_model, cfg.ssm, batch, dt)
        if spec.cross_attn:
            c["cross"] = attn_lib.init_cache(cfg, batch, max(image_len, 1), dt)
        return c or None

    scanned = tuple(
        jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), one(spec)
        )
        for spec in pattern
    )
    return {"scanned": scanned, "rem": tuple(one(s) for s in rem)}


def init_paged_caches(
    params: Params, cfg: ModelConfig, num_pages: int, page_size: int,
    kv_dtype: str = "fp32",
) -> Params:
    """Paged zero caches: one head-major page pool per attention layer, all
    indexed by the same physical page ids (one allocator drives every
    layer, vLLM-style). Only pure-attention stacks support paging — SSM
    state and cross-attention K/V are not page-structured. ``kv_dtype``
    selects the pool storage format (``cache.quant``): quantized pools
    carry per-(head, page) scale arrays next to the code pools."""
    dt = jnp.dtype(cfg.compute_dtype)
    pattern, rem = cfg.pattern_for_depth()
    for spec in list(pattern) + list(rem):
        if spec.kind != "attn" or spec.cross_attn:
            raise ValueError(
                "paged caches require a pure self-attention stack; "
                f"got layer kind={spec.kind!r} cross_attn={spec.cross_attn}"
            )

    def one(_spec: LayerSpec):
        return {"attn": attn_lib.init_paged_cache(
            cfg, num_pages, page_size, dt, kv_dtype=kv_dtype
        )}

    scanned = tuple(
        jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_periods,) + x.shape), one(spec)
        )
        for spec in pattern
    )
    return {"scanned": scanned, "rem": tuple(one(s) for s in rem)}
