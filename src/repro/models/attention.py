"""Attention blocks: GQA self-attention, cross-attention, decode paths.

The kernel-facing layer of the model stack. The paper's technique enters in
two ways:
  * the ``mapping`` handed to ``kernels.ops.flash_attention`` (grid order /
    KV residency / megacore semantics),
  * head layout: q/k/v projections emit heads in ACC-contiguous order so the
    model-axis shard boundaries coincide with KV groups
    (``core.placement.ACC_ALIGNED``) — KV is never duplicated across shards.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels import ops
from repro.kernels.flash_attention import PAPER_MAPPINGS, MappingConfig
from repro.models import layers


def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(h * hd)
    p = {
        "wq_dm": jax.random.normal(ks[0], (d, h * hd), layers.default_dtype()) * s,
        "wk_dm": jax.random.normal(ks[1], (d, hkv * hd), layers.default_dtype()) * s,
        "wv_dm": jax.random.normal(ks[2], (d, hkv * hd), layers.default_dtype()) * s,
        "wo_md": jax.random.normal(ks[3], (h * hd, d), layers.default_dtype()) * so,
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd)
        p["k_norm"] = layers.init_rmsnorm(hd)
    return p


def _mapping(cfg: ModelConfig) -> Optional[MappingConfig]:
    """Mapping for the kernels: an explicit paper mapping by name, or None
    for ``"auto"`` — ops then resolves the best schedule per call shape via
    ``kernels.ops.resolve_mapping`` (perf-model + HBM-traffic scored)."""
    if cfg.mapping_name == "auto":
        return None
    return PAPER_MAPPINGS[cfg.mapping_name]


def _project_qkv(params, x, cfg: ModelConfig, positions, rope_theta, kv_x=None,
                 rope: bool = True):
    """x: (B, S, D) -> q (B,H,S,hd), k/v (B,Hkv,Skv,hd)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    skv = src.shape[1]
    q = (x @ params["wq_dm"].astype(x.dtype)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (src @ params["wk_dm"].astype(x.dtype)).reshape(b, skv, hkv, hd).transpose(0, 2, 1, 3)
    v = (src @ params["wv_dm"].astype(x.dtype)).reshape(b, skv, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.head_placement == "striped":
        # Naive round-robin head placement (paper baseline): the physical
        # head order emitted by the (sharded) projections is striped across
        # model shards, so regrouping into logical ACC order moves Q and K/V
        # across shards — the pod-scale analogue of the fragmented L2. The
        # permutation gathers land as collectives in the compiled HLO;
        # benchmarks/roofline A/Bs this against acc_aligned.
        from repro.core import placement as placement_lib

        plan = placement_lib.plan(
            h, hkv, cfg.placement_shards, placement_lib.STRIPED
        )
        q = jnp.take(q, jnp.asarray(plan.q_perm), axis=1)
        k = jnp.take(k, jnp.asarray(plan.kv_perm), axis=1)
        v = jnp.take(v, jnp.asarray(plan.kv_perm), axis=1)
    if cfg.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q)
        k = layers.rmsnorm(params["k_norm"], k)
    if rope:
        q = layers.apply_rotary(q, positions, rope_theta)
        k = layers.apply_rotary(k, positions, rope_theta)
    return q, k, v


def attention_block(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: Optional[jnp.ndarray] = None,
    encoder_states: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full-sequence self- (or cross-) attention. x: (B, S, D)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    cross = spec.cross_attn and encoder_states is not None
    q, k, v = _project_qkv(
        params, x, cfg, positions, spec.rope_theta,
        kv_x=encoder_states if cross else None,
        rope=not cross,
    )
    o = ops.flash_attention(
        q, k, v,
        causal=not cross,
        window=None if cross else spec.window,
        softcap=cfg.attn_softcap,
        mapping=_mapping(cfg),
        impl=cfg.attn_impl,
        chunk_unroll=cfg.attn_chunk_unroll,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return o @ params["wo_md"].astype(x.dtype)


def attention_prefill(
    params, x, cfg: ModelConfig, spec: LayerSpec, *, cache_len: int,
    positions=None, encoder_states=None, prefix_kv=None, q_offset: int = 0,
) -> Tuple[jnp.ndarray, dict]:
    """Like attention_block but also returns the populated KV cache
    (padded to ``cache_len``) for subsequent decode steps.

    ``prefix_kv`` (+ static ``q_offset``): prefix-extension prefill — the
    first ``q_offset`` positions were already prefilled by an earlier
    request sharing this prefix (paged engine, ``cache.prefix``); their K/V
    arrives dense-gathered in ``prefix_kv["k"|"v"]: (B, Hkv, q_offset, hd)``
    and only the tail's K/V is computed and returned (the caller scatters it
    into fresh pages). Queries sit at absolute positions ``q_offset + i``.
    """
    b, s, d = x.shape
    if positions is None:
        positions = q_offset + jnp.arange(s)
    cross = spec.cross_attn and encoder_states is not None
    q, k, v = _project_qkv(
        params, x, cfg, positions, spec.rope_theta,
        kv_x=encoder_states if cross else None, rope=not cross,
    )
    k_full, v_full = k, v
    if prefix_kv is not None:
        k_full = jnp.concatenate([prefix_kv["k"].astype(k.dtype), k], axis=2)
        v_full = jnp.concatenate([prefix_kv["v"].astype(v.dtype), v], axis=2)
    o = ops.flash_attention(
        q, k_full, v_full, causal=not cross,
        window=None if cross else spec.window,
        softcap=cfg.attn_softcap, mapping=_mapping(cfg), impl=cfg.attn_impl,
        chunk_unroll=cfg.attn_chunk_unroll, q_offset=q_offset,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    pad = cache_len - k.shape[2]
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
    }
    return o @ params["wo_md"].astype(x.dtype), cache


def attention_decode(
    params, x, cfg: ModelConfig, spec: LayerSpec, cache: dict, lengths: jnp.ndarray,
    *, is_cross: bool = False,
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, Hkv, Smax, hd);
    lengths: (B,) prefix length *including* the new token. ``is_cross``:
    the cache holds static encoder (image) K/V — read-only."""
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if is_cross:
        # Cross-attn KV is static (image tokens): cache holds it untouched.
        q = (x @ params["wq_dm"].astype(x.dtype)).reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
        if cfg.qk_norm:
            q = layers.rmsnorm(params["q_norm"], q)
        kv_len = jnp.full((b,), cache["k"].shape[2], jnp.int32)
        o = ops.decode_attention(
            q[:, :, 0], cache["k"], cache["v"], kv_len,
            softcap=cfg.attn_softcap, impl=cfg.attn_impl if cfg.attn_impl != "xla_flash" else "xla",
        )
        o = o.reshape(b, 1, h * hd)
        return o @ params["wo_md"].astype(x.dtype), cache

    positions = (lengths - 1)[:, None]  # (B, 1) absolute position of new token
    q, k_new, v_new = _project_qkv(params, x, cfg, positions, spec.rope_theta)
    # In-place row write at position lengths-1 (donated cache buffers alias).
    idx = lengths - 1

    def _write(c, new, i):
        return jax.lax.dynamic_update_slice(c, new, (0, i, 0))

    k = jax.vmap(_write)(cache["k"], k_new, idx)
    v = jax.vmap(_write)(cache["v"], v_new, idx)
    impl = cfg.attn_impl if cfg.attn_impl not in ("xla_flash", "xla_flash_tri") else "xla"
    o = ops.decode_attention(
        q[:, :, 0], k, v, lengths,
        softcap=cfg.attn_softcap, window=spec.window, impl=impl,
    )
    o = o.reshape(b, 1, h * hd)
    return o @ params["wo_md"].astype(x.dtype), {"k": k, "v": v}


def attention_decode_paged(
    params, x, cfg: ModelConfig, spec: LayerSpec, cache: dict,
    page_table: jnp.ndarray, lengths: jnp.ndarray,
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode over the paged KV pool.

    x: (B, 1, D); cache k/v_pages: (Hkv, P, page_size, hd) head-major;
    page_table: (B, max_pages) physical ids (null-page padded); lengths:
    (B,) length *including* the new token. The new K/V row is scattered
    into the sequence's tail page, then the paged flash-decode kernel
    consumes the page table natively. Rows whose table is all null pages
    (inactive decode slots) harmlessly write the reserved null page.
    """
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k_pages, v_pages = cache["k_pages"], cache["v_pages"]
    ps = k_pages.shape[2]

    positions = (lengths - 1)[:, None]
    q, k_new, v_new = _project_qkv(params, x, cfg, positions, spec.rope_theta)

    # Clamp for inactive rows (length 0): they index the null-padded table
    # head and write the reserved null page.
    idx = jnp.maximum(lengths - 1, 0)
    pids = jnp.take_along_axis(page_table, (idx // ps)[:, None], axis=1)[:, 0]
    offs = idx % ps
    # (B, Hkv, 1, hd) -> (Hkv, B, hd); scatter one row per (head, sequence).
    k_pages = k_pages.at[:, pids, offs].set(
        k_new[:, :, 0].transpose(1, 0, 2).astype(k_pages.dtype)
    )
    v_pages = v_pages.at[:, pids, offs].set(
        v_new[:, :, 0].transpose(1, 0, 2).astype(v_pages.dtype)
    )
    impl = cfg.attn_impl if cfg.attn_impl not in ("xla_flash", "xla_flash_tri") else "xla"
    o = ops.paged_decode_attention(
        q[:, :, 0], k_pages, v_pages, page_table, lengths,
        softcap=cfg.attn_softcap, window=spec.window, impl=impl,
    )
    o = o.reshape(b, 1, h * hd)
    return o @ params["wo_md"].astype(x.dtype), {
        "k_pages": k_pages, "v_pages": v_pages,
    }


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, cache_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, cache_len, hd), dtype),
    }


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int, dtype) -> dict:
    """Head-major page pool for one layer: all pages of a KV head are
    contiguous (``cache.layout.HEAD_ALIGNED`` placement by construction)."""
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k_pages": jnp.zeros((hkv, num_pages, page_size, hd), dtype),
        "v_pages": jnp.zeros((hkv, num_pages, page_size, hd), dtype),
    }
