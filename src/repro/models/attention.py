"""Attention blocks: GQA self-attention, cross-attention, decode paths.

The kernel-facing layer of the model stack. The paper's technique enters in
two ways:
  * the :class:`~repro.kernels.plan.AttentionPlan` handed to the
    ``kernels.ops`` entry points (grid order / KV residency / megacore
    semantics / kernel impl) — resolved here via ``plan_for_config``, the
    only place the config's schedule policy is read,
  * head layout: q/k/v projections emit heads in ACC-contiguous order so the
    model-axis shard boundaries coincide with KV groups
    (``core.placement.ACC_ALIGNED``) — KV is never duplicated across shards.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cache import quant as quant_lib
from repro.configs.base import LayerSpec, ModelConfig
from repro.kernels import ops
from repro.kernels import plan as plan_lib
from repro.models import layers


def init_attention(key, cfg: ModelConfig) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    so = 1.0 / math.sqrt(h * hd)
    p = {
        "wq_dm": jax.random.normal(ks[0], (d, h * hd), layers.default_dtype()) * s,
        "wk_dm": jax.random.normal(ks[1], (d, hkv * hd), layers.default_dtype()) * s,
        "wv_dm": jax.random.normal(ks[2], (d, hkv * hd), layers.default_dtype()) * s,
        "wo_md": jax.random.normal(ks[3], (h * hd, d), layers.default_dtype()) * so,
    }
    if cfg.qk_norm:
        p["q_norm"] = layers.init_rmsnorm(hd)
        p["k_norm"] = layers.init_rmsnorm(hd)
    return p


def _plan(cfg: ModelConfig, shape, *, phase, window=None, kv_layout=plan_lib.DENSE,
          page_size=None, prefix_pages=0, dtype_bytes=None,
          kv_dtype="fp32") -> plan_lib.AttentionPlan:
    """The layer's attention plan: schedule + impl for this call shape,
    resolved (and LRU-cached) by the plan layer from the config policy."""
    return plan_lib.plan_for_config(
        cfg, shape, phase=phase, window=window, kv_layout=kv_layout,
        page_size=page_size, prefix_pages=prefix_pages, dtype_bytes=dtype_bytes,
        kv_dtype=kv_dtype,
    )


def _project_qkv(params, x, cfg: ModelConfig, positions, rope_theta, kv_x=None,
                 rope: bool = True):
    """x: (B, S, D) -> q (B,H,S,hd), k/v (B,Hkv,Skv,hd)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    src = x if kv_x is None else kv_x
    skv = src.shape[1]
    q = (x @ params["wq_dm"].astype(x.dtype)).reshape(b, s, h, hd).transpose(0, 2, 1, 3)
    k = (src @ params["wk_dm"].astype(x.dtype)).reshape(b, skv, hkv, hd).transpose(0, 2, 1, 3)
    v = (src @ params["wv_dm"].astype(x.dtype)).reshape(b, skv, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.head_placement == "striped":
        # Naive round-robin head placement (paper baseline): the physical
        # head order emitted by the (sharded) projections is striped across
        # model shards, so regrouping into logical ACC order moves Q and K/V
        # across shards — the pod-scale analogue of the fragmented L2. The
        # permutation gathers land as collectives in the compiled HLO;
        # benchmarks/roofline A/Bs this against acc_aligned.
        from repro.core import placement as placement_lib

        plan = placement_lib.plan(
            h, hkv, cfg.placement_shards, placement_lib.STRIPED
        )
        q = jnp.take(q, jnp.asarray(plan.q_perm), axis=1)
        k = jnp.take(k, jnp.asarray(plan.kv_perm), axis=1)
        v = jnp.take(v, jnp.asarray(plan.kv_perm), axis=1)
    if cfg.qk_norm:
        q = layers.rmsnorm(params["q_norm"], q)
        k = layers.rmsnorm(params["k_norm"], k)
    if rope:
        q = layers.apply_rotary(q, positions, rope_theta)
        k = layers.apply_rotary(k, positions, rope_theta)
    return q, k, v


def attention_block(
    params: dict,
    x: jnp.ndarray,
    cfg: ModelConfig,
    spec: LayerSpec,
    *,
    positions: Optional[jnp.ndarray] = None,
    encoder_states: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Full-sequence self- (or cross-) attention. x: (B, S, D)."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    cross = spec.cross_attn and encoder_states is not None
    q, k, v = _project_qkv(
        params, x, cfg, positions, spec.rope_theta,
        kv_x=encoder_states if cross else None,
        rope=not cross,
    )
    window = None if cross else spec.window
    plan = _plan(
        cfg, (b, cfg.n_heads, k.shape[1], s, k.shape[2], cfg.head_dim),
        phase=plan_lib.PREFILL, window=window, dtype_bytes=q.dtype.itemsize,
    )
    o = ops.flash_attention(
        q, k, v,
        causal=not cross,
        window=window,
        softcap=cfg.attn_softcap,
        plan=plan,
        chunk_unroll=cfg.attn_chunk_unroll,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    return o @ params["wo_md"].astype(x.dtype)


def attention_prefill(
    params, x, cfg: ModelConfig, spec: LayerSpec, *, cache_len: int,
    positions=None, encoder_states=None,
) -> Tuple[jnp.ndarray, dict]:
    """Like attention_block but also returns the populated KV cache
    (padded to ``cache_len``) for subsequent decode steps."""
    b, s, d = x.shape
    if positions is None:
        positions = jnp.arange(s)
    cross = spec.cross_attn and encoder_states is not None
    q, k, v = _project_qkv(
        params, x, cfg, positions, spec.rope_theta,
        kv_x=encoder_states if cross else None, rope=not cross,
    )
    window = None if cross else spec.window
    plan = _plan(
        cfg, (b, cfg.n_heads, k.shape[1], s, k.shape[2], cfg.head_dim),
        phase=plan_lib.PREFILL, window=window, dtype_bytes=q.dtype.itemsize,
    )
    o = ops.flash_attention(
        q, k, v, causal=not cross, window=window,
        softcap=cfg.attn_softcap, plan=plan,
        chunk_unroll=cfg.attn_chunk_unroll,
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    pad = cache_len - k.shape[2]
    cache = {
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
    }
    return o @ params["wo_md"].astype(x.dtype), cache


def attention_prefill_paged(
    params, x, cfg: ModelConfig, spec: LayerSpec, cache: dict,
    page_table: jnp.ndarray, prefix_len: jnp.ndarray, tail_len: jnp.ndarray,
    *, cache_len: int, positions: jnp.ndarray,
    plan: Optional[plan_lib.AttentionPlan] = None,
) -> Tuple[jnp.ndarray, dict]:
    """Prefix-extension prefill over the paged KV pool (PR-3 headline).

    The first ``prefix_len[b]`` positions were already prefilled by an
    earlier request sharing this prefix (paged engine, ``cache.prefix``);
    their K/V stays **in its pages** — the paged prefill kernel reads it
    straight from ``page_table`` (B, prefix_pages), no gather. Only the
    tail's K/V is computed and returned, padded to ``cache_len`` (the
    caller scatters it into fresh pages). ``positions`` must already carry
    the absolute query positions (``prefix_len[b] + i``); ``tail_len``
    masks bucket padding (rows past it emit zeros).
    """
    b, s, d = x.shape
    q, k, v = _project_qkv(params, x, cfg, positions, spec.rope_theta)
    k_pages, v_pages = cache["k_pages"], cache["v_pages"]
    kv_dtype = quant_lib.kv_dtype_of(k_pages.dtype)
    if plan is None:
        plan = _plan(
            cfg,
            (b, cfg.n_heads, cfg.n_kv_heads,
             s, page_table.shape[1] * k_pages.shape[2] + s, cfg.head_dim),
            phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
            page_size=k_pages.shape[2], prefix_pages=page_table.shape[1],
            window=spec.window, dtype_bytes=q.dtype.itemsize,
            kv_dtype=kv_dtype,
        )
    o = ops.paged_prefill_attention(
        q, k_pages, v_pages, page_table, k, v, prefix_len, tail_len,
        softcap=cfg.attn_softcap, window=spec.window, plan=plan,
        k_scales=cache.get("k_scales"), v_scales=cache.get("v_scales"),
    )
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.head_dim)
    pad = cache_len - k.shape[2]
    cache_out = {
        "k": jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0))),
        "v": jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0))),
    }
    return o @ params["wo_md"].astype(x.dtype), cache_out


def attention_decode(
    params, x, cfg: ModelConfig, spec: LayerSpec, cache: dict, lengths: jnp.ndarray,
    *, is_cross: bool = False,
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: (B, 1, D); cache k/v: (B, Hkv, Smax, hd);
    lengths: (B,) prefix length *including* the new token. ``is_cross``:
    the cache holds static encoder (image) K/V — read-only."""
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    if is_cross:
        # Cross-attn KV is static (image tokens): cache holds it untouched.
        q = (x @ params["wq_dm"].astype(x.dtype)).reshape(b, 1, h, hd).transpose(0, 2, 1, 3)
        if cfg.qk_norm:
            q = layers.rmsnorm(params["q_norm"], q)
        kv_len = jnp.full((b,), cache["k"].shape[2], jnp.int32)
        plan = _plan(
            cfg, (b, h, hkv, 1, cache["k"].shape[2], hd),
            phase=plan_lib.DECODE, dtype_bytes=q.dtype.itemsize,
        )
        o = ops.decode_attention(
            q[:, :, 0], cache["k"], cache["v"], kv_len,
            softcap=cfg.attn_softcap, plan=plan,
        )
        o = o.reshape(b, 1, h * hd)
        return o @ params["wo_md"].astype(x.dtype), cache

    positions = (lengths - 1)[:, None]  # (B, 1) absolute position of new token
    q, k_new, v_new = _project_qkv(params, x, cfg, positions, spec.rope_theta)
    # In-place row write at position lengths-1 (donated cache buffers alias).
    idx = lengths - 1

    def _write(c, new, i):
        return jax.lax.dynamic_update_slice(c, new, (0, i, 0))

    k = jax.vmap(_write)(cache["k"], k_new, idx)
    v = jax.vmap(_write)(cache["v"], v_new, idx)
    plan = _plan(
        cfg, (b, h, hkv, 1, k.shape[2], hd),
        phase=plan_lib.DECODE, window=spec.window, dtype_bytes=q.dtype.itemsize,
    )
    o = ops.decode_attention(
        q[:, :, 0], k, v, lengths,
        softcap=cfg.attn_softcap, window=spec.window, plan=plan,
    )
    o = o.reshape(b, 1, h * hd)
    return o @ params["wo_md"].astype(x.dtype), {"k": k, "v": v}


def attention_decode_paged(
    params, x, cfg: ModelConfig, spec: LayerSpec, cache: dict,
    page_table: jnp.ndarray, lengths: jnp.ndarray,
) -> Tuple[jnp.ndarray, dict]:
    """One-token decode over the paged KV pool.

    x: (B, 1, D); cache k/v_pages: (Hkv, P, page_size, hd) head-major;
    page_table: (B, max_pages) physical ids (null-page padded); lengths:
    (B,) length *including* the new token. The new K/V row is scattered
    into the sequence's tail page, then the paged flash-decode kernel
    consumes the page table natively. Rows whose table is all null pages
    (inactive decode slots) harmlessly write the reserved null page.
    """
    b, _, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    k_pages, v_pages = cache["k_pages"], cache["v_pages"]
    ps = k_pages.shape[2]

    positions = (lengths - 1)[:, None]
    q, k_new, v_new = _project_qkv(params, x, cfg, positions, spec.rope_theta)

    # Clamp for inactive rows (length 0): they index the null-padded table
    # head and write the reserved null page.
    idx = jnp.maximum(lengths - 1, 0)
    pids = jnp.take_along_axis(page_table, (idx // ps)[:, None], axis=1)[:, 0]
    offs = idx % ps
    kv_dtype = quant_lib.kv_dtype_of(k_pages.dtype)
    # (B, Hkv, 1, hd) -> (Hkv, B, hd); scatter one row per (head, sequence).
    # Quantized pools append through the rescale-on-append path (the page's
    # codes shrink when a louder token widens its scale); fp32 degenerates
    # to the plain scatter with scales passed through as None.
    k_pages, ksc = quant_lib.append_rows(
        k_pages, cache.get("k_scales"), k_new[:, :, 0].transpose(1, 0, 2),
        pids, offs, kv_dtype,
    )
    v_pages, vsc = quant_lib.append_rows(
        v_pages, cache.get("v_scales"), v_new[:, :, 0].transpose(1, 0, 2),
        pids, offs, kv_dtype,
    )
    plan = _plan(
        cfg, (b, h, hkv, 1, page_table.shape[1] * ps, hd),
        phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED, page_size=ps,
        window=spec.window, dtype_bytes=q.dtype.itemsize, kv_dtype=kv_dtype,
    )
    o = ops.paged_decode_attention(
        q[:, :, 0], k_pages, v_pages, page_table, lengths,
        softcap=cfg.attn_softcap, window=spec.window, plan=plan,
        k_scales=ksc, v_scales=vsc,
    )
    o = o.reshape(b, 1, h * hd)
    cache_out = {"k_pages": k_pages, "v_pages": v_pages}
    if ksc is not None:
        cache_out["k_scales"] = ksc
        cache_out["v_scales"] = vsc
    return o @ params["wo_md"].astype(x.dtype), cache_out


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype) -> dict:
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, hkv, cache_len, hd), dtype),
        "v": jnp.zeros((batch, hkv, cache_len, hd), dtype),
    }


def init_paged_cache(cfg: ModelConfig, num_pages: int, page_size: int, dtype,
                     kv_dtype: str = "fp32") -> dict:
    """Head-major page pool for one layer: all pages of a KV head are
    contiguous (``cache.layout.HEAD_ALIGNED`` placement by construction).

    ``kv_dtype`` != "fp32" stores 1-byte codes (``cache.quant``) plus one
    fp32 scale per (kv head, physical page) for K and V each — the scale
    arrays are page-table metadata and ride next to it into the kernels.
    """
    hkv, hd = cfg.n_kv_heads, cfg.head_dim
    quant_lib.validate_kv_dtype(kv_dtype)
    if kv_dtype == "fp32":
        return {
            "k_pages": jnp.zeros((hkv, num_pages, page_size, hd), dtype),
            "v_pages": jnp.zeros((hkv, num_pages, page_size, hd), dtype),
        }
    sdt = quant_lib.storage_dtype(kv_dtype)
    return {
        "k_pages": jnp.zeros((hkv, num_pages, page_size, hd), sdt),
        "v_pages": jnp.zeros((hkv, num_pages, page_size, hd), sdt),
        "k_scales": jnp.zeros((hkv, num_pages), jnp.float32),
        "v_scales": jnp.zeros((hkv, num_pages), jnp.float32),
    }
