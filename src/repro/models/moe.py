"""Mixture-of-Experts FFN: top-k routing with capacity-bounded scatter dispatch.

TPU-native MoE (Mixtral 8e top-2; Moonlight 64e top-6): static shapes
throughout, no ragged ops. Dispatch is sort-free scatter into per-expert
buffers of capacity ``C = ceil(tokens * top_k / E * capacity_factor)``;
overflow tokens are dropped (their combine weight is zero) — the standard
GShard/Switch discipline.

Expert parallelism: the (E, C, d) dispatch buffer and the expert weights are
sharded on the ``model`` ("expert") axis via sharding constraints injected by
``distributed.sharding.shard_moe`` (a callable threaded through to avoid a
mesh dependency here). Under pjit this lowers to the canonical
all-to-all -> grouped-GEMM -> all-to-all schedule.

Aux losses: load-balance (Switch) + router z-loss, returned for the trainer.
"""

from __future__ import annotations

import math
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models import layers


def init_moe(key, d_model: int, cfg: MoEConfig) -> dict:
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d_model)
    s_out = 1.0 / math.sqrt(cfg.d_ff)
    e = cfg.num_experts
    p = {
        "router_de": jax.random.normal(ks[0], (d_model, e), layers.default_dtype()) * s_in,
        # Expert weights: leading expert dim is the EP shard axis.
        "wi_gate_edm": jax.random.normal(ks[1], (e, d_model, cfg.d_ff), layers.default_dtype()) * s_in,
        "wi_up_edm": jax.random.normal(ks[2], (e, d_model, cfg.d_ff), layers.default_dtype()) * s_in,
        "wo_emd": jax.random.normal(ks[3], (e, cfg.d_ff, d_model), layers.default_dtype()) * s_out,
    }
    if cfg.num_shared_experts:
        p["shared"] = layers.init_mlp(
            ks[4], d_model, cfg.d_ff * cfg.num_shared_experts
        )
    return p


def moe_ffn(
    params: dict,
    x: jnp.ndarray,
    cfg: MoEConfig,
    *,
    shard_buffers: Callable[[jnp.ndarray], jnp.ndarray] = lambda t: t,
    capacity: Optional[int] = None,
) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D) -> (out (B, S, D), aux {lb_loss, z_loss, ...})."""
    b, s, d = x.shape
    n = b * s
    e, k = cfg.num_experts, cfg.top_k
    xt = x.reshape(n, d)

    # --- Routing (f32 for numerics) ---
    logits = xt.astype(jnp.float32) @ params["router_de"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                      # (n, e)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)              # (n, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )

    # --- Aux losses ---
    me = jnp.mean(probs, axis=0)                                  # mean prob/expert
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(expert_idx, e, dtype=jnp.float32), axis=1), axis=0
    )                                                             # mean assignment
    lb_loss = e * jnp.sum(me * ce) / k
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- Capacity-bounded positions: rank of each (token, slot) within its
    # expert, computed with a cumulative one-hot sum (static shapes).
    if capacity is None:
        capacity = int(math.ceil(n * k / e * cfg.capacity_factor))
        capacity = max(8, min(capacity, n))
    flat_expert = expert_idx.reshape(-1)                          # (n*k,) slot-major? no: token-major
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)      # (n*k, e)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - 1)              # inclusive -> 0-based
    pos = jnp.take_along_axis(pos_in_expert, flat_expert[:, None], axis=1)[:, 0]
    keep = pos < capacity
    gate_flat = gate_vals.reshape(-1) * keep.astype(jnp.float32)

    # --- Scatter tokens into (E, C, D) buffers ---
    token_idx = jnp.repeat(jnp.arange(n), k)
    slot = jnp.where(keep, flat_expert * capacity + pos, e * capacity)  # drop row
    buf = jnp.zeros((e * capacity + 1, d), xt.dtype)
    buf = buf.at[slot].add(xt[token_idx] * keep[:, None].astype(xt.dtype))
    buf = buf[: e * capacity].reshape(e, capacity, d)
    buf = shard_buffers(buf)

    # --- Expert computation: grouped GEMMs over the expert dim ---
    dt = xt.dtype
    gate_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_gate_edm"].astype(dt))
    up_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_up_edm"].astype(dt))
    h = jax.nn.silu(gate_h) * up_h
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wo_emd"].astype(dt))
    out_buf = shard_buffers(out_buf)

    # --- Gather back and combine with gate weights ---
    out_flat = out_buf.reshape(e * capacity, d)
    gathered = out_flat[jnp.where(keep, flat_expert * capacity + pos, 0)]
    gathered = gathered * gate_flat[:, None].astype(dt)
    out = jnp.zeros((n, d), dt).at[token_idx].add(gathered)

    if "shared" in params:
        out = out + layers.mlp(params["shared"], xt)

    aux = {
        "moe_lb_loss": lb_loss,
        "moe_z_loss": z_loss,
        "moe_dropped_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
    }
    return out.reshape(b, s, d), aux
