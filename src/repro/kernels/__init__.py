"""NUMA-aware attention kernels (Pallas TPU) + oracles.

plan             the attention-plan layer: one resolver (plan_attention)
                 for every phase (prefill | extend | decode) and KV layout
flash_attention  FA2 forward: mapping-parameterized grid (paper's technique)
flash_attention_bwd  dQ / dK/dV kernels with the same grid-order choice
decode_attention  flash-decode: one ACC per (batch, kv-head) grid cell,
                 plus the split-K path (PARALLEL axis over KV ranges)
paged_decode_attention  flash-decode over a page table (scalar-prefetch
                 index maps; head-major page pool = NUMA-aligned placement),
                 split-K over domain-pure page ranges
decode_common    shared decode arithmetic: unit relevance predicate,
                 online-softmax block update, split-state combine
paged_prefill_attention  prefix-extension prefill reading prefix K/V
                 straight from the page table (no gather, no q_offset
                 fallback)
ssd              Mamba-2 SSD intra-chunk kernel (head-first grid)
ops              public jit'd API executing AttentionPlans + custom VJP
ref              pure-jnp oracles for all of the above
"""

from repro.kernels import ops, plan, ref  # noqa: F401
from repro.kernels.ops import resolve_kv_layout, resolve_mapping  # noqa: F401
from repro.kernels.plan import AttentionPlan, plan_attention  # noqa: F401
from repro.kernels.paged_decode_attention import paged_flash_decode  # noqa: F401
from repro.kernels.paged_prefill_attention import paged_flash_prefill  # noqa: F401
from repro.kernels.flash_attention import (  # noqa: F401
    BLOCK_FIRST,
    HEAD_FIRST,
    PAPER_MAPPINGS,
    MappingConfig,
    flash_attention_fwd,
    hbm_block_fetches,
)
from repro.kernels.flash_attention_bwd import flash_attention_bwd  # noqa: F401
from repro.kernels.decode_attention import flash_decode  # noqa: F401
from repro.kernels.ssd import ssd_chunked_pallas, ssd_intra_chunk  # noqa: F401
