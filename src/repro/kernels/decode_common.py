"""Shared decode-kernel arithmetic: chunk relevance + split-state combine.

Both flash-decode kernels (dense ``decode_attention.py`` and paged
``paged_decode_attention.py``) walk the KV axis in fixed-size units — KV
chunks for the dense stripe, pages for the pool — and both need the same
two pieces of softmax bookkeeping:

  * :func:`chunk_relevant` — may a KV unit starting at ``chunk_start``
    contain *any* position the query attends? This gates the whole
    unit's compute (``pl.when``); per-position masking inside the unit
    does the fine trimming. The predicate is exact (sound *and*
    complete): it is True iff at least one position in
    ``[chunk_start, chunk_start + chunk_len)`` is valid under the decode
    mask ``pos < length`` (and ``pos > length - 1 - window`` for sliding
    windows) — property-tested in ``tests/test_decode_relevance.py``.

  * :func:`combine_split_states` — merge per-split partial online-softmax
    states. With split-K decode (PR 4) a new PARALLEL grid axis
    partitions the KV units into ``num_splits`` ranges; each split emits
    its running ``(acc, m, l)`` instead of a normalized output, and this
    second stage rescales every split to the global row max and
    normalizes once. It is a pure vectorized-JAX stage: the state tensor
    is tiny (``B x Hkv x splits x group x D`` floats) next to the KV
    traffic of stage one, so it fuses into the surrounding jit rather
    than warranting its own Mosaic kernel.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def chunk_relevant(chunk_start, chunk_len: int, length, window):
    """True iff the KV unit ``[chunk_start, chunk_start + chunk_len)`` can
    hold a valid key for a decode row of ``length`` live tokens.

    ``chunk_start`` / ``length`` may be traced scalars (the kernels call
    this on grid indices and SMEM lengths); ``chunk_len`` and ``window``
    are Python ints (jit constants). A position ``pos`` is valid when
    ``pos < length`` and, under a sliding window of size W, additionally
    ``pos > length - 1 - W``. The unit holds a valid position iff its
    first position precedes ``length`` and its last position reaches the
    window's left edge.
    """
    relevant = chunk_start < length
    if window is not None and window > 0:
        relevant &= chunk_start + chunk_len - 1 >= length - window
    return relevant


def accumulate_kv_block(
    q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
    *, scale, softcap, window, block_start, block_len: int, length,
    k_scale=None, v_scale=None,
):
    """One online-softmax step over a KV unit, shared by all four decode
    kernel bodies (dense/paged x one-pass/split-K).

    q_ref/k_ref/v_ref: the current ``(1, 1, G, D)`` q block and ``(1, 1,
    block_len, D)`` KV unit; acc/m/l_ref: VMEM running state ``(G, D)`` /
    ``(G, 128)`` / ``(G, 128)``. ``block_start`` and ``length`` may be
    traced (grid index x unit size, SMEM length); ``block_len`` /
    ``window`` / ``scale`` / ``softcap`` are jit constants. Positions at
    or past ``length`` (and outside the sliding window) are masked
    per-element; the caller gates whole irrelevant units with
    :func:`chunk_relevant`.

    ``k_scale`` / ``v_scale`` are the quantized pools' per-(head, page)
    dequant factors (traced SMEM scalars, prefetched next to the page
    table): the unit's 1-byte codes widen to fp32 here, in VMEM, right
    before the matmuls — HBM streamed only the codes. ``None`` keeps the
    fp32 pools untouched.
    """
    q = q_ref[0, 0].astype(jnp.float32)      # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)      # (block_len, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if k_scale is not None:
        k = k * k_scale
    if v_scale is not None:
        v = v * v_scale
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos = block_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_len), 1)
    valid = pos < length
    if window is not None and window > 0:
        valid &= pos > length - 1 - window
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[:, 0:1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    l_ref[...] = jnp.broadcast_to(
        l_ref[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_ref.shape
    )
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)


def combine_split_states(acc, m, l):
    """Merge per-split online-softmax states into the final attention row.

    acc: ``(..., S, G, D)`` unnormalized value accumulators, one per split;
    m, l: ``(..., S, G, 1)`` running row max / normalizer of each split.
    Returns ``(..., G, D)`` float32 — ``sum_s exp(m_s - m*) acc_s`` over
    ``sum_s exp(m_s - m*) l_s`` with ``m* = max_s m_s``.

    Splits that saw no relevant KV carry ``(0, NEG_INF, 0)``: their
    rescale factor underflows to exactly 0 against any live split, and a
    row with *no* live split (length 0) has ``l* == 0`` and emits exact
    zeros — the same guard the one-pass kernels' emit step applies.
    """
    m_star = jnp.max(m, axis=-3, keepdims=True)
    alpha = jnp.exp(m - m_star)
    l_star = jnp.sum(l * alpha, axis=-3)
    acc_star = jnp.sum(acc * alpha, axis=-3)
    return acc_star / jnp.where(l_star == 0.0, 1.0, l_star)
