"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

The SSD hot spot is the intra-chunk quadratic: per (batch, chunk, head),
   y_intra = ((C Bᵀ) ⊙ L) · (dt·x),     L_ij = exp(acum_i - acum_j)·[j<=i]
   S_c     = (B ⊙ exp(acum_last - acum))ᵀ · (dt·x)
— three (chunk × N × chunk/P) matmuls per grid cell, MXU-shaped, with the
decay math fused in VMEM. The hymba/mamba prefill cells are memory-bound on
exactly these tensors in the XLA path (EXPERIMENTS.md §Roofline); fusing the
masked-decay epilogue removes the materialized (q × q) f32 intermediates.

Grid order follows the paper's generalized insight: (batch, head, chunk) —
all chunks of one head stream consecutively, so the per-head decay/state
context stays resident, and the chunk axis is ARBITRARY (sequential) while
batch/head are PARALLEL for megacore.

The inter-chunk recurrence (O(L/q) scan) and output stitching remain in
jnp — see ``ssd_chunked_pallas`` and ``models.ssm.ssd_chunked`` (the
oracle); tests/test_ssd_kernel.py sweeps shapes x chunk sizes.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _ssd_chunk_kernel(xdt_ref, bh_ref, ch_ref, acum_ref, y_ref, s_ref, *, chunk):
    xdt = xdt_ref[0, 0, 0].astype(jnp.float32)   # (q, P)
    bh = bh_ref[0, 0, 0].astype(jnp.float32)     # (q, N)
    ch = ch_ref[0, 0, 0].astype(jnp.float32)     # (q, N)
    ac = acum_ref[0, 0, 0].astype(jnp.float32)   # (q,)

    cb = jax.lax.dot_general(
        ch, bh, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                          # (q, q)
    seg = ac[:, None] - ac[None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    # Mask in log space BEFORE exp (above-diagonal seg > 0 overflows).
    seg = jnp.where(cols <= rows, seg, NEG_INF)
    y = jax.lax.dot_general(
        cb * jnp.exp(seg), xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (q, P)
    dte = jnp.exp(ac[-1] - ac)                 # decay to chunk end
    s = jax.lax.dot_general(
        bh * dte[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (N, P)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    s_ref[0, 0, 0] = s.astype(s_ref.dtype)


def ssd_intra_chunk(
    xdt: jnp.ndarray,    # (B, nc, H, q, P) dt-scaled inputs
    bh: jnp.ndarray,     # (B, nc, H, q, N)
    ch: jnp.ndarray,     # (B, nc, H, q, N)
    acum: jnp.ndarray,   # (B, nc, H, q) inclusive cumsum of dt*A
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y_intra (B,nc,H,q,P), chunk_states (B,nc,H,N,P))."""
    b, nc, h, q, p = xdt.shape
    n = bh.shape[-1]
    kernel = functools.partial(_ssd_chunk_kernel, chunk=q)
    grid = (b, h, nc)  # head-first: chunks of one head stream consecutively

    def xmap(b_, h_, c_):
        return (b_, c_, h_, 0, 0)

    def amap(b_, h_, c_):
        return (b_, c_, h_, 0)

    y, s = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), xmap),
            pl.BlockSpec((1, 1, 1, q, n), xmap),
            pl.BlockSpec((1, 1, 1, q, n), xmap),
            pl.BlockSpec((1, 1, 1, q), amap),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, q, p), xmap),
            pl.BlockSpec((1, 1, 1, n, p), xmap),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nc, h, q, p), jnp.float32),
            jax.ShapeDtypeStruct((b, nc, h, n, p), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(2.0 * b * nc * h * (q * q * n + q * q * p + q * n * p)),
            bytes_accessed=int(4 * b * nc * h * q * (p + 2 * n + 1)),
            transcendentals=int(b * nc * h * q * q),
        ),
        interpret=interpret,
        name="ssd_intra_chunk",
    )(xdt, bh, ch, acum)
    return y, s


def ssd_chunked_pallas(
    x: jnp.ndarray,      # (B, L, H, P)
    dt: jnp.ndarray,     # (B, L, H)
    a: jnp.ndarray,      # (H,)
    b_mat: jnp.ndarray,  # (B, L, G, N)
    c_mat: jnp.ndarray,  # (B, L, G, N)
    chunk: int,
    h0: jnp.ndarray = None,
    *,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in for models.ssm.ssd_chunked with the intra-chunk block on the
    Pallas kernel. Same padding/initial-state semantics."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    q = min(chunk, l)
    pad = (-l) % q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
    l_pad = l + pad
    nc = l_pad // q
    f32 = jnp.float32

    dtc = dt.reshape(bsz, nc, q, h).astype(f32)
    adt = dtc * a[None, None, None, :]
    acum = jnp.cumsum(adt, axis=2)                      # (B,nc,q,H)
    xdt = (x.reshape(bsz, nc, q, h, p).astype(f32) * dtc[..., None])
    bh = jnp.repeat(b_mat.reshape(bsz, nc, q, g, n), rep, axis=3).astype(f32)
    ch = jnp.repeat(c_mat.reshape(bsz, nc, q, g, n), rep, axis=3).astype(f32)

    y_intra, s_c = ssd_intra_chunk(
        xdt.transpose(0, 1, 3, 2, 4),                   # (B,nc,H,q,P)
        bh.transpose(0, 1, 3, 2, 4),
        ch.transpose(0, 1, 3, 2, 4),
        acum.transpose(0, 1, 3, 2),                     # (B,nc,H,q)
        interpret=interpret,
    )
    y_intra = y_intra.transpose(0, 1, 3, 2, 4)          # (B,nc,q,H,P)
    s_c = s_c.transpose(0, 1, 2, 4, 3)                  # (B,nc,H,P,N)

    # Inter-chunk recurrence + cross-chunk output term (cheap, stays in jnp).
    last = acum[:, :, -1, :]                            # (B,nc,H)
    chunk_decay = jnp.exp(last)

    def step(hprev, inp):
        dec, s = inp
        return hprev * dec[:, :, None, None] + s, hprev

    if h0 is None:
        h0 = jnp.zeros((bsz, h, p, n), f32)
    hT, h_in = jax.lax.scan(
        step, h0.astype(f32),
        (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(s_c, 1, 0)),
    )
    h_in = jnp.moveaxis(h_in, 0, 1)                     # (B,nc,H,P,N)
    y_inter = jnp.einsum("bcqhn,bchpn->bcqhp", ch * jnp.exp(acum)[..., None], h_in)
    y = (y_intra + y_inter).reshape(bsz, l_pad, h, p)[:, :l]
    return y.astype(x.dtype), hT
