"""The attention-plan layer: one resolver for every attention phase.

After PR 2 the NUMA-aware *schedule* — the thing the paper says decides
attention performance — was resolved in four different places: the ops
dispatch (``resolve_mapping`` / ``resolve_kv_layout`` plus three entry
points), the model attention layer (``cfg.mapping_name`` lookups), the
transformer prefill (``q_offset`` threading) and the serving engines
(pinned-mapping validation, gather-then-dense prefix prefill). This module
collapses all of that into a single value:

  ``AttentionPlan`` — phase (prefill | extend | decode), KV layout (dense |
  paged), the resolved ``MappingConfig``, the concrete kernel impl, the
  decode KV chunk, the split-K ``num_splits`` (PR 4: chosen by
  ``perf_model.estimate_decode_splits``' occupancy model), the NUMA
  placement policy, and the backend/interpret environment it was resolved
  for. The paged-extend impl is likewise a scored choice
  (``perf_model.estimate_extend_prefill``): the prefix-aware kernel vs
  the gather route, per shape.

produced by one resolver:

  ``plan_attention(shape, ...)`` — scores (grid order x KV residency x
  block size) candidates with the analytic NUMA model (``core.perf_model``)
  plus the exact HBM-traffic model (``hbm_block_fetches``), picks the
  kernel implementation for the phase/backend, and LRU-caches the result.
  The cache key includes the **backend and the interpret flag** (the PR-1
  resolver silently shared entries across backends when tests flipped
  ``JAX_PLATFORMS``), so a plan resolved for a CPU dry-run can never leak
  into a TPU trace.

Call sites execute plans instead of hand-threading ``mapping_name`` /
``q_offset`` / chunk arguments through four layers:

  * ``kernels.ops`` builds a plan when none is passed and dispatches on
    ``plan.impl`` / ``plan.mapping`` / ``plan.chunk``;
  * ``models.attention`` / ``models.transformer`` resolve via
    :func:`plan_for_config` (which is where ``cfg.mapping_name`` /
    ``cfg.attn_impl`` policy is read — nowhere else);
  * ``serving.engine`` builds one **extend** plan per (tail-bucket,
    prefix-page-bucket) jit key and hands it to ``transformer.prefill``.

The legacy entry points ``ops.resolve_mapping`` / ``ops.resolve_kv_layout``
survive as thin wrappers over this module (see ops.py).

Phases
------
  * ``PREFILL`` — full-sequence attention, causal, dense K/V.
  * ``EXTEND``  — prefix-extension prefill: the query block sits after an
    already-cached prefix. With ``kv_layout=PAGED`` this resolves to the
    paged prefix-aware Pallas prefill kernel
    (``kernels.paged_prefill_attention``) which reads prefix K/V straight
    from the page table; the dense variant is the legacy XLA
    ``q_offset`` route, kept as the oracle/fallback.
  * ``DECODE``  — one query token against a cache (dense stripe or pages).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

from repro import compat
from repro.kernels.flash_attention import (
    BLOCK_FIRST,
    HEAD_FIRST,
    PAPER_MAPPINGS,
    MappingConfig,
    hbm_block_fetches,
)

# Phases
PREFILL = "prefill"
EXTEND = "extend"
DECODE = "decode"
PHASES = (PREFILL, EXTEND, DECODE)

# KV layouts
DENSE = "dense"
PAGED = "paged"


# -----------------------------------------------------------------------------
# The plan
# -----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttentionPlan:
    """One resolved attention schedule: everything a call site needs to
    execute attention for one (phase, layout, shape, backend) cell.

    Frozen + hashable so it can ride jit closures and custom_vjp nondiff
    arguments, and so equal plans are interchangeable cache entries.
    """

    phase: str                     # PREFILL | EXTEND | DECODE
    kv_layout: str                 # DENSE | PAGED
    impl: str                      # concrete: "pallas"|"xla_flash"|"xla_flash_tri"|"xla"|"ref"
    mapping: MappingConfig         # grid order / residency / blocks
    backend: str                   # backend the plan was resolved for
    interpret: bool                # Pallas interpret mode on this backend
    chunk: Optional[int] = None    # decode KV chunk (dense flash-decode)
    page_size: Optional[int] = None     # paged layouts
    prefix_pages: int = 0          # EXTEND: page-table width (bucketed)
    window: Optional[int] = None   # sliding window the plan was scored for
    placement: Optional[str] = None     # paged: head_aligned | interleaved
    num_splits: int = 1            # DECODE: split-K ranges (occupancy model)
    num_devices: int = 1           # mesh width the plan was scored for
    #: Paged pools' storage format (``cache.quant``): "fp32" | "int8" |
    #: "fp8". Quantized plans expect per-page scales next to the page
    #: table at call time; dense layouts are always fp32.
    kv_dtype: str = "fp32"
    #: DECODE on a mesh: True when the joint (domain, device) model kept
    #: split-K ranges device-pure (head-sharded pool, every range local to
    #: its owner's HBM); False when striping the pool across devices won
    #: (fast fabric + too few KV heads to feed every device). None off-mesh.
    split_device_pure: Optional[bool] = None

    @property
    def prefix_capacity(self) -> int:
        """Max prefix tokens this (extend) plan can attend: the page-table
        width times the page size. The *live* prefix length is dynamic
        (``prefix_len`` arrays at call time) and may be smaller — the jit
        key buckets pages to powers of two to bound compilations."""
        return self.prefix_pages * (self.page_size or 0)


# -----------------------------------------------------------------------------
# Mapping scoring (moved verbatim from the PR-1 ops.resolve_mapping body)
# -----------------------------------------------------------------------------

#: Candidate (block_m, block_n) tilings, preference-ordered. The MXU-native
#: 128x128 default first; larger variants only win when the model says so
#: (e.g. less padding waste). Sub-128 blocks are excluded — the analytic
#: model would pick them for their smaller causal-diagonal waste, but they
#: under-fill the 128x128 MXU; short sequences still clamp via min(bm, sq).
_CANDIDATE_BLOCKS = ((128, 128), (256, 128), (128, 256))

#: Grid order -> paper mapping name for the analytic model. Every emitted
#: candidate has acc_parallel=True, so both orders score as their swizzled
#: variant (the naive_* names carry perf_model's ACC-replication penalty for
#: schedules we never emit); residency is decided by the candidate filter
#: plus the exact HBM-traffic tie-break, not by the analytic proxy.
_PAPER_NAME = {
    HEAD_FIRST: "swizzled_head_first",
    BLOCK_FIRST: "swizzled_block_first",
}


def _topology_for(backend: str):
    from repro.core import numa

    if backend == "gpu":
        return numa.MI300X
    # TPU and CPU alike schedule for the megacore TPU target: CPU hosts run
    # the kernels in interpret mode, and using the same topology guarantees
    # dry-runs pick the same mapping the real hardware would.
    return numa.TPU_V5P_MEGACORE


@functools.lru_cache(maxsize=1024)
def _score_mapping(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    dtype_bytes: int,
    backend: str,
    vmem_budget_bytes: int,
    decode: bool,
    window: Optional[int],
) -> MappingConfig:
    from repro.core import perf_model
    from repro.core import swizzle
    from repro.core.cache_sim import AttentionWorkload
    from repro.core.swizzle import AttentionGrid

    topo = _topology_for(backend)
    group = max(1, num_q_heads // max(num_kv_heads, 1))
    # A sliding window bounds the KV each row actually touches: score (and
    # choose blocks for) the live span, rounded up to a whole tile, not the
    # full cache. Decode shapes attend every prior position, so they score
    # non-causal — a causal model would halve their tile count and pick
    # systematically undersized blocks.
    causal = not decode
    if window is not None and window > 0:
        seq_kv = min(seq_kv, -(-(window + (0 if decode else seq_q)) // 128) * 128)

    def _clamp(block, seq):
        # Never emit a block shorter than the sequence rounded up to the
        # sublane quantum (16 covers bf16's 16 and f32's 8): ops pads the
        # sequence to the block size, and a non-multiple-of-sublane block
        # only works in interpret mode — Mosaic rejects the layout.
        return min(block, max(16, -(-seq // 16) * 16))

    best = None  # (time, traffic, candidate_rank, config)
    rank = 0
    for bm, bn in _CANDIDATE_BLOCKS:
        bm_eff = _clamp(bm, seq_q)
        bn_eff = _clamp(bn, seq_kv)
        for order in (HEAD_FIRST, BLOCK_FIRST):
            for kv_resident in (True, False):
                # Sawtooth wavefront (ROADMAP 5(a)) is a streaming-only
                # refinement: serpentine KV sweeps share boundary tiles, so
                # it enters the candidate space wherever a sweep exists
                # (head_first streaming). Listed after linear so it wins
                # only on the exact-traffic tie-break, never on rank.
                traversals = (swizzle.LINEAR,)
                if not kv_resident and order == HEAD_FIRST:
                    traversals = (swizzle.LINEAR, swizzle.SAWTOOTH)
                for traversal in traversals:
                    cand = MappingConfig(
                        order=order,
                        kv_resident=kv_resident,
                        acc_parallel=True,
                        block_m=bm_eff,
                        block_n=bn_eff,
                        vmem_budget_bytes=vmem_budget_bytes,
                        traversal=traversal,
                    )
                    if kv_resident and not cand.resolve_resident(
                        seq_kv, head_dim, dtype_bytes
                    ):
                        # Over-budget residency degenerates to streaming;
                        # keep only the honest streaming candidate.
                        continue
                    # perf_model.estimate models a square (seq_kv x seq_kv)
                    # launch: it recomputes blocks_per_head from
                    # wl.seq_len, so feed it the same convention. For
                    # rectangular shapes (bucketed prefill vs long cache)
                    # the analytic time is a square proxy; the exact
                    # rectangular traffic enters via the tie-break below.
                    grid = AttentionGrid(
                        batch=batch,
                        num_q_heads=num_q_heads,
                        blocks_per_head=-(-seq_kv // bm_eff),
                        group_size=group,
                    )
                    wl = AttentionWorkload(
                        grid=grid,
                        seq_len=seq_kv,
                        head_dim=head_dim,
                        block_m=bm_eff,
                        block_n=bn_eff,
                        causal=causal,
                        dtype_bytes=dtype_bytes,
                    )
                    est = perf_model.estimate(_PAPER_NAME[order], wl, topo)
                    traffic = hbm_block_fetches(
                        batch=batch,
                        num_q_heads=num_q_heads,
                        num_kv_heads=num_kv_heads,
                        seq_q=seq_q,
                        seq_kv=seq_kv,
                        head_dim=head_dim,
                        dtype_bytes=dtype_bytes,
                        mapping=cand,
                    )["total_bytes"]
                    key = (est.time, traffic, rank)
                    rank += 1
                    if best is None or key < best[0]:
                        best = (key, cand)
    return best[1]


# -----------------------------------------------------------------------------
# KV-layout scoring (moved from the PR-2 ops.resolve_kv_layout body)
# -----------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _score_kv_layout(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    mean_len: int,
    capacity: int,
    page_size: int,
    head_dim: int,
    dtype_bytes: int,
    backend: str,
    shared_prefix_len: int,
) -> Tuple[str, float, float]:
    from repro.core import perf_model

    topo = _topology_for(backend)
    dense = perf_model.estimate_dense_decode(
        batch=batch, num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
        capacity=capacity, head_dim=head_dim, dtype_bytes=dtype_bytes,
        topo=topo,
    )
    candidates = {"dense": dense.time}
    for policy in ("head_aligned", "interleaved"):
        est = perf_model.estimate_paged_decode(
            batch=batch, num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
            mean_len=mean_len, page_size=page_size, head_dim=head_dim,
            dtype_bytes=dtype_bytes, topo=topo, policy=policy,
            shared_prefix_len=shared_prefix_len,
        )
        candidates[f"paged:{policy}"] = est.time
    best = min(candidates, key=candidates.get)
    return best, candidates[best], candidates["dense"]


def resolve_kv_layout(
    shape: Tuple[int, int, int, int, int],
    *,
    capacity: int,
    page_size: int = 64,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
    shared_prefix_len: int = 0,
) -> str:
    """Rank KV layouts for a decode mix; returns ``"dense"``,
    ``"paged:head_aligned"`` or ``"paged:interleaved"``.

    ``shape`` is ``(batch, num_q_heads, num_kv_heads, mean_len, head_dim)``
    — the decode batch and its mean live sequence length; ``capacity`` is
    the dense per-slot stripe the paged layout would replace. Scored with
    ``core.perf_model``'s paged/dense decode estimates (page-granular
    traffic, once-per-domain shared-prefix reuse, link-cost for remote
    pages) — the decode analogue of the mapping scoring above."""
    b, hq, hkv, mean_len, head_dim = (int(x) for x in shape)
    best, _, _ = _score_kv_layout(
        b, hq, hkv, mean_len, int(capacity), int(page_size),
        head_dim, int(dtype_bytes),
        backend or compat.default_backend(),
        int(shared_prefix_len),
    )
    return best


# -----------------------------------------------------------------------------
# Impl + chunk resolution
# -----------------------------------------------------------------------------

_DENSE_PREFILL_IMPLS = ("pallas", "xla_flash", "xla_flash_tri", "ref")


@functools.lru_cache(maxsize=512)
def _score_extend_route(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    tail_len: int,
    prefix_len: int,
    page_size: int,
    head_dim: int,
    dtype_bytes: int,
    backend: str,
) -> str:
    """Paged-vs-gather extend route for one shape: "pallas" (the paged
    prefix-aware kernel) or "xla" (gather the prefix to dense, run the
    dense flash oracle). Scored with
    ``perf_model.estimate_extend_prefill`` under both models — the paged
    kernel reads each prefix page once but its grid is only B x Hkv wide;
    the gather route triples the prefix traffic (read + write-back + dense
    re-read, fabric cost included) to regain full occupancy. Ties keep
    the kernel (no gather is the better default at equal cost)."""
    from repro.core import perf_model

    topo = _topology_for(backend)
    kw = dict(
        batch=batch, num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
        prefix_len=prefix_len, tail_len=tail_len, page_size=page_size,
        head_dim=head_dim, dtype_bytes=dtype_bytes, topo=topo,
    )
    paged = perf_model.estimate_extend_prefill(gather=False, **kw)
    gather = perf_model.estimate_extend_prefill(gather=True, **kw)
    return "pallas" if paged.time <= gather.time else "xla"


def _resolve_impl(phase: str, kv_layout: str, impl: str, backend: str) -> str:
    """Concrete kernel implementation for a phase/layout on a backend.

    ``impl`` is the caller's policy (``cfg.attn_impl``), usually "auto".
    Decode phases coerce the prefill-only xla_flash* impls to the dense
    "xla" oracle (this coercion previously lived in models/attention.py).
    """
    if phase == DECODE:
        if impl in ("auto",):
            return "pallas" if backend == "tpu" else "xla"
        if impl in ("xla_flash", "xla_flash_tri"):
            return "xla"
        if impl in ("pallas", "xla", "ref"):
            return impl
        raise ValueError(f"unknown decode impl {impl!r}")
    if phase == EXTEND and kv_layout == PAGED:
        # The headline kernel: paged prefix-aware Pallas prefill — the only
        # non-gather route, so "auto" resolves to it on every backend (CPU
        # hosts run it in interpret mode). An explicitly pinned compiled
        # CPU impl (xla_flash*) coerces to the compiled gather oracle
        # instead, mirroring the decode-phase coercion — never silently to
        # the interpreter.
        if impl in ("auto", "pallas"):
            return "pallas"
        if impl in ("xla", "ref", "xla_flash", "xla_flash_tri"):
            return "xla"
        raise ValueError(f"unknown paged-extend impl {impl!r}")
    if phase == EXTEND:
        # Dense extend: the legacy q-offset route. The Pallas forward does
        # not carry the offset, so "pallas"/"auto" fall back to xla_flash —
        # this is the oracle path the paged kernel is tested against.
        if impl in ("auto", "pallas"):
            return "xla_flash"
        if impl in _DENSE_PREFILL_IMPLS:
            return impl
        raise ValueError(f"unknown dense-extend impl {impl!r}")
    # PREFILL
    if impl == "auto":
        return "pallas" if backend == "tpu" else "xla_flash"
    if impl in _DENSE_PREFILL_IMPLS:
        return impl
    raise ValueError(f"unknown prefill impl {impl!r}")


def _decode_chunk(mapping: MappingConfig, smax: int) -> int:
    """KV chunk for the dense flash-decode kernel: the resolver's block_n,
    preferring a divisor of the cache capacity (largest sublane-multiple
    divisor <= block_n) so the serving hot loop never pays a pad copy.
    Only truly odd capacities keep the non-dividing chunk (ops pads)."""
    chunk = min(mapping.block_n, smax)
    if smax % chunk:
        divisor = next(
            (c for c in range(chunk, 7, -1) if smax % c == 0 and c % 8 == 0),
            None,
        )
        if divisor is not None:
            chunk = divisor
    return chunk


# -----------------------------------------------------------------------------
# The resolver
# -----------------------------------------------------------------------------


@functools.lru_cache(maxsize=2048)
def _plan_cached(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    phase: str,
    kv_layout: str,
    backend: str,
    interpret: bool,
    dtype_bytes: int,
    window: Optional[int],
    page_size: Optional[int],
    prefix_pages: int,
    mapping_name: str,
    impl: str,
    vmem_budget_bytes: int,
    num_devices: int,
    device_link_bw: Optional[float],
    kv_dtype: str,
) -> AttentionPlan:
    if mapping_name != "auto":
        mapping = PAPER_MAPPINGS[mapping_name]  # KeyError = fail fast
    elif phase == EXTEND and kv_layout == PAGED:
        # The paged prefill kernel takes no MappingConfig (its schedule is
        # the fixed head-first page walk); skip the candidate sweep and
        # carry the default paper schedule for introspection only.
        mapping = MappingConfig()
    else:
        mapping = _score_mapping(
            batch, num_q_heads, num_kv_heads, seq_q, seq_kv, head_dim,
            dtype_bytes, backend, vmem_budget_bytes,
            phase == DECODE, window,
        )

    chunk = None
    if phase == DECODE and kv_layout == DENSE:
        chunk = _decode_chunk(mapping, seq_kv)

    placement = None
    if kv_layout == PAGED:
        # Head-major pools are head-aligned by construction (cache.layout);
        # the plan records the placement the kernels assume.
        placement = "head_aligned"

    resolved_impl = _resolve_impl(phase, kv_layout, impl, backend)
    if phase == EXTEND and kv_layout == PAGED and impl == "auto" \
            and prefix_pages > 0:
        # Route choice (PR-4 satellite): the paged kernel reads the prefix
        # once but exposes only B x Hkv parallel cells; the gather route
        # pays ~3x the prefix bytes to recover the dense flash grid's
        # occupancy. perf_model charges both (occupancy factors included)
        # and the cheaper route wins — an explicitly pinned impl skips
        # this and goes through _resolve_impl's coercions above.
        resolved_impl = _score_extend_route(
            batch, num_q_heads, num_kv_heads, seq_q,
            prefix_pages * (page_size or 0), page_size, head_dim,
            dtype_bytes, backend,
        )

    num_splits = 1
    split_device_pure = None
    if phase == DECODE:
        # Split-K (PR 4): sequence-parallel decode, chosen by occupancy —
        # cells x splits vs the domain count, combine overhead charged
        # explicitly. The granule is what the kernel can actually split
        # at: KV chunks for the dense stripe, pages for the pool.
        # On a mesh (PR 9) the same sweep scores placement jointly over
        # (domain, device): device-pure ranges ride local HBM, straddled
        # ones pay the inter-device tier for crossing bytes.
        from repro.core import numa, perf_model

        granule = chunk if kv_layout == DENSE else page_size
        if granule:
            mesh = None
            if num_devices > 1:
                mesh = numa.mesh_topology(
                    num_devices, chip=_topology_for(backend),
                    device_link_bw=device_link_bw,
                )
            split = perf_model.estimate_decode_splits(
                batch=batch, num_q_heads=num_q_heads,
                num_kv_heads=num_kv_heads, seq_kv=seq_kv, granule=granule,
                head_dim=head_dim, dtype_bytes=dtype_bytes,
                topo=_topology_for(backend), window=window, mesh=mesh,
            )
            num_splits = split.num_splits
            split_device_pure = split.device_pure

    return AttentionPlan(
        phase=phase,
        kv_layout=kv_layout,
        impl=resolved_impl,
        mapping=mapping,
        backend=backend,
        interpret=interpret,
        chunk=chunk,
        page_size=page_size,
        prefix_pages=prefix_pages,
        window=window,
        placement=placement,
        num_splits=num_splits,
        num_devices=num_devices,
        split_device_pure=split_device_pure,
        kv_dtype=kv_dtype,
    )


def plan_attention(
    shape: Tuple[int, int, int, int, int, int],
    *,
    phase: str = PREFILL,
    kv_layout: str = DENSE,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    dtype_bytes: int = 2,
    window: Optional[int] = None,
    page_size: Optional[int] = None,
    prefix_pages: int = 0,
    mapping_name: str = "auto",
    impl: str = "auto",
    vmem_budget_bytes: int = MappingConfig.vmem_budget_bytes,
    num_devices: int = 1,
    device_link_bw: Optional[float] = None,
    kv_dtype: str = "fp32",
) -> AttentionPlan:
    """Resolve the best :class:`AttentionPlan` for an attention shape.

    ``shape`` is ``(batch, num_q_heads, num_kv_heads, seq_q, seq_kv,
    head_dim)``. Conventions per phase:

      * PREFILL: ``seq_q`` = ``seq_kv`` = the prompt length;
      * EXTEND:  ``seq_q`` = the tail length, ``seq_kv`` = prefix + tail
        (pass ``prefix_pages`` / ``page_size`` for the paged layout —
        ``prefix_pages`` is the *bucketed* page-table width, part of the
        plan so equal jit keys share one plan);
      * DECODE:  ``seq_q`` = 1, ``seq_kv`` = the cache capacity.

    ``backend`` defaults to the host's jit target and ``interpret`` to
    ``compat.use_interpret(backend)`` — both are part of the cache key, so
    flipping ``JAX_PLATFORMS`` between calls can never reuse a stale plan.
    ``mapping_name`` / ``impl`` carry the config policy ("auto" or a pinned
    ``PAPER_MAPPINGS`` name / kernel impl); this is the only layer that
    interprets them.

    ``num_devices`` > 1 scores DECODE split-K placement jointly over
    (domain, device) via ``numa.mesh_topology`` — ``device_link_bw``
    overrides the inter-device fabric figure (``None`` = the chip preset's
    link) — and records the verdict in ``plan.split_device_pure``.
    """
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; expected one of {PHASES}")
    if kv_layout not in (DENSE, PAGED):
        raise ValueError(f"unknown kv layout {kv_layout!r}")
    if kv_layout == PAGED and page_size is None:
        raise ValueError("paged plans require page_size")
    from repro.cache import quant as quant_lib

    quant_lib.validate_kv_dtype(kv_dtype)
    if kv_dtype != "fp32" and kv_layout != PAGED:
        raise ValueError(
            f"kv_dtype={kv_dtype!r} requires the paged KV layout "
            "(dense stripes are always fp32)"
        )
    if kv_dtype != "fp32":
        # Quantized pools stream 1-byte codes: the traffic/occupancy models
        # score the bytes that actually move.
        dtype_bytes = quant_lib.kv_itemsize(kv_dtype)
    b, hq, hkv, sq, skv, d = (int(x) for x in shape)
    backend = backend or compat.default_backend()
    if interpret is None:
        interpret = compat.use_interpret(backend)
    return _plan_cached(
        b, hq, hkv, sq, skv, d,
        phase, kv_layout, backend, bool(interpret),
        int(dtype_bytes),
        int(window) if window else None,
        int(page_size) if page_size else None,
        int(prefix_pages),
        mapping_name, impl,
        int(vmem_budget_bytes),
        int(num_devices),
        float(device_link_bw) if device_link_bw is not None else None,
        kv_dtype,
    )


@functools.lru_cache(maxsize=256)
def _plan_for_mapping_cached(
    mapping: MappingConfig,
    phase: str,
    kv_layout: str,
    backend: str,
    interpret: bool,
    impl: str,
    window: Optional[int],
) -> AttentionPlan:
    return AttentionPlan(
        phase=phase,
        kv_layout=kv_layout,
        impl=_resolve_impl(phase, kv_layout, impl, backend),
        mapping=mapping,
        backend=backend,
        interpret=interpret,
        window=window,
    )


def plan_for_mapping(
    mapping: MappingConfig,
    *,
    phase: str = PREFILL,
    kv_layout: str = DENSE,
    impl: str = "auto",
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    window: Optional[int] = None,
) -> AttentionPlan:
    """A plan carrying a caller-supplied ``MappingConfig`` verbatim (paper
    A/B pins, kernel tests): only the impl/backend environment is resolved
    — no candidate scoring runs for a schedule that is already decided."""
    if phase not in PHASES:
        raise ValueError(f"unknown phase {phase!r}; expected one of {PHASES}")
    backend = backend or compat.default_backend()
    if interpret is None:
        interpret = compat.use_interpret(backend)
    return _plan_for_mapping_cached(
        mapping, phase, kv_layout, backend, bool(interpret), impl,
        int(window) if window else None,
    )


# -----------------------------------------------------------------------------
# Config-policy helpers (the only readers of cfg.mapping_name / cfg.attn_impl)
# -----------------------------------------------------------------------------


def plan_for_config(
    cfg,
    shape: Tuple[int, int, int, int, int, int],
    *,
    phase: str = PREFILL,
    kv_layout: str = DENSE,
    window: Optional[int] = None,
    dtype_bytes: Optional[int] = None,
    page_size: Optional[int] = None,
    prefix_pages: int = 0,
    backend: Optional[str] = None,
    interpret: Optional[bool] = None,
    num_devices: int = 1,
    device_link_bw: Optional[float] = None,
    kv_dtype: str = "fp32",
) -> AttentionPlan:
    """:func:`plan_attention` with the schedule/impl policy read from a
    ``ModelConfig``. Models, engines and benchmarks call this instead of
    touching ``cfg.mapping_name`` / ``cfg.attn_impl`` themselves."""
    if dtype_bytes is None:
        import jax.numpy as jnp

        dtype_bytes = jnp.dtype(cfg.compute_dtype).itemsize
    return plan_attention(
        shape,
        phase=phase,
        kv_layout=kv_layout,
        backend=backend,
        interpret=interpret,
        dtype_bytes=dtype_bytes,
        window=window,
        page_size=page_size,
        prefix_pages=prefix_pages,
        mapping_name=getattr(cfg, "mapping_name", "auto"),
        impl=getattr(cfg, "attn_impl", "auto"),
        num_devices=num_devices,
        device_link_bw=device_link_bw,
        kv_dtype=kv_dtype,
    )


def with_mapping(cfg, mapping: Optional[str]):
    """Return ``cfg`` with its kernel-schedule policy overridden (and
    validated): ``mapping`` is "auto" or a ``PAPER_MAPPINGS`` name. A bad
    pinned name raises here, at engine construction, instead of surfacing
    mid-trace."""
    if mapping is not None and mapping != cfg.mapping_name:
        cfg = dataclasses.replace(cfg, mapping_name=mapping)
    if cfg.mapping_name != "auto":
        PAPER_MAPPINGS[cfg.mapping_name]  # KeyError = fail fast
    return cfg
