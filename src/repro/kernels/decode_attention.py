"""Flash-decode Pallas kernel: one new token against a long KV cache.

Serving-shape companion of ``flash_attention.py`` (decode_32k / long_500k
dry-run cells lower this). The ACC structure survives in decode: all query
heads of a GQA group read the same KV cache, so the q-block of the kernel is
the *whole group* — KV is fetched once per (batch, kv head) and the group
dimension rides the MXU rows. Grid order is head-first by construction
(one ACC per (b, hkv) grid cell), i.e. the paper's co-location applied to
decode; there is no block-first analogue because a single token has one row
block.

Sequence lengths are dynamic (per-request): ``lengths`` rides in SMEM and
gates both the masking and the chunk relevance test
(``decode_common.chunk_relevant``), so compute scales with the actual
prefix length, not the cache capacity.

Split-K (PR 4): with ``num_splits > 1`` a third PARALLEL grid axis
partitions the chunk walk into ``num_splits`` contiguous ranges
(``cache.layout.decode_split_ranges`` — the same boundary arithmetic the
paged kernel snaps to domain stripes). Each (b, hkv, split) cell emits its
partial online-softmax state ``(acc, m, l)`` instead of a normalized row,
and ``decode_common.combine_split_states`` merges the splits — so a
long-context, small-batch decode step exposes ``B x Hkv x num_splits``
parallel cells instead of idling all but ``B x Hkv`` compute domains.
``num_splits`` is chosen per shape by the plan layer
(``perf_model.estimate_decode_splits``); callers never hardcode it.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.cache import layout as layout_lib
from repro.kernels import decode_common

NEG_INF = decode_common.NEG_INF


def split_chunk_index_map(cps, num_chunks):
    """K/V BlockSpec index map of the dense split-K kernel for ``cps``
    chunks per split over ``num_chunks`` total. The tail split's overhang
    clamps to the last real chunk — the DMA must name a valid block; the
    kernel's range test skips its compute. Module-level so the access
    tracer replays the exact function handed to ``pallas_call``."""

    def kv_index(b_, h_, s_, j_):
        return (b_, h_, jnp.minimum(s_ * cps + j_, num_chunks - 1), 0)

    return kv_index


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, softcap, window, chunk, num_chunks, group_padded,
):
    n_idx = pl.program_id(2)
    length = len_ref[0, 0]

    @pl.when(n_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    chunk_start = n_idx * chunk

    @pl.when(decode_common.chunk_relevant(chunk_start, chunk, length, window))
    def _compute():
        decode_common.accumulate_kv_block(
            q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
            scale=scale, softcap=softcap, window=window,
            block_start=chunk_start, block_len=chunk, length=length,
        )

    @pl.when(n_idx == num_chunks - 1)
    def _emit():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def _decode_split_kernel(
    len_ref, q_ref, k_ref, v_ref, acc_out, m_out, l_out,
    acc_ref, m_ref, l_ref,
    *, scale, softcap, window, chunk, num_chunks, chunks_per_split,
):
    """Stage one of split-K decode: one (b, hkv, split) cell walks its
    chunk range and emits raw ``(acc, m, l)`` — no normalization here;
    the combine stage owns it. Ranges past ``num_chunks`` (non-divisible
    splits: the BlockSpec clamps their DMA to the last real chunk) are
    skipped by the relevance test and emit the empty state."""
    s_idx = pl.program_id(2)
    j_idx = pl.program_id(3)
    length = len_ref[0, 0]

    @pl.when(j_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_global = s_idx * chunks_per_split + j_idx
    chunk_start = n_global * chunk
    relevant = (n_global < num_chunks) & decode_common.chunk_relevant(
        chunk_start, chunk, length, window
    )

    @pl.when(relevant)
    def _compute():
        decode_common.accumulate_kv_block(
            q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
            scale=scale, softcap=softcap, window=window,
            block_start=chunk_start, block_len=chunk, length=length,
        )

    @pl.when(j_idx == chunks_per_split - 1)
    def _emit():
        acc_out[0, 0, 0] = acc_ref[...]
        m_out[0, 0, 0] = m_ref[...]
        l_out[0, 0, 0] = l_ref[...]


def flash_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    chunk: int = 512,
    num_splits: int = 1,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, D); caches: (B, Hkv, Smax, D); lengths: (B,) int32.

    Returns (B, Hq, D). Smax must be a multiple of ``chunk`` (ops.py pads).
    The GQA group dimension is padded to the sublane count inside.
    ``num_splits > 1`` runs the sequence-parallel (split-K) path: the
    chunk walk is partitioned across a PARALLEL grid axis and the partial
    softmax states are merged by ``decode_common.combine_split_states``
    (clamped to the chunk count; 1 keeps the one-pass kernel).
    """
    b, hq, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    chunk = min(chunk, smax)
    num_chunks = smax // chunk

    gp = max(8, -(-group // 8) * 8)  # pad group to sublane multiple
    qg = q.reshape(b, hkv, group, d)
    if gp != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - group), (0, 0)))
    lengths2d = lengths.reshape(b, 1).astype(jnp.int32)

    ranges = layout_lib.decode_split_ranges(num_chunks, num_splits)
    num_splits = len(ranges)
    if num_splits > 1:
        return _flash_decode_split(
            qg, k_cache, v_cache, lengths2d, ranges,
            scale=scale, softcap=softcap, window=window, chunk=chunk,
            num_chunks=num_chunks, gp=gp, group=group, interpret=interpret,
            out_dtype=q.dtype,
        )

    fn = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            scale=scale, softcap=softcap, window=window,
            chunk=chunk, num_chunks=num_chunks, group_padded=gp,
        ),
        grid=(b, hkv, num_chunks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, n_: (b_, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, gp, d), lambda b_, h_, n_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, n_: (b_, h_, n_, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, n_: (b_, h_, n_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, d), lambda b_, h_, n_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gp, d), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hq * smax * d),
            bytes_accessed=int(
                q.dtype.itemsize * b * (2 * hkv * smax * d + 2 * hq * d)
            ),
            transcendentals=int(b * hq * smax),
        ),
        interpret=interpret,
        name="flash_decode",
    )
    out = fn(lengths2d, qg, k_cache, v_cache)
    return out[:, :, :group, :].reshape(b, hq, d)


def _flash_decode_split(
    qg, k_cache, v_cache, lengths2d, ranges,
    *, scale, softcap, window, chunk, num_chunks, gp, group, interpret,
    out_dtype,
):
    b, hkv, _, d = k_cache.shape
    num_splits = len(ranges)
    cps = ranges[0][1] - ranges[0][0]  # chunks per split (tail may be short)

    kv_index = split_chunk_index_map(cps, num_chunks)

    fn = pl.pallas_call(
        functools.partial(
            _decode_split_kernel,
            scale=scale, softcap=softcap, window=window,
            chunk=chunk, num_chunks=num_chunks, chunks_per_split=cps,
        ),
        grid=(b, hkv, num_splits, cps),
        in_specs=[
            pl.BlockSpec(
                (1, 1), lambda b_, h_, s_, j_: (b_, 0),
                memory_space=pltpu.SMEM,
            ),
            pl.BlockSpec((1, 1, gp, d), lambda b_, h_, s_, j_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, chunk, d), kv_index),
            pl.BlockSpec((1, 1, chunk, d), kv_index),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, 1, 1, gp, d), lambda b_, h_, s_, j_: (b_, h_, s_, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, 1, gp, 128), lambda b_, h_, s_, j_: (b_, h_, s_, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, 1, gp, 128), lambda b_, h_, s_, j_: (b_, h_, s_, 0, 0)
            ),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, num_splits, gp, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, num_splits, gp, 128), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, num_splits, gp, 128), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((gp, d), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hkv * group * num_chunks * chunk * d),
            bytes_accessed=int(
                k_cache.dtype.itemsize
                * b * (2 * hkv * num_chunks * chunk * d + 2 * hkv * group * d)
            ),
            transcendentals=int(b * hkv * group * num_chunks * chunk),
        ),
        interpret=interpret,
        name="flash_decode_split",
    )
    acc, m, l = fn(lengths2d, qg, k_cache, v_cache)
    out = decode_common.combine_split_states(acc, m[..., :1], l[..., :1])
    return out[:, :, :group, :].reshape(b, hkv * group, d).astype(out_dtype)
