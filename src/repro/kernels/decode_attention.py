"""Flash-decode Pallas kernel: one new token against a long KV cache.

Serving-shape companion of ``flash_attention.py`` (decode_32k / long_500k
dry-run cells lower this). The ACC structure survives in decode: all query
heads of a GQA group read the same KV cache, so the q-block of the kernel is
the *whole group* — KV is fetched once per (batch, kv head) and the group
dimension rides the MXU rows. Grid order is head-first by construction
(one ACC per (b, hkv) grid cell), i.e. the paper's co-location applied to
decode; there is no block-first analogue because a single token has one row
block.

Sequence lengths are dynamic (per-request): ``lengths`` rides in SMEM and
gates both the masking and the chunk relevance test, so compute scales with
the actual prefix length, not the cache capacity.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _decode_kernel(
    len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, softcap, window, chunk, num_chunks, group_padded,
):
    n_idx = pl.program_id(2)
    length = len_ref[0, 0]

    @pl.when(n_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    chunk_start = n_idx * chunk
    relevant = chunk_start < length
    if window is not None and window > 0:
        relevant &= chunk_start + chunk - 1 >= length - 1 - window + 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (Gp, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (chunk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        pos = chunk_start + jax.lax.broadcasted_iota(jnp.int32, (1, chunk), 1)
        valid = pos < length
        if window is not None and window > 0:
            valid &= pos > length - 1 - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_ref.shape
        )
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(n_idx == num_chunks - 1)
    def _emit():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    chunk: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, D); caches: (B, Hkv, Smax, D); lengths: (B,) int32.

    Returns (B, Hq, D). Smax must be a multiple of ``chunk`` (ops.py pads).
    The GQA group dimension is padded to the sublane count inside.
    """
    b, hq, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    chunk = min(chunk, smax)
    num_chunks = smax // chunk

    gp = max(8, -(-group // 8) * 8)  # pad group to sublane multiple
    qg = q.reshape(b, hkv, group, d)
    if gp != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - group), (0, 0)))
    lengths2d = lengths.reshape(b, 1).astype(jnp.int32)

    fn = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            scale=scale, softcap=softcap, window=window,
            chunk=chunk, num_chunks=num_chunks, group_padded=gp,
        ),
        grid=(b, hkv, num_chunks),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b_, h_, n_: (b_, 0), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1, gp, d), lambda b_, h_, n_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, n_: (b_, h_, n_, 0)),
            pl.BlockSpec((1, 1, chunk, d), lambda b_, h_, n_: (b_, h_, n_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, gp, d), lambda b_, h_, n_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((gp, d), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
            pltpu.VMEM((gp, 128), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hq * smax * d),
            bytes_accessed=int(
                q.dtype.itemsize * b * (2 * hkv * smax * d + 2 * hq * d)
            ),
            transcendentals=int(b * hq * smax),
        ),
        interpret=interpret,
        name="flash_decode",
    )
    out = fn(lengths2d, qg, k_cache, v_cache)
    return out[:, :, :group, :].reshape(b, hq, d)
