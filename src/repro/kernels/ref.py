"""Pure-jnp oracles for every kernel in this package.

These are the ground truth for the per-kernel allclose sweeps in
``tests/test_flash_attention.py`` / ``tests/test_decode_attention.py``.
They implement exact (non-flash) attention in float32 with all the mask /
softcap / GQA variants the assigned architectures need. Gradients of the
Pallas backward kernels are checked against ``jax.grad`` of these.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

NEG_INF = -1e30  # large-but-finite: keeps fully-masked rows NaN-free


def _expand_kv(x: jnp.ndarray, group: int) -> jnp.ndarray:
    """(B, Hkv, S, D) -> (B, Hkv*group, S, D) by repetition (GQA)."""
    if group == 1:
        return x
    b, hkv, s, d = x.shape
    return jnp.repeat(x, group, axis=1)


def attention_mask(
    q_len: int,
    kv_len: int,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """(q_len, kv_len) boolean mask. True = attend.

    ``window``: sliding-window size W — position i attends to [i-W+1, i]
    (Mistral/Gemma-style local attention). ``q_offset`` positions the query
    block absolutely (decode: q_offset = kv_len - q_len).
    """
    rows = jnp.arange(q_len)[:, None] + q_offset
    cols = jnp.arange(kv_len)[None, :]
    mask = jnp.ones((q_len, kv_len), dtype=bool)
    if causal:
        mask &= cols <= rows
    if window is not None and window > 0:
        mask &= cols > rows - window
    return mask


def attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Exact multi-head attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D) with Hq % Hkv == 0.
    Returns (B, Hq, Sq, D) in q.dtype; internals run in float32.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    k = _expand_kv(k, group)
    v = _expand_kv(v, group)
    if scale is None:
        scale = 1.0 / d**0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if causal or window is not None:
        mask = attention_mask(sq, skv, causal=causal, window=window, q_offset=q_offset)
        s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p / l, v.astype(jnp.float32))
    return o.astype(q.dtype)


def attention_lse(
    q, k, v, *, causal=True, window=None, softcap=None, scale=None, q_offset=0
) -> jnp.ndarray:
    """Row logsumexp of the (scaled, capped, masked) logits — the auxiliary
    output of the flash forward used by the backward pass. (B, Hq, Sq)."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    k = _expand_kv(k, hq // hkv)
    if scale is None:
        scale = 1.0 / d**0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    if causal or window is not None:
        mask = attention_mask(sq, skv, causal=causal, window=window, q_offset=q_offset)
        s = jnp.where(mask[None, None], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    return m + jnp.log(jnp.sum(jnp.exp(s - m[..., None]), axis=-1))


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Single-token decode oracle.

    q: (B, Hq, D) — one new token per sequence;
    k_cache/v_cache: (B, Hkv, Smax, D); lengths: (B,) valid prefix lengths
    (the new token is at position lengths-1).
    Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    group = hq // hkv
    k = _expand_kv(k_cache, group)
    v = _expand_kv(v_cache, group)
    if scale is None:
        scale = 1.0 / d**0.5
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(smax)[None, None, :]
    valid = pos < lengths[:, None, None]
    if window is not None and window > 0:
        valid &= pos > (lengths[:, None, None] - 1 - window)
    s = jnp.where(valid, s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    # Zero masked probabilities and guard the normalizer so a fully-masked
    # row (length == 0 slot) yields exactly 0, matching the flash kernels'
    # l == 0 emit path, instead of a mean over garbage cache rows.
    p = jnp.where(valid, jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhk,bhkd->bhd", p / jnp.where(l == 0.0, 1.0, l),
                   v.astype(jnp.float32))
    return o.astype(q.dtype)


def split_decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    num_splits: int,
    granule: int,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
) -> jnp.ndarray:
    """Split-K decode oracle: exact per-range softmax states, combined.

    Independently re-implements what the split-K kernels compute — the KV
    axis is partitioned into the same unit-granular ranges
    (``cache.layout.decode_split_ranges`` over ``granule``-sized units:
    chunks for the dense kernel, pages for the paged one), each range
    contributes its exact ``(acc, m, l)`` state, and the states merge by
    rescaling to the global row max. Lets tests check the *split
    semantics* (range partitioning + state merge) against ground truth
    rather than only end-to-end outputs. Shapes as
    :func:`decode_attention`.
    """
    from repro.cache.layout import decode_split_ranges

    b, hq, d = q.shape
    _, hkv, smax, _ = k_cache.shape
    k = _expand_kv(k_cache, hq // hkv)
    v = _expand_kv(v_cache, hq // hkv)
    if scale is None:
        scale = 1.0 / d**0.5
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(smax)[None, None, :]
    valid = pos < lengths[:, None, None]
    if window is not None and window > 0:
        valid &= pos > (lengths[:, None, None] - 1 - window)
    s = jnp.where(valid, s, NEG_INF)

    num_units = -(-smax // granule)
    states = []  # (m, l, acc) per range, fully-masked ranges included
    for start, end in decode_split_ranges(num_units, num_splits):
        lo, hi = start * granule, min(end * granule, smax)
        sr = s[:, :, lo:hi]
        vr = valid[:, :, lo:hi]
        if sr.shape[-1] == 0:
            m_r = jnp.full(s.shape[:2] + (1,), NEG_INF)
            l_r = jnp.zeros_like(m_r)
            acc_r = jnp.zeros(s.shape[:2] + (d,), jnp.float32)
        else:
            # An all-masked range must contribute the empty state exactly
            # (m = NEG_INF), matching a split whose relevance test never
            # fired, not max(NEG_INF-masked scores).
            any_live = jnp.any(vr, axis=-1, keepdims=True)
            m_r = jnp.where(
                any_live, jnp.max(sr, axis=-1, keepdims=True), NEG_INF
            )
            p_r = jnp.where(vr, jnp.exp(sr - m_r), 0.0)
            l_r = jnp.sum(p_r, axis=-1, keepdims=True)
            acc_r = jnp.einsum(
                "bhk,bhkd->bhd", p_r, v[:, :, lo:hi].astype(jnp.float32)
            )
        states.append((m_r, l_r, acc_r))

    m_all = jnp.stack([m_ for m_, _, _ in states])           # (S, B, H, 1)
    m_star = jnp.max(m_all, axis=0)
    alpha = jnp.exp(m_all - m_star[None])
    l_star = sum(a_ * l_ for a_, (_, l_, _) in zip(alpha, states))
    acc_star = sum(a_ * acc_ for a_, (_, _, acc_) in zip(alpha, states))
    o = acc_star / jnp.where(l_star == 0.0, 1.0, l_star)
    return o.astype(q.dtype)


def gather_pages(
    pages: jnp.ndarray,
    page_table: jnp.ndarray,
    scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Materialize a dense fp32 cache view from head-major pages.

    pages: (Hkv, P, page_size, D); page_table: (B, max_pages) physical ids.
    Returns (B, Hkv, max_pages * page_size, D) — logical order per sequence.

    ``scales`` (``(Hkv, P)`` fp32) marks the pool as quantized codes
    (``cache.quant``): each gathered page is dequantized by its
    per-(head, page) scale — the oracle form of the kernels' in-VMEM
    dequant, keyed by the same physical page ids.
    """
    hkv, _, ps, d = pages.shape
    b, mp = page_table.shape
    g = jnp.take(pages, page_table.reshape(-1), axis=1)  # (Hkv, B*mp, ps, D)
    if scales is not None:
        s = jnp.take(scales, page_table.reshape(-1), axis=1)  # (Hkv, B*mp)
        g = g.astype(jnp.float32) * s[..., None, None]
    return g.reshape(hkv, b, mp * ps, d).transpose(1, 0, 2, 3)


def paged_prefill_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    k_tail: jnp.ndarray,
    v_tail: jnp.ndarray,
    prefix_len: jnp.ndarray,
    tail_len: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Prefix-extension prefill oracle: gather the prefix pages to a dense
    view (exactly what the paged prefill kernel avoids), concatenate the
    tail K/V, and run exact attention with per-row dynamic offsets.

    q/k_tail/v_tail: (B, H*, St, D); k/v_pages: (Hkv, P, ps, D);
    page_table: (B, mp); prefix_len/tail_len: (B,) live prefix/tail tokens.
    Rows at or past ``tail_len`` emit exact zeros. ``k_scales``/
    ``v_scales`` dequantize quantized pools (see :func:`gather_pages`).
    Returns (B, Hq, St, D).
    """
    if (k_scales is None) != (v_scales is None):
        raise ValueError("k_scales and v_scales must be passed together")
    b, hq, st, d = q.shape
    hkv = k_pages.shape[0]
    group = hq // hkv
    kp = gather_pages(k_pages, page_table, k_scales)  # (B, Hkv, sp, D)
    vp = gather_pages(v_pages, page_table, v_scales)
    kp = kp.astype(k_tail.dtype)
    vp = vp.astype(v_tail.dtype)
    sp = kp.shape[2]
    k = _expand_kv(jnp.concatenate([kp, k_tail], axis=2), group)
    v = _expand_kv(jnp.concatenate([vp, v_tail], axis=2), group)
    if scale is None:
        scale = 1.0 / d**0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    rows = jnp.arange(st)[None, :]                              # tail-local
    rows_abs = prefix_len[:, None] + rows                       # (B, St)
    col_pref = jnp.arange(sp)[None, :]                          # absolute
    col_tail = jnp.arange(st)[None, :]                          # tail-local
    # (B, St, sp): live prefix columns (always causally visible).
    m_pref = jnp.broadcast_to(
        (col_pref < prefix_len[:, None])[:, None, :], (b, st, sp)
    )
    # (B, St, St): causal within the tail, bucket padding masked.
    m_tail = (col_tail[:, None, :] <= rows[:, :, None]) & (
        (col_tail < tail_len[:, None])[:, None, :]
    )
    m_tail = jnp.broadcast_to(m_tail, (b, st, st))
    mask = jnp.concatenate([m_pref, m_tail], axis=-1)           # (B, St, K)
    if window is not None and window > 0:
        col_abs = jnp.concatenate(
            [jnp.broadcast_to(col_pref, (b, sp)),
             prefix_len[:, None] + col_tail], axis=-1
        )                                                       # (B, K)
        mask &= col_abs[:, None, :] > rows_abs[:, :, None] - window
    mask &= (rows < tail_len[:, None])[:, :, None]              # dead rows
    s = jnp.where(mask[:, None], s, NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask[:, None], jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum(
        "bhqk,bhkd->bhqd", p / jnp.where(l == 0.0, 1.0, l),
        v.astype(jnp.float32),
    )
    return o.astype(q.dtype)


def paged_decode_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Paged decode oracle: gather pages to a dense cache (dequantizing
    quantized pools by their per-(head, page) scales), then the dense
    oracle. The gather is exactly what the paged Pallas kernel avoids."""
    if (k_scales is None) != (v_scales is None):
        raise ValueError("k_scales and v_scales must be passed together")
    k = gather_pages(k_pages, page_table, k_scales)
    v = gather_pages(v_pages, page_table, v_scales)
    return decode_attention(
        q, k, v, lengths, softcap=softcap, scale=scale, window=window
    )
