"""FlashAttention-2 backward Pallas kernels (dQ and dK/dV).

Mirrors the paper's §4.6 evaluation: the backward pass has the same ACC
structure as the forward (all row blocks of a head share K/V; all column
blocks share Q/dO), so the same head-first vs block-first grid-order choice
applies. Two kernels, following the standard FA2 decomposition:

  * ``_dq_kernel``  — grid over (batch, q-head, q-block, kv-block): streams
    K/V, accumulates dQ in VMEM scratch, emits on the last kv-block.
  * ``_dkv_kernel`` — grid over (batch, kv-head, kv-block, group, q-block):
    K/V tile is revisited across the whole (group x q-block) inner sweep —
    fetched once per ACC under head-first order — while Q/dO/LSE/delta
    stream. dK/dV accumulate across the GQA group inside the kernel, so no
    (B, Hq, S, D)-sized partials ever materialize.

Numerics: p is recomputed from the saved forward LSE; the softcap derivative
(1 - tanh^2) is folded in when configured. Rows whose LSE is -inf (padding /
fully-masked) contribute nothing.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

from repro.kernels.flash_attention import (
    BLOCK_FIRST,
    HEAD_FIRST,
    NEG_INF,
    MappingConfig,
    _apply_softcap,
    _block_mask,
    _dim_semantics,
)


def _recompute_p(q, k, lse, rows, cols, *, scale, causal, window, softcap, kv_len):
    """Recompute the (block_m, block_n) probability tile and the capped
    logits (needed for the softcap chain rule)."""
    s_raw = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale
    s = _apply_softcap(s_raw, softcap)
    mask = _block_mask(rows, cols, causal=causal, window=window, kv_len=kv_len)
    valid_row = lse > NEG_INF / 2  # (bm, 1): padding / fully-masked guard
    p = jnp.where(mask & valid_row, jnp.exp(s - lse), 0.0)
    return p, s, mask


def _ds_raw(p, dp, delta, s_capped, softcap):
    ds = p * (dp - delta)
    if softcap is not None and softcap > 0:
        ds = ds * (1.0 - (s_capped / softcap) ** 2)
    return ds


def _dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_acc,
    *, scale, causal, window, softcap, kv_len, num_n, block_m, block_n, order,
):
    m_idx = pl.program_id(2) if order == HEAD_FIRST else pl.program_id(1)
    n_idx = pl.program_id(3)

    @pl.when(n_idx == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    q_start = m_idx * block_m
    kv_start = n_idx * block_n
    relevant = kv_start < kv_len
    if causal:
        relevant &= kv_start <= q_start + block_m - 1
    if window is not None and window > 0:
        relevant &= kv_start + block_n - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
        p, s_capped, _ = _recompute_p(
            q, k, lse, rows, cols,
            scale=scale, causal=causal, window=window, softcap=softcap, kv_len=kv_len,
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = _ds_raw(p, dp, delta, s_capped, softcap)
        dq_acc[...] += scale * jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(n_idx == num_n - 1)
    def _emit():
        dq_ref[0, 0] = dq_acc[...].astype(dq_ref.dtype)


def _dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc, dv_acc,
    *, scale, causal, window, softcap, kv_len, num_m, group, block_m, block_n, order,
):
    n_idx = pl.program_id(2) if order == HEAD_FIRST else pl.program_id(1)
    g_idx = pl.program_id(3)
    m_idx = pl.program_id(4)

    @pl.when((g_idx == 0) & (m_idx == 0))
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q_start = m_idx * block_m
    kv_start = n_idx * block_n
    relevant = kv_start < kv_len
    if causal:
        relevant &= q_start + block_m - 1 >= kv_start
    if window is not None and window > 0:
        relevant &= q_start <= kv_start + block_n - 1 + window - 1

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0][:, None]
        delta = delta_ref[0, 0][:, None]
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)
        cols = kv_start + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
        p, s_capped, _ = _recompute_p(
            q, k, lse, rows, cols,
            scale=scale, causal=causal, window=window, softcap=softcap, kv_len=kv_len,
        )
        # dV += P^T dO ; dP = dO V^T ; dS = P*(dP - delta) ; dK += dS^T Q
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = _ds_raw(p, dp, delta, s_capped, softcap)
        dk_acc[...] += scale * jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when((g_idx == group - 1) & (m_idx == num_m - 1))
    def _emit():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _bwd_cost(b, hq, sq, skv, d, causal, dtype_bytes):
    frac = 0.5 if causal and sq == skv else 1.0
    flops = 10.0 * b * hq * sq * skv * d * frac  # 5 matmuls
    bytes_accessed = dtype_bytes * b * hq * (4 * sq * d + 4 * skv * d)
    return pl.CostEstimate(
        flops=int(flops),
        bytes_accessed=int(bytes_accessed),
        transcendentals=int(b * hq * sq * skv * frac),
    )


def flash_attention_bwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    o: jnp.ndarray,
    lse: jnp.ndarray,
    do: jnp.ndarray,
    *,
    mapping: MappingConfig = MappingConfig(),
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_len: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (dq, dk, dv). Shapes as in the forward."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    if kv_len is None:
        kv_len = skv
    bm = min(mapping.block_m, sq)
    bn = min(mapping.block_n, skv)
    num_m, num_n = sq // bm, skv // bn

    # delta = rowsum(dO * O): tiny elementwise reduction, done in XLA.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    # ---- dQ ----
    if mapping.order == HEAD_FIRST:
        def gidx3(b_, h_, m_):
            return b_, h_, m_
        dq_grid = (b, hq, num_m, num_n)
    else:
        def gidx3(b_, m_, h_):
            return b_, h_, m_
        dq_grid = (b, num_m, hq, num_n)

    def q_idx(*g):
        b_, h_, m_ = gidx3(*g[:3])
        return (b_, h_, m_, 0)

    def kv_idx(*g):
        b_, h_, m_ = gidx3(*g[:3])
        return (b_, h_ // group, g[3], 0)

    def row_idx(*g):
        return gidx3(*g[:3])

    dq_fn = pl.pallas_call(
        functools.partial(
            _dq_kernel,
            scale=scale, causal=causal, window=window, softcap=softcap,
            kv_len=kv_len, num_n=num_n, block_m=bm, block_n=bn, order=mapping.order,
        ),
        grid=dq_grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, d), q_idx),
            pl.BlockSpec((1, 1, bn, d), kv_idx),
            pl.BlockSpec((1, 1, bn, d), kv_idx),
            pl.BlockSpec((1, 1, bm, d), q_idx),
            pl.BlockSpec((1, 1, bm), row_idx),
            pl.BlockSpec((1, 1, bm), row_idx),
        ],
        out_specs=pl.BlockSpec((1, 1, bm, d), q_idx),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bm, d), jnp.float32)],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=_dim_semantics(
                mapping.order, mapping.acc_parallel, len(dq_grid)
            ),
        ),
        cost_estimate=_bwd_cost(b, hq, sq, skv, d, causal, q.dtype.itemsize),
        interpret=interpret,
        name=f"fa2_dq_{mapping.order}",
    )
    dq = dq_fn(q, k, v, do, lse, delta)

    # ---- dK/dV ----
    if mapping.order == HEAD_FIRST:
        def gidx_kv(b_, hkv_, n_):
            return b_, hkv_, n_
        dkv_grid = (b, hkv, num_n, group, num_m)
    else:
        def gidx_kv(b_, n_, hkv_):
            return b_, hkv_, n_
        dkv_grid = (b, num_n, hkv, group, num_m)

    def kv_idx2(*g):
        b_, hkv_, n_ = gidx_kv(*g[:3])
        return (b_, hkv_, n_, 0)

    def q_idx2(*g):
        b_, hkv_, n_ = gidx_kv(*g[:3])
        return (b_, hkv_ * group + g[3], g[4], 0)

    def row_idx2(*g):
        b_, hkv_, n_ = gidx_kv(*g[:3])
        return (b_, hkv_ * group + g[3], g[4])

    dkv_fn = pl.pallas_call(
        functools.partial(
            _dkv_kernel,
            scale=scale, causal=causal, window=window, softcap=softcap,
            kv_len=kv_len, num_m=num_m, group=group, block_m=bm, block_n=bn,
            order=mapping.order,
        ),
        grid=dkv_grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, d), q_idx2),
            pl.BlockSpec((1, 1, bn, d), kv_idx2),
            pl.BlockSpec((1, 1, bn, d), kv_idx2),
            pl.BlockSpec((1, 1, bm, d), q_idx2),
            pl.BlockSpec((1, 1, bm), row_idx2),
            pl.BlockSpec((1, 1, bm), row_idx2),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bn, d), kv_idx2),
            pl.BlockSpec((1, 1, bn, d), kv_idx2),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, skv, d), k.dtype),
            jax.ShapeDtypeStruct((b, hkv, skv, d), v.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((bn, d), jnp.float32),
            pltpu.VMEM((bn, d), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=_dim_semantics(
                mapping.order, mapping.acc_parallel, len(dkv_grid)
            ),
        ),
        cost_estimate=_bwd_cost(b, hq, sq, skv, d, causal, q.dtype.itemsize),
        interpret=interpret,
        name=f"fa2_dkv_{mapping.order}",
    )
    dk, dv = dkv_fn(q, k, v, do, lse, delta)
    return dq, dk, dv
