"""Paged prefix-aware prefill Pallas kernel (PR-3 headline).

Prefix-extension prefill: a request whose first ``prefix_len`` tokens were
already prefilled by an earlier request sharing the prefix (paged engine,
``cache.prefix``) only computes its tail's Q/K/V — but every tail query
must still attend the whole prefix. Before this kernel that meant
gathering the prefix's pages to a dense view and running the XLA
``q_offset`` flash path (off Pallas entirely). Here the prefix K/V is read
**straight from the page table**, exactly like ``paged_decode_attention``:

  * the page table rides in SMEM via scalar prefetch and the prefix K/V
    BlockSpec index maps read it directly
    (``index_map = lambda b, h, s, pt, ...: (h, pt[b, s], 0, 0)``) — no
    gather, no dense copy;
  * grid ``(B, Hkv, prefix_pages + tail_tiles)`` is head-first: the leading
    two dims stay PARALLEL so a megacore splits at ACC boundaries, and the
    head-major pool keeps every page in its head's domain stripe
    (``cache.layout.HEAD_ALIGNED`` by construction);
  * the whole GQA group rides in the q block (``(G*Sq, D)`` folded rows),
    so each prefix page is fetched once per (batch, kv-head) — never per
    q-head — the paper's ACC co-location carried into prefill.

The KV walk is two-phase under one online softmax: steps ``< prefix_pages``
sweep the scalar-prefetched pages, later steps sweep the dense tail K/V
(just produced by the projections; the caller scatters it into fresh pages
afterwards). Lengths are **dynamic**: ``prefix_len`` (B,) masks the live
prefix inside a power-of-two-bucketed page table (entries past the live
prefix hold the reserved null page — the copy still issues, the compute is
skipped), and ``tail_len`` (B,) masks bucket padding; rows at or past the
live tail emit exact zeros, so length-0 tails are well-defined.

The XLA ``flash_attention(q_offset=...)`` route survives as the oracle this
kernel is tested against in interpret mode (tests/test_paged_prefill.py).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def prefix_page_index_map(mp):
    """K/V prefix-page BlockSpec index map for an ``mp``-column page table:
    grid cell (batch, kv-head, step) DMAs physical page ``pt[b, s]`` of
    head ``h``. Tail-sweep steps (``s >= mp``) clamp to the last table
    entry — the copy still issues (a valid physical page; the engine
    null-pads) but compute is gated off by the phase predicate.
    Module-level so the domain-purity access tracer replays the exact
    function handed to ``pallas_call``."""

    def page_idx(b_, h_, s_, pt, plen, tlen, *scales):
        return (h_, pt[b_, jnp.minimum(s_, mp - 1)], 0, 0)

    return page_idx


def _paged_prefill_kernel(
    pt_ref, plen_ref, tlen_ref,   # scalar-prefetch: (B, mp), (B,), (B,)
    *refs,                        # [ks, vs,] q, kp, vp, kt, vt, o, acc, m, l
    scale, softcap, window, page_size, num_prefix, num_tail, seq_tail,
    quantized,
):
    if quantized:
        (ks_ref, vs_ref, q_ref, kp_ref, vp_ref, kt_ref, vt_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        (q_ref, kp_ref, vp_ref, kt_ref, vt_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
        ks_ref = vs_ref = None
    b_idx = pl.program_id(0)
    h_idx = pl.program_id(1)
    s_idx = pl.program_id(2)
    plen = plen_ref[b_idx]
    tlen = tlen_ref[b_idx]
    num_steps = num_prefix + num_tail
    rows = q_ref.shape[2]          # G * seq_tail (GQA group folded in)

    @pl.when(s_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Tail-local row index of each folded (group, tail-position) row; its
    # absolute position is plen + row_i. Rows at/past the live tail are
    # fully masked and emit zeros.
    row_i = jax.lax.broadcasted_iota(jnp.int32, (rows, page_size), 0) % seq_tail
    row_ok = row_i < tlen

    def online_update(s, valid, v):
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True),
            l_ref.shape,
        )
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    def scores(k):
        q = q_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        return s

    # ---- phase A: prefix pages (page-table-indirected) -------------------
    prefix_live = s_idx * page_size < plen
    if window is not None and window > 0:
        # Pages wholly before the earliest row's window (rows start at
        # absolute position plen) contribute nothing — skip the compute,
        # as the decode kernel does; the in-mask handles the boundary.
        prefix_live &= s_idx * page_size + page_size - 1 >= plen - window

    @pl.when((s_idx < num_prefix) & prefix_live)
    def _prefix():
        k = kp_ref[0, 0].astype(jnp.float32)     # (page_size, D)
        v = vp_ref[0, 0].astype(jnp.float32)
        if quantized:
            # The prefix pages are quantized codes; their per-(head, page)
            # scales prefetched next to the page table dequantize them
            # here, in VMEM. The dense tail (phase B) is fresh fp32.
            pid = pt_ref[b_idx, jnp.minimum(s_idx, num_prefix - 1)]
            k = k * ks_ref[h_idx, pid]
            v = v * vs_ref[h_idx, pid]
        col = s_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1
        )
        # Prefix columns are always causally visible (col < plen <= row
        # absolute position); only liveness and the window mask apply.
        valid = (col < plen) & row_ok
        if window is not None and window > 0:
            valid &= col > (plen + row_i) - window
        online_update(scores(k), valid, v)

    # ---- phase B: dense tail (freshly projected K/V) ---------------------
    t_idx = s_idx - num_prefix
    @pl.when((s_idx >= num_prefix) & (t_idx * page_size < tlen))
    def _tail():
        k = kt_ref[0, 0].astype(jnp.float32)     # (page_size, D)
        v = vt_ref[0, 0].astype(jnp.float32)
        col = t_idx * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1
        )
        # Tail columns sit at absolute plen + col: causality and the window
        # reduce to tail-local comparisons (plen cancels).
        valid = (col <= row_i) & (col < tlen) & row_ok
        if window is not None and window > 0:
            valid &= col > row_i - window
        online_update(scores(k), valid, v)

    @pl.when(s_idx == num_steps - 1)
    def _emit():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (
            acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
        ).astype(o_ref.dtype)


def paged_flash_prefill(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    k_tail: jnp.ndarray,
    v_tail: jnp.ndarray,
    prefix_len: jnp.ndarray,
    tail_len: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    interpret: bool = False,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Prefix-extension prefill over paged prefix K/V + dense tail K/V.

    q: (B, Hq, St, D) tail queries at absolute positions
    ``prefix_len[b] + i``; k/v_pages: (Hkv, P, page_size, D) head-major
    pool; page_table: (B, max_prefix_pages) physical page ids in logical
    order (entries past the live prefix must hold a valid id — the null
    page); k/v_tail: (B, Hkv, St, D) the tail's freshly projected K/V;
    prefix_len: (B,) live prefix tokens (<= max_prefix_pages * page_size,
    need not be a page multiple); tail_len: (B,) live tail tokens (rows
    past it emit zeros). Returns (B, Hq, St, D).

    ``k_scales`` / ``v_scales`` (``(Hkv, P)`` fp32, both or neither):
    quantized-pool mode — the prefix pages hold 1-byte codes and their
    scales prefetch into SMEM next to the page table; the kernel
    dequantizes each prefix page in VMEM. The dense tail K/V stays fp32
    either way (it was just projected; quantization happens when the
    engine scatters it into pages).
    """
    b, hq, st, d = q.shape
    hkv, _, page_size, _ = k_pages.shape
    if k_tail.shape != (b, hkv, st, d):
        raise ValueError(
            f"tail K/V shape {k_tail.shape} != {(b, hkv, st, d)}"
        )
    if hq % hkv:
        raise ValueError(f"Hq={hq} not divisible by Hkv={hkv}")
    if page_size % 8:
        raise ValueError(f"page_size {page_size} must be a sublane multiple (8)")
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5

    # Pad the tail to whole page-size tiles (the padded tail rows/cols are
    # masked via tail_len) so the tail sweep reuses the page tile shape.
    st_p = max(page_size, -(-st // page_size) * page_size)
    if st_p != st:
        pad = ((0, 0), (0, 0), (0, st_p - st), (0, 0))
        q = jnp.pad(q, pad)
        k_tail = jnp.pad(k_tail, pad)
        v_tail = jnp.pad(v_tail, pad)
    num_tail = st_p // page_size

    # An empty page table would break the clamped index map; give it one
    # (never-live) column so prefix_len == 0 batches still trace.
    mp = page_table.shape[1]
    if mp == 0:
        page_table = jnp.zeros((b, 1), jnp.int32)
        mp = 1

    # Fold the GQA group into the q block: each page is then fetched once
    # per (batch, kv-head) grid cell, never per q-head. st_p is a multiple
    # of page_size >= 8, so the folded row count stays sublane-aligned.
    rows = group * st_p
    qg = q.reshape(b, hkv, rows, d)

    if (k_scales is None) != (v_scales is None):
        raise ValueError("k_scales and v_scales must be passed together")
    quantized = k_scales is not None

    grid = (b, hkv, mp + num_tail)
    kernel = functools.partial(
        _paged_prefill_kernel,
        scale=scale, softcap=softcap, window=window,
        page_size=page_size, num_prefix=mp, num_tail=num_tail, seq_tail=st_p,
        quantized=quantized,
    )

    page_idx = prefix_page_index_map(mp)

    def tail_idx(b_, h_, s_, pt, plen, tlen, *scales):
        return (b_, h_, jnp.clip(s_ - mp, 0, num_tail - 1), 0)

    def q_idx(b_, h_, s_, pt, plen, tlen, *scales):
        return (b_, h_, 0, 0)

    prefetch = [
        page_table.astype(jnp.int32),
        prefix_len.astype(jnp.int32),
        tail_len.astype(jnp.int32),
    ]
    if quantized:
        prefetch += [k_scales.astype(jnp.float32),
                     v_scales.astype(jnp.float32)]

    fn = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, rows, d), q_idx),
                pl.BlockSpec((1, 1, page_size, d), page_idx),
                pl.BlockSpec((1, 1, page_size, d), page_idx),
                pl.BlockSpec((1, 1, page_size, d), tail_idx),
                pl.BlockSpec((1, 1, page_size, d), tail_idx),
            ],
            out_specs=pl.BlockSpec((1, 1, rows, d), q_idx),
            scratch_shapes=[
                pltpu.VMEM((rows, d), jnp.float32),
                pltpu.VMEM((rows, 128), jnp.float32),
                pltpu.VMEM((rows, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hq * st_p * (mp * page_size + st_p) * d),
            bytes_accessed=int(
                q.dtype.itemsize
                * b * hkv * (2 * (mp + num_tail) * page_size * d
                             + 2 * group * st_p * d)
            ),
            transcendentals=int(b * hq * st_p * (mp * page_size + st_p)),
        ),
        interpret=interpret,
        name="paged_flash_prefill",
    )
    out = fn(*prefetch, qg, k_pages, v_pages, k_tail, v_tail)
    return out.reshape(b, hq, st_p, d)[:, :, :st]
