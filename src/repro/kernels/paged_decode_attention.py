"""Paged flash-decode Pallas kernel: decode over a page-table-indirected KV.

The serving-native sibling of ``decode_attention.py``. There the KV cache is
a dense per-slot stripe ``(B, Hkv, Smax, D)``; here it is a pool of
fixed-size pages ``(Hkv, num_pages, page_size, D)`` plus a per-sequence page
table, so sequences grow page-at-a-time, share prefix pages, and never
reserve capacity they don't use. The kernel consumes that layout *natively*:
the page table rides in SMEM via scalar prefetch and the K/V BlockSpec index
maps read it directly —

    index_map = lambda b, h, p, pt, lens: (h, pt[b, p], 0, 0)

so the Pallas pipeline DMAs exactly the pages the sequence owns, in logical
order, with no gather/copy materializing a dense view first.

The NUMA structure of the dense kernel is preserved:
  * grid (B, Hkv, max_pages) is head-first — one ACC still owns each
    (batch, kv-head) cell, and the leading two dims stay PARALLEL so a
    megacore splits at ACC boundaries;
  * the physical page array is **head-major**: all pages of one KV head are
    contiguous, i.e. they live in that head's domain stripe
    (``cache.layout.HEAD_ALIGNED``). The cell and its pages share a domain
    by construction — the serving-scale form of the paper's WG->XCD
    co-location;
  * the GQA group is the q block, so each page is fetched once per
    (batch, kv-head), never per q-head.

Split-K (PR 4): ``num_splits > 1`` adds a PARALLEL grid axis over
contiguous **page ranges** (``cache.layout.decode_split_ranges``). Each
(b, hkv, split) cell walks only its range and emits partial ``(acc, m,
l)``; ``decode_common.combine_split_states`` merges them. Split
boundaries are page-granular by construction and, because the pool is
head-major (every page of a KV head lives in that head's domain stripe),
**no split ever straddles NUMA domains** — each partial pass stays inside
one domain's cache (``layout.split_ranges_domain_aligned`` proves this in
tests). ``num_splits`` comes from the plan layer's occupancy model; the
long-context, small-batch serving regime is where it exceeds 1.

Out-of-range page-table entries must hold a valid physical id (the engine
pads with the reserved null page 0): the index map still issues the copy,
and the in-kernel relevance test (``decode_common.chunk_relevant``) skips
the compute.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.cache import layout as layout_lib
from repro.kernels import decode_common

NEG_INF = decode_common.NEG_INF


def paged_kv_index_map(b_, h_, p_, pt, ln, *scales):
    """K/V BlockSpec index map of the one-pass paged kernel: grid cell
    (batch, kv-head, logical page) DMAs physical page ``pt[b, p]`` of head
    ``h``. Module-level (not a closure) so the domain-purity access tracer
    (``repro.analysis.access_trace``) replays the *same* function the
    kernel hands to ``pallas_call``. The trailing ``*scales`` absorbs the
    quantized pools' prefetched scale tables (unused for addressing — the
    physical page id keys both the pool and its scales)."""
    return (h_, pt[b_, p_], 0, 0)


def _q_index_map(b_, h_, p_, pt, ln, *scales):
    return (b_, h_, 0, 0)


def split_kv_index_map(pps, max_pages):
    """K/V index map of the split-K paged kernel for ``pps`` pages per
    split over a ``max_pages``-wide table. The tail split's overhang is
    clamped to the last table slot — the DMA must name a valid page; the
    kernel's range test skips its compute."""

    def kv_index(b_, h_, s_, j_, pt, ln, *scales):
        return (h_, pt[b_, jnp.minimum(s_ * pps + j_, max_pages - 1)], 0, 0)

    return kv_index


def _split_q_index_map(b_, h_, s_, j_, pt, ln, *scales):
    return (b_, h_, 0, 0)


def _split_out_index_map(b_, h_, s_, j_, pt, ln, *scales):
    return (b_, h_, s_, 0, 0)


def _paged_decode_kernel(
    pt_ref, len_ref,            # scalar-prefetch: (B, max_pages), (B,)
    *refs,                      # [ks_ref, vs_ref,] q, k, v, o, acc, m, l
    scale, softcap, window, page_size, max_pages, quantized,
):
    if quantized:
        ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs
        ks_ref = vs_ref = None
    b_idx = pl.program_id(0)
    h_idx = pl.program_id(1)
    p_idx = pl.program_id(2)
    length = len_ref[b_idx]

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page_start = p_idx * page_size

    @pl.when(
        decode_common.chunk_relevant(page_start, page_size, length, window)
    )
    def _compute():
        if quantized:
            pid = pt_ref[b_idx, p_idx]
            k_scale = ks_ref[h_idx, pid]
            v_scale = vs_ref[h_idx, pid]
        else:
            k_scale = v_scale = None
        decode_common.accumulate_kv_block(
            q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
            scale=scale, softcap=softcap, window=window,
            block_start=page_start, block_len=page_size, length=length,
            k_scale=k_scale, v_scale=v_scale,
        )

    @pl.when(p_idx == max_pages - 1)
    def _emit():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def _paged_decode_split_kernel(
    pt_ref, len_ref,            # scalar-prefetch: (B, max_pages), (B,)
    *refs,                      # [ks, vs,] q, k, v, acc/m/l out, acc/m/l
    scale, softcap, window, page_size, max_pages, pages_per_split,
    quantized,
):
    """Stage one of paged split-K decode: one (b, hkv, split) cell walks
    its page range (domain-pure under the head-major pool) and emits raw
    ``(acc, m, l)``. Overhanging tail-split steps (non-divisible ranges:
    their DMA is clamped to the last table slot) are skipped by the range
    test and contribute the empty state."""
    if quantized:
        (ks_ref, vs_ref, q_ref, k_ref, v_ref,
         acc_out, m_out, l_out, acc_ref, m_ref, l_ref) = refs
    else:
        (q_ref, k_ref, v_ref,
         acc_out, m_out, l_out, acc_ref, m_ref, l_ref) = refs
        ks_ref = vs_ref = None
    b_idx = pl.program_id(0)
    h_idx = pl.program_id(1)
    s_idx = pl.program_id(2)
    j_idx = pl.program_id(3)
    length = len_ref[b_idx]

    @pl.when(j_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    p_global = s_idx * pages_per_split + j_idx
    page_start = p_global * page_size
    relevant = (p_global < max_pages) & decode_common.chunk_relevant(
        page_start, page_size, length, window
    )

    @pl.when(relevant)
    def _compute():
        if quantized:
            pid = pt_ref[b_idx, jnp.minimum(p_global, max_pages - 1)]
            k_scale = ks_ref[h_idx, pid]
            v_scale = vs_ref[h_idx, pid]
        else:
            k_scale = v_scale = None
        decode_common.accumulate_kv_block(
            q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
            scale=scale, softcap=softcap, window=window,
            block_start=page_start, block_len=page_size, length=length,
            k_scale=k_scale, v_scale=v_scale,
        )

    @pl.when(j_idx == pages_per_split - 1)
    def _emit():
        acc_out[0, 0, 0] = acc_ref[...]
        m_out[0, 0, 0] = m_ref[...]
        l_out[0, 0, 0] = l_ref[...]


def paged_flash_decode(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    num_splits: int = 1,
    interpret: bool = False,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """q: (B, Hq, D); k/v_pages: (Hkv, P, page_size, D) head-major;
    page_table: (B, max_pages) int32 physical page ids (entries past a
    sequence's live pages must point at a valid page — the null page);
    lengths: (B,) int32. Returns (B, Hq, D).

    ``num_splits > 1`` runs the sequence-parallel (split-K) path over
    domain-aligned page ranges (clamped to the table width; 1 keeps the
    one-pass kernel).

    ``k_scales`` / ``v_scales`` (``(Hkv, P)`` fp32, both or neither) mark
    the pools as quantized codes (``cache.quant``): the scales prefetch
    into SMEM next to the page table — metadata keyed by the *physical*
    page id the table resolves — and the kernel bodies dequantize each
    page in VMEM right before the matmuls.
    """
    b, hq, d = q.shape
    hkv, num_pages, page_size, _ = k_pages.shape
    max_pages = page_table.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    if page_size % 8:
        raise ValueError(f"page_size {page_size} must be a sublane multiple (8)")
    if (k_scales is None) != (v_scales is None):
        raise ValueError("k_scales and v_scales must be passed together")
    quantized = k_scales is not None

    gp = max(8, -(-group // 8) * 8)  # pad GQA group to the sublane quantum
    qg = q.reshape(b, hkv, group, d)
    if gp != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - group), (0, 0)))

    ranges = layout_lib.decode_split_ranges(max_pages, num_splits)
    num_splits = len(ranges)
    if num_splits > 1:
        return _paged_flash_decode_split(
            qg, k_pages, v_pages, page_table, lengths, ranges,
            scale=scale, softcap=softcap, window=window,
            max_pages=max_pages, gp=gp, group=group, interpret=interpret,
            out_dtype=q.dtype, k_scales=k_scales, v_scales=v_scales,
        )

    prefetch = [page_table.astype(jnp.int32), lengths.astype(jnp.int32)]
    if quantized:
        prefetch += [k_scales.astype(jnp.float32),
                     v_scales.astype(jnp.float32)]
    fn = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel,
            scale=scale, softcap=softcap, window=window,
            page_size=page_size, max_pages=max_pages, quantized=quantized,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, hkv, max_pages),
            in_specs=[
                pl.BlockSpec((1, 1, gp, d), _q_index_map),
                pl.BlockSpec((1, 1, page_size, d), paged_kv_index_map),
                pl.BlockSpec((1, 1, page_size, d), paged_kv_index_map),
            ],
            out_specs=pl.BlockSpec((1, 1, gp, d), _q_index_map),
            scratch_shapes=[
                pltpu.VMEM((gp, d), jnp.float32),
                pltpu.VMEM((gp, 128), jnp.float32),
                pltpu.VMEM((gp, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hq * max_pages * page_size * d),
            bytes_accessed=int(
                b * (2 * k_pages.dtype.itemsize * hkv * max_pages
                     * page_size * d + 2 * q.dtype.itemsize * hq * d)
            ),
            transcendentals=int(b * hq * max_pages * page_size),
        ),
        interpret=interpret,
        name="paged_flash_decode",
    )
    out = fn(*prefetch, qg, k_pages, v_pages)
    return out[:, :, :group, :].reshape(b, hq, d)


def _paged_flash_decode_split(
    qg, k_pages, v_pages, page_table, lengths, ranges,
    *, scale, softcap, window, max_pages, gp, group, interpret, out_dtype,
    k_scales=None, v_scales=None,
):
    b = qg.shape[0]
    hkv, _, page_size, d = k_pages.shape
    num_splits = len(ranges)
    pps = ranges[0][1] - ranges[0][0]  # pages per split (tail may be short)
    quantized = k_scales is not None

    kv_index = split_kv_index_map(pps, max_pages)
    prefetch = [page_table.astype(jnp.int32), lengths.astype(jnp.int32)]
    if quantized:
        prefetch += [k_scales.astype(jnp.float32),
                     v_scales.astype(jnp.float32)]

    fn = pl.pallas_call(
        functools.partial(
            _paged_decode_split_kernel,
            scale=scale, softcap=softcap, window=window,
            page_size=page_size, max_pages=max_pages, pages_per_split=pps,
            quantized=quantized,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=len(prefetch),
            grid=(b, hkv, num_splits, pps),
            in_specs=[
                pl.BlockSpec((1, 1, gp, d), _split_q_index_map),
                pl.BlockSpec((1, 1, page_size, d), kv_index),
                pl.BlockSpec((1, 1, page_size, d), kv_index),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1, gp, d), _split_out_index_map),
                pl.BlockSpec((1, 1, 1, gp, 128), _split_out_index_map),
                pl.BlockSpec((1, 1, 1, gp, 128), _split_out_index_map),
            ],
            scratch_shapes=[
                pltpu.VMEM((gp, d), jnp.float32),
                pltpu.VMEM((gp, 128), jnp.float32),
                pltpu.VMEM((gp, 128), jnp.float32),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, num_splits, gp, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, num_splits, gp, 128), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, num_splits, gp, 128), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hkv * group * max_pages * page_size * d),
            bytes_accessed=int(
                k_pages.dtype.itemsize
                * b * (2 * hkv * max_pages * page_size * d + 2 * hkv * group * d)
            ),
            transcendentals=int(b * hkv * group * max_pages * page_size),
        ),
        interpret=interpret,
        name="paged_flash_decode_split",
    )
    acc, m, l = fn(*prefetch, qg, k_pages, v_pages)
    out = decode_common.combine_split_states(acc, m[..., :1], l[..., :1])
    return out[:, :, :group, :].reshape(b, hkv * group, d).astype(out_dtype)
