"""Paged flash-decode Pallas kernel: decode over a page-table-indirected KV.

The serving-native sibling of ``decode_attention.py``. There the KV cache is
a dense per-slot stripe ``(B, Hkv, Smax, D)``; here it is a pool of
fixed-size pages ``(Hkv, num_pages, page_size, D)`` plus a per-sequence page
table, so sequences grow page-at-a-time, share prefix pages, and never
reserve capacity they don't use. The kernel consumes that layout *natively*:
the page table rides in SMEM via scalar prefetch and the K/V BlockSpec index
maps read it directly —

    index_map = lambda b, h, p, pt, lens: (h, pt[b, p], 0, 0)

so the Pallas pipeline DMAs exactly the pages the sequence owns, in logical
order, with no gather/copy materializing a dense view first.

The NUMA structure of the dense kernel is preserved:
  * grid (B, Hkv, max_pages) is head-first — one ACC still owns each
    (batch, kv-head) cell, and the leading two dims stay PARALLEL so a
    megacore splits at ACC boundaries;
  * the physical page array is **head-major**: all pages of one KV head are
    contiguous, i.e. they live in that head's domain stripe
    (``cache.layout.HEAD_ALIGNED``). The cell and its pages share a domain
    by construction — the serving-scale form of the paper's WG->XCD
    co-location;
  * the GQA group is the q block, so each page is fetched once per
    (batch, kv-head), never per q-head.

Out-of-range page-table entries must hold a valid physical id (the engine
pads with the reserved null page 0): the index map still issues the copy,
and the in-kernel relevance test skips the compute.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30


def _paged_decode_kernel(
    pt_ref, len_ref,            # scalar-prefetch: (B, max_pages), (B,)
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *, scale, softcap, window, page_size, max_pages,
):
    b_idx = pl.program_id(0)
    p_idx = pl.program_id(2)
    length = len_ref[b_idx]

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    page_start = p_idx * page_size
    relevant = page_start < length
    if window is not None and window > 0:
        relevant &= page_start + page_size - 1 >= length - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)      # (Gp, D)
        k = k_ref[0, 0].astype(jnp.float32)      # (page_size, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, (1, page_size), 1)
        valid = pos < length
        if window is not None and window > 0:
            valid &= pos > length - 1 - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[:, 0:1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_ref[...] = jnp.broadcast_to(
            l_ref[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True), l_ref.shape
        )
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(p_idx == max_pages - 1)
    def _emit():
        l = l_ref[:, 0:1]
        o_ref[0, 0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(o_ref.dtype)


def paged_flash_decode(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B, Hq, D); k/v_pages: (Hkv, P, page_size, D) head-major;
    page_table: (B, max_pages) int32 physical page ids (entries past a
    sequence's live pages must point at a valid page — the null page);
    lengths: (B,) int32. Returns (B, Hq, D).
    """
    b, hq, d = q.shape
    hkv, num_pages, page_size, _ = k_pages.shape
    max_pages = page_table.shape[1]
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    if page_size % 8:
        raise ValueError(f"page_size {page_size} must be a sublane multiple (8)")

    gp = max(8, -(-group // 8) * 8)  # pad GQA group to the sublane quantum
    qg = q.reshape(b, hkv, group, d)
    if gp != group:
        qg = jnp.pad(qg, ((0, 0), (0, 0), (0, gp - group), (0, 0)))

    fn = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel,
            scale=scale, softcap=softcap, window=window,
            page_size=page_size, max_pages=max_pages,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(b, hkv, max_pages),
            in_specs=[
                pl.BlockSpec((1, 1, gp, d), lambda b_, h_, p_, pt, ln: (b_, h_, 0, 0)),
                pl.BlockSpec(
                    (1, 1, page_size, d),
                    lambda b_, h_, p_, pt, ln: (h_, pt[b_, p_], 0, 0),
                ),
                pl.BlockSpec(
                    (1, 1, page_size, d),
                    lambda b_, h_, p_, pt, ln: (h_, pt[b_, p_], 0, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, gp, d), lambda b_, h_, p_, pt, ln: (b_, h_, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((gp, d), jnp.float32),
                pltpu.VMEM((gp, 128), jnp.float32),
                pltpu.VMEM((gp, 128), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, gp, d), q.dtype),
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=(
                compat.PARALLEL,
                compat.PARALLEL,
                compat.ARBITRARY,
            ),
        ),
        cost_estimate=pl.CostEstimate(
            flops=int(4.0 * b * hq * max_pages * page_size * d),
            bytes_accessed=int(
                q.dtype.itemsize
                * b * (2 * hkv * max_pages * page_size * d + 2 * hq * d)
            ),
            transcendentals=int(b * hq * max_pages * page_size),
        ),
        interpret=interpret,
        name="paged_flash_decode",
    )
    out = fn(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
             qg, k_pages, v_pages)
    return out[:, :, :group, :].reshape(b, hq, d)
