"""Public jit'd attention ops: impl dispatch, padding, custom VJP.

Three implementations behind one API:

  * ``pallas``    — the NUMA-aware Pallas kernels (flash_attention.py /
                    flash_attention_bwd.py / decode_attention.py). Real
                    Mosaic lowering on TPU; ``interpret=True`` elsewhere.
  * ``xla_flash`` — chunked online-softmax in pure jnp (lax.scan over KV
                    chunks). Differentiable, remat-friendly, O(S·chunk)
                    memory. Used for the multi-pod dry-run (the CPU backend
                    cannot lower Mosaic) and for CPU-hosted training smokes.
  * ``xla_flash_tri`` — beyond-paper §Perf variant: causally-triangular
                    unrolled chunking that skips above-diagonal work, halving
                    attention HLO FLOPs on training shapes (see
                    EXPERIMENTS.md §Perf).
  * ``ref``       — exact attention (tests only).

``impl='auto'`` picks pallas on TPU and xla_flash elsewhere (backend
detection via ``repro.compat``).

``resolve_mapping(shape, backend)`` is the scheduling entry point: given an
attention shape it scores every (grid order x KV residency x block size)
candidate with the analytic NUMA model (``core.perf_model``, cross-validated
against ``core.cache_sim``) plus the static HBM-traffic model
(``hbm_block_fetches``) and returns the best ``MappingConfig``. Results are
LRU-cached per shape/backend, so jit traces pay the cost once. Passing
``mapping=None`` (the default) to ``flash_attention`` routes through it —
there is deliberately no module-level default mapping anymore.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ref as ref_mod
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import (
    BLOCK_FIRST,
    HEAD_FIRST,
    MappingConfig,
    flash_attention_fwd,
    hbm_block_fetches,
)
from repro.kernels.flash_attention_bwd import flash_attention_bwd


def _on_tpu() -> bool:
    return compat.on_tpu()


# -----------------------------------------------------------------------------
# Mapping resolution: shape -> best NUMA-aware schedule
# -----------------------------------------------------------------------------

#: Candidate (block_m, block_n) tilings, preference-ordered. The MXU-native
#: 128x128 default first; larger variants only win when the model says so
#: (e.g. less padding waste). Sub-128 blocks are excluded — the analytic
#: model would pick them for their smaller causal-diagonal waste, but they
#: under-fill the 128x128 MXU; short sequences still clamp via min(bm, sq).
_CANDIDATE_BLOCKS = ((128, 128), (256, 128), (128, 256))

#: Grid order -> paper mapping name for the analytic model. Every emitted
#: candidate has acc_parallel=True, so both orders score as their swizzled
#: variant (the naive_* names carry perf_model's ACC-replication penalty for
#: schedules we never emit); residency is decided by the candidate filter
#: plus the exact HBM-traffic tie-break, not by the analytic proxy.
_PAPER_NAME = {
    HEAD_FIRST: "swizzled_head_first",
    BLOCK_FIRST: "swizzled_block_first",
}


def _topology_for(backend: str):
    from repro.core import numa

    if backend == "gpu":
        return numa.MI300X
    # TPU and CPU alike schedule for the megacore TPU target: CPU hosts run
    # the kernels in interpret mode, and using the same topology guarantees
    # dry-runs pick the same mapping the real hardware would.
    return numa.TPU_V5P_MEGACORE


@functools.lru_cache(maxsize=1024)
def _resolve_mapping_cached(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    dtype_bytes: int,
    backend: str,
    vmem_budget_bytes: int,
) -> MappingConfig:
    from repro.core import perf_model
    from repro.core.cache_sim import AttentionWorkload
    from repro.core.swizzle import AttentionGrid

    topo = _topology_for(backend)
    group = max(1, num_q_heads // max(num_kv_heads, 1))

    def _clamp(block, seq):
        # Never emit a block shorter than the sequence rounded up to the
        # sublane quantum (16 covers bf16's 16 and f32's 8): ops pads the
        # sequence to the block size, and a non-multiple-of-sublane block
        # only works in interpret mode — Mosaic rejects the layout.
        return min(block, max(16, -(-seq // 16) * 16))

    best = None  # (time, traffic, candidate_rank, config)
    rank = 0
    for bm, bn in _CANDIDATE_BLOCKS:
        bm_eff = _clamp(bm, seq_q)
        bn_eff = _clamp(bn, seq_kv)
        for order in (HEAD_FIRST, BLOCK_FIRST):
            for kv_resident in (True, False):
                cand = MappingConfig(
                    order=order,
                    kv_resident=kv_resident,
                    acc_parallel=True,
                    block_m=bm_eff,
                    block_n=bn_eff,
                    vmem_budget_bytes=vmem_budget_bytes,
                )
                if kv_resident and not cand.resolve_resident(
                    seq_kv, head_dim, dtype_bytes
                ):
                    # Over-budget residency degenerates to streaming; keep
                    # only the honest streaming candidate.
                    continue
                # perf_model.estimate models a square (seq_kv x seq_kv)
                # launch: it recomputes blocks_per_head from wl.seq_len, so
                # feed it the same convention. For rectangular shapes
                # (bucketed prefill vs long cache) the analytic time is a
                # square proxy; the exact rectangular traffic enters via the
                # tie-break below.
                grid = AttentionGrid(
                    batch=batch,
                    num_q_heads=num_q_heads,
                    blocks_per_head=-(-seq_kv // bm_eff),
                    group_size=group,
                )
                wl = AttentionWorkload(
                    grid=grid,
                    seq_len=seq_kv,
                    head_dim=head_dim,
                    block_m=bm_eff,
                    block_n=bn_eff,
                    causal=True,
                    dtype_bytes=dtype_bytes,
                )
                est = perf_model.estimate(_PAPER_NAME[order], wl, topo)
                traffic = hbm_block_fetches(
                    batch=batch,
                    num_q_heads=num_q_heads,
                    num_kv_heads=num_kv_heads,
                    seq_q=seq_q,
                    seq_kv=seq_kv,
                    head_dim=head_dim,
                    dtype_bytes=dtype_bytes,
                    mapping=cand,
                )["total_bytes"]
                key = (est.time, traffic, rank)
                rank += 1
                if best is None or key < best[0]:
                    best = (key, cand)
    return best[1]


def resolve_mapping(
    shape: Tuple[int, int, int, int, int, int],
    backend: Optional[str] = None,
    *,
    dtype_bytes: int = 2,
    vmem_budget_bytes: int = MappingConfig.vmem_budget_bytes,
) -> MappingConfig:
    """Pick the best ``MappingConfig`` for an attention shape.

    ``shape`` is ``(batch, num_q_heads, num_kv_heads, seq_q, seq_kv,
    head_dim)``; ``backend`` defaults to the host's jit target. The resolver
    prefers the paper's swizzled head-first residency exactly when the K/V of
    one head fits the VMEM budget (``MappingConfig.resolve_resident``), and
    falls back to a streamed head-first sweep otherwise; block sizes are
    chosen by the HBM-traffic model. Results are LRU-cached.
    """
    b, hq, hkv, sq, skv, d = (int(x) for x in shape)
    return _resolve_mapping_cached(
        b, hq, hkv, sq, skv, d,
        int(dtype_bytes),
        backend or compat.default_backend(),
        int(vmem_budget_bytes),
    )


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# -----------------------------------------------------------------------------
# Pallas path with custom VJP
# -----------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _pallas_attention(q, k, v, causal, window, softcap, scale, mapping, interpret):
    o, _ = flash_attention_fwd(
        q, k, v, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return o


def _pallas_attention_fwd(q, k, v, causal, window, softcap, scale, mapping, interpret):
    o, lse = flash_attention_fwd(
        q, k, v, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _pallas_attention_bwd(causal, window, softcap, scale, mapping, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return dq, dk, dv


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


# -----------------------------------------------------------------------------
# XLA flash (scan over KV chunks) — dry-run / CPU path
# -----------------------------------------------------------------------------


def _xla_flash(q, k, v, *, causal, window, softcap, scale, kv_len, chunk=1024,
               unroll=False):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, skv)
    nc = -(-skv // chunk)
    kp = _pad_to(k, 2, chunk).reshape(b, hkv, nc, chunk, d)
    vp = _pad_to(v, 2, chunk).reshape(b, hkv, nc, chunk, d)
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    if scale is None:
        scale = 1.0 / d**0.5
    rows = jnp.arange(sq)[:, None]

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, off = xs
        s = jnp.einsum(
            "bhgqd,bhcd->bhgqc", qg, kc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        cols = off + jnp.arange(chunk)[None, :]
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window is not None and window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask[None, None, None], s, ref_mod.NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, sq, 1), ref_mod.NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, sq, 1), jnp.float32),
        jnp.zeros((b, hkv, g, sq, d), jnp.float32),
    )
    xs = (
        jnp.moveaxis(kp, 2, 0),
        jnp.moveaxis(vp, 2, 0),
        jnp.arange(nc) * chunk,
    )
    (m_fin, l_fin, acc), _ = jax.lax.scan(step, init, xs,
                                          unroll=nc if unroll else 1)
    l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
    o = (acc / l_safe).reshape(b, hq, sq, d)
    return o.astype(q.dtype)


def _xla_flash_tri(q, k, v, *, causal, window, softcap, scale, kv_len, chunk=1024):
    """Causal-triangular variant: q chunk i only attends kv[: (i+1)*chunk].

    Unrolled over q chunks with per-iteration static shapes, so the
    above-diagonal half of the score matrix is never built — the compiled
    HLO carries ~half the attention FLOPs of the scan variant on causal
    training shapes. Falls back to the scan variant when not causal or when
    q/kv lengths differ (prefix-cache prefill).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if not causal or sq != skv or sq % chunk:
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=kv_len, chunk=chunk,
        )
    nq = sq // chunk
    outs = []
    for i in range(nq):
        qi = q[:, :, i * chunk : (i + 1) * chunk]
        hi = (i + 1) * chunk
        lo = 0
        if window is not None and window > 0:
            lo = max(0, (i * chunk - window + 1) // chunk * chunk)
        ki = k[:, :, lo:hi]
        vi = v[:, :, lo:hi]
        # positions are absolute: shift rows by q_offset via kv_len masking
        oi = _xla_flash_offset(
            qi, ki, vi, abs_q=i * chunk, abs_k=lo, causal=True, window=window,
            softcap=softcap, scale=scale, kv_len=min(kv_len, hi), chunk=chunk,
        )
        outs.append(oi)
    return jnp.concatenate(outs, axis=2)


def _xla_flash_offset(
    q, k, v, *, abs_q, abs_k, causal, window, softcap, scale, kv_len, chunk
):
    """One (q-chunk x kv-prefix) tile with absolute position masking."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum(
        "bhgqd,bhcd->bhgqc", qg, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    rows = abs_q + jnp.arange(sq)[:, None]
    cols = abs_k + jnp.arange(skv)[None, :]
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None and window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None, None], s, ref_mod.NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqc,bhcd->bhgqd", p / jnp.where(l == 0, 1, l),
                   v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


# -----------------------------------------------------------------------------
# Public API
# -----------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    mapping: Optional[MappingConfig] = None,
    impl: str = "auto",
    chunk_unroll: bool = False,
) -> jnp.ndarray:
    """Multi-head / grouped-query attention. q: (B,Hq,Sq,D); k,v: (B,Hkv,Skv,D).

    ``mapping=None`` auto-selects the NUMA-aware schedule for this shape via
    :func:`resolve_mapping`.
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla_flash"
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    if impl == "ref":
        return ref_mod.attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale
        )
    if impl == "xla_flash":
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=skv, unroll=chunk_unroll,
        )
    if impl == "xla_flash_tri":
        return _xla_flash_tri(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=skv,
        )
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    if mapping is None:
        mapping = resolve_mapping(
            (b, hq, k.shape[1], sq, skv, d),
            dtype_bytes=q.dtype.itemsize,
        )
    bm, bn = mapping.block_m, mapping.block_n
    qp = _pad_to(q, 2, bm)
    kp = _pad_to(k, 2, bn)
    vp = _pad_to(v, 2, bn)
    interpret = compat.use_interpret()
    o = _pallas_attention(
        qp, kp, vp, causal, window, softcap, scale, mapping, interpret
    )
    return o[:, :, :sq]


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Single-token decode. q: (B,Hq,D); caches: (B,Hkv,Smax,D); lengths: (B,)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla" or impl == "ref":
        return ref_mod.decode_attention(
            q, k_cache, v_cache, lengths, softcap=softcap, scale=scale, window=window
        )
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    smax = k_cache.shape[2]
    chunk = 512 if smax % 512 == 0 else smax
    return flash_decode(
        q, k_cache, v_cache, lengths,
        softcap=softcap, scale=scale, window=window, chunk=chunk,
        interpret=compat.use_interpret(),
    )
