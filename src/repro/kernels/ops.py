"""Public jit'd attention ops: plan-driven dispatch, padding, custom VJP.

Three implementations behind one API:

  * ``pallas``    — the NUMA-aware Pallas kernels (flash_attention.py /
                    flash_attention_bwd.py / decode_attention.py /
                    paged_decode_attention.py / paged_prefill_attention.py).
                    Real Mosaic lowering on TPU; ``interpret=True`` elsewhere.
  * ``xla_flash`` — chunked online-softmax in pure jnp (lax.scan over KV
                    chunks). Differentiable, remat-friendly, O(S·chunk)
                    memory. Used for the multi-pod dry-run (the CPU backend
                    cannot lower Mosaic) and for CPU-hosted training smokes.
  * ``xla_flash_tri`` — beyond-paper §Perf variant: causally-triangular
                    unrolled chunking that skips above-diagonal work, halving
                    attention HLO FLOPs on training shapes (see
                    EXPERIMENTS.md §Perf).
  * ``ref``       — exact attention (tests only).

Scheduling lives in **``kernels.plan``** (PR 3): every public op accepts an
:class:`~repro.kernels.plan.AttentionPlan` and, when none is passed, builds
one via ``plan.plan_attention`` for its phase (prefill / extend / decode)
and KV layout (dense / paged). The legacy entry points below —
``resolve_mapping`` and ``resolve_kv_layout`` — are thin wrappers over that
resolver, kept for benchmarks and tests that only want the mapping or the
layout ranking.

The paged serving pair: ``paged_decode_attention`` dispatches the
page-table flash-decode kernel, and ``paged_prefill_attention`` dispatches
the prefix-aware paged prefill kernel — prefix K/V read straight from the
page table, no gather and no XLA ``q_offset`` fallback (which survives on
``flash_attention`` as the oracle route).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import plan as plan_lib
from repro.kernels import ref as ref_mod
from repro.kernels.decode_attention import flash_decode
from repro.kernels.paged_decode_attention import paged_flash_decode
from repro.kernels.paged_prefill_attention import paged_flash_prefill
from repro.kernels.flash_attention import (
    MappingConfig,
    flash_attention_fwd,
    hbm_block_fetches,  # noqa: F401  (re-export: benchmarks/tests import it here)
)
from repro.kernels.flash_attention_bwd import flash_attention_bwd
from repro.kernels.plan import AttentionPlan, plan_attention  # noqa: F401


# -----------------------------------------------------------------------------
# Legacy resolvers: thin wrappers over the plan layer
# -----------------------------------------------------------------------------


def resolve_mapping(
    shape: Tuple[int, int, int, int, int, int],
    backend: Optional[str] = None,
    *,
    dtype_bytes: int = 2,
    vmem_budget_bytes: int = MappingConfig.vmem_budget_bytes,
    decode: bool = False,
    window: Optional[int] = None,
) -> MappingConfig:
    """Pick the best ``MappingConfig`` for an attention shape.

    Thin wrapper over :func:`repro.kernels.plan.plan_attention` (which owns
    the scoring and the cache); returns only the plan's mapping. ``shape``
    is ``(batch, num_q_heads, num_kv_heads, seq_q, seq_kv, head_dim)``;
    ``decode`` / ``window`` select the phase and are part of the plan key,
    so a decode-over-long-cache shape resolves to a different schedule than
    a prefill of the same nominal (seq_q, seq_kv).
    """
    return plan_attention(
        shape,
        phase=plan_lib.DECODE if decode else plan_lib.PREFILL,
        backend=backend,
        dtype_bytes=dtype_bytes,
        window=window,
        vmem_budget_bytes=vmem_budget_bytes,
    ).mapping


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# -----------------------------------------------------------------------------
# Pallas path with custom VJP
# -----------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _pallas_attention(q, k, v, causal, window, softcap, scale, mapping, interpret):
    o, _ = flash_attention_fwd(
        q, k, v, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return o


def _pallas_attention_fwd(q, k, v, causal, window, softcap, scale, mapping, interpret):
    o, lse = flash_attention_fwd(
        q, k, v, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _pallas_attention_bwd(causal, window, softcap, scale, mapping, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return dq, dk, dv


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


# -----------------------------------------------------------------------------
# XLA flash (scan over KV chunks) — dry-run / CPU path
# -----------------------------------------------------------------------------


def _xla_flash(q, k, v, *, causal, window, softcap, scale, kv_len, chunk=1024,
               unroll=False, q_offset=0):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, skv)
    nc = -(-skv // chunk)
    kp = _pad_to(k, 2, chunk).reshape(b, hkv, nc, chunk, d)
    vp = _pad_to(v, 2, chunk).reshape(b, hkv, nc, chunk, d)
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    if scale is None:
        scale = 1.0 / d**0.5
    # Rows sit at absolute positions q_offset + i (prefix-extension prefill:
    # the query block starts after an already-cached prefix).
    rows = q_offset + jnp.arange(sq)[:, None]

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, off = xs
        s = jnp.einsum(
            "bhgqd,bhcd->bhgqc", qg, kc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        cols = off + jnp.arange(chunk)[None, :]
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window is not None and window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask[None, None, None], s, ref_mod.NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, sq, 1), ref_mod.NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, sq, 1), jnp.float32),
        jnp.zeros((b, hkv, g, sq, d), jnp.float32),
    )
    xs = (
        jnp.moveaxis(kp, 2, 0),
        jnp.moveaxis(vp, 2, 0),
        jnp.arange(nc) * chunk,
    )
    (m_fin, l_fin, acc), _ = jax.lax.scan(step, init, xs,
                                          unroll=nc if unroll else 1)
    l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
    o = (acc / l_safe).reshape(b, hq, sq, d)
    return o.astype(q.dtype)


def _xla_flash_tri(q, k, v, *, causal, window, softcap, scale, kv_len, chunk=1024,
                   q_offset=0):
    """Causal-triangular variant: q chunk i only attends kv[: (i+1)*chunk].

    Unrolled over q chunks with per-iteration static shapes, so the
    above-diagonal half of the score matrix is never built — the compiled
    HLO carries ~half the attention FLOPs of the scan variant on causal
    training shapes. Falls back to the scan variant when not causal, when
    q/kv lengths differ, or when the query block is offset (prefix-cache
    extension prefill).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if not causal or sq != skv or sq % chunk or q_offset:
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=kv_len, chunk=chunk, q_offset=q_offset,
        )
    nq = sq // chunk
    outs = []
    for i in range(nq):
        qi = q[:, :, i * chunk : (i + 1) * chunk]
        hi = (i + 1) * chunk
        lo = 0
        if window is not None and window > 0:
            lo = max(0, (i * chunk - window + 1) // chunk * chunk)
        ki = k[:, :, lo:hi]
        vi = v[:, :, lo:hi]
        # positions are absolute: shift rows by q_offset via kv_len masking
        oi = _xla_flash_offset(
            qi, ki, vi, abs_q=i * chunk, abs_k=lo, causal=True, window=window,
            softcap=softcap, scale=scale, kv_len=min(kv_len, hi), chunk=chunk,
        )
        outs.append(oi)
    return jnp.concatenate(outs, axis=2)


def _xla_flash_offset(
    q, k, v, *, abs_q, abs_k, causal, window, softcap, scale, kv_len, chunk
):
    """One (q-chunk x kv-prefix) tile with absolute position masking."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum(
        "bhgqd,bhcd->bhgqc", qg, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    rows = abs_q + jnp.arange(sq)[:, None]
    cols = abs_k + jnp.arange(skv)[None, :]
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None and window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None, None], s, ref_mod.NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqc,bhcd->bhgqd", p / jnp.where(l == 0, 1, l),
                   v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


# -----------------------------------------------------------------------------
# Public API
# -----------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    mapping: Optional[MappingConfig] = None,
    impl: str = "auto",
    chunk_unroll: bool = False,
    q_offset: int = 0,
    plan: Optional[AttentionPlan] = None,
) -> jnp.ndarray:
    """Multi-head / grouped-query attention. q: (B,Hq,Sq,D); k,v: (B,Hkv,Skv,D).

    ``plan=None`` resolves an :class:`AttentionPlan` for this shape (phase
    ``prefill``, or dense ``extend`` when ``q_offset`` is nonzero); an
    explicit ``mapping`` overrides the plan's schedule (paper A/B pins).

    ``q_offset`` places the query block at absolute positions
    ``[q_offset, q_offset + Sq)`` against a longer KV — the dense
    prefix-extension route. The Pallas forward does not carry the offset,
    so this path runs XLA flash; it is the oracle the paged prefill kernel
    (:func:`paged_prefill_attention`) is tested against.
    """
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    if plan is None:
        phase = plan_lib.EXTEND if q_offset else plan_lib.PREFILL
        if mapping is not None:
            # The schedule is already decided (paper A/B pins, kernel
            # tests): resolve only the impl/backend environment.
            plan = plan_lib.plan_for_mapping(
                mapping, phase=phase, impl=impl, window=window,
            )
        else:
            plan = plan_attention(
                (b, hq, k.shape[1], sq, skv, d),
                phase=phase, window=window,
                dtype_bytes=q.dtype.itemsize, impl=impl,
            )
    impl = plan.impl
    if q_offset and impl == "pallas":
        # Safety net for hand-built prefill plans reused with an offset.
        impl = "xla_flash"
    if impl == "ref":
        return ref_mod.attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset,
        )
    if impl == "xla_flash":
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=skv, unroll=chunk_unroll, q_offset=q_offset,
        )
    if impl == "xla_flash_tri":
        return _xla_flash_tri(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=skv, q_offset=q_offset,
        )
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    if mapping is None:
        mapping = plan.mapping
    bm, bn = mapping.block_m, mapping.block_n
    qp = _pad_to(q, 2, bm)
    kp = _pad_to(k, 2, bn)
    vp = _pad_to(v, 2, bn)
    o = _pallas_attention(
        qp, kp, vp, causal, window, softcap, scale, mapping, plan.interpret
    )
    return o[:, :, :sq]


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    impl: str = "auto",
    plan: Optional[AttentionPlan] = None,
) -> jnp.ndarray:
    """Single-token decode. q: (B,Hq,D); caches: (B,Hkv,Smax,D); lengths: (B,)."""
    b, hq, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    if plan is None:
        plan = plan_attention(
            (b, hq, hkv, 1, smax, d),
            phase=plan_lib.DECODE, window=window,
            dtype_bytes=q.dtype.itemsize, impl=impl,
        )
    if plan.impl in ("xla", "ref"):
        return ref_mod.decode_attention(
            q, k_cache, v_cache, lengths, softcap=softcap, scale=scale, window=window
        )
    if plan.impl != "pallas":
        raise ValueError(f"unknown impl {plan.impl!r}")
    # The KV chunk comes from the plan (the resolver's block_n, preferring a
    # divisor of the capacity). Only truly odd capacities pay the
    # pad-to-chunk copy; the padded tail sits beyond every ``lengths``
    # entry, so masking never admits it. ``num_splits`` likewise rides the
    # plan (the occupancy model's split-K choice); the kernel clamps it to
    # the chunk count.
    chunk = min(plan.chunk or smax, smax)
    if smax % chunk:
        k_cache = _pad_to(k_cache, 2, chunk)
        v_cache = _pad_to(v_cache, 2, chunk)
    return flash_decode(
        q, k_cache, v_cache, lengths,
        softcap=softcap, scale=scale, window=window, chunk=chunk,
        num_splits=plan.num_splits,
        interpret=plan.interpret,
    )


def paged_decode_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
    impl: str = "auto",
    plan: Optional[AttentionPlan] = None,
) -> jnp.ndarray:
    """Paged single-token decode. q: (B,Hq,D); k/v_pages: (Hkv,P,ps,D)
    head-major; page_table: (B,max_pages) physical ids (null-page padded);
    lengths: (B,). The pallas path consumes the page table natively via
    scalar prefetch; xla/ref gathers a dense view first (oracle/dry-run).

    ``k_scales``/``v_scales`` — (Hkv, P) fp32 per-page dequant factors for
    quantized pools (``cache.quant``); they ride the same scalar-prefetch
    path as the page table, and ``None`` means the pools are fp32.
    """
    b, hq, d = q.shape
    hkv, _, ps, _ = k_pages.shape
    if plan is None:
        plan = plan_attention(
            (b, hq, hkv, 1, page_table.shape[1] * ps, d),
            phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED, page_size=ps,
            window=window, dtype_bytes=k_pages.dtype.itemsize, impl=impl,
        )
    if plan.impl in ("xla", "ref"):
        return ref_mod.paged_decode_attention(
            q, k_pages, v_pages, page_table, lengths,
            softcap=softcap, scale=scale, window=window,
            k_scales=k_scales, v_scales=v_scales,
        )
    if plan.impl != "pallas":
        raise ValueError(f"unknown impl {plan.impl!r}")
    return paged_flash_decode(
        q, k_pages, v_pages, page_table, lengths,
        softcap=softcap, scale=scale, window=window,
        k_scales=k_scales, v_scales=v_scales,
        num_splits=plan.num_splits,
        interpret=plan.interpret,
    )


def paged_prefill_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    k_tail: jnp.ndarray,
    v_tail: jnp.ndarray,
    prefix_len: jnp.ndarray,
    tail_len: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    k_scales: Optional[jnp.ndarray] = None,
    v_scales: Optional[jnp.ndarray] = None,
    impl: str = "auto",
    plan: Optional[AttentionPlan] = None,
) -> jnp.ndarray:
    """Prefix-extension prefill over paged prefix K/V (PR-3 headline).

    q: (B,Hq,St,D) tail queries at absolute positions ``prefix_len[b]+i``;
    k/v_pages: (Hkv,P,ps,D) head-major pool; page_table:
    (B,max_prefix_pages) physical ids in logical order (null-page padded
    past the live prefix); k/v_tail: (B,Hkv,St,D) the tail's fresh K/V;
    prefix_len/tail_len: (B,) live prefix/tail tokens (dynamic — the page
    table width is a bucketed jit constant, the live lengths are not).

    The pallas path reads the prefix straight from the page table (no
    gather, no dense copy); xla/ref is the gather-based oracle.
    ``k_scales``/``v_scales`` are the quantized pools' (Hkv, P) per-page
    dequant factors (``None`` for fp32 pools); the tail K/V is always
    fresh fp32 activations and never quantized.
    """
    b, hq, st, d = q.shape
    hkv, _, ps, _ = k_pages.shape
    if plan is None:
        plan = plan_attention(
            (b, hq, hkv, st, page_table.shape[1] * ps + st, d),
            phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED, page_size=ps,
            prefix_pages=page_table.shape[1], window=window,
            dtype_bytes=q.dtype.itemsize, impl=impl,
        )
    if plan.impl in ("xla", "ref"):
        return ref_mod.paged_prefill_attention(
            q, k_pages, v_pages, page_table, k_tail, v_tail,
            prefix_len, tail_len,
            softcap=softcap, scale=scale, window=window,
            k_scales=k_scales, v_scales=v_scales,
        )
    if plan.impl != "pallas":
        raise ValueError(f"unknown impl {plan.impl!r}")
    return paged_flash_prefill(
        q, k_pages, v_pages, page_table, k_tail, v_tail,
        prefix_len, tail_len,
        softcap=softcap, scale=scale, window=window,
        k_scales=k_scales, v_scales=v_scales,
        interpret=plan.interpret,
    )


# -----------------------------------------------------------------------------
# KV-layout resolution: thin wrapper over the plan layer
# -----------------------------------------------------------------------------


def resolve_kv_layout(
    shape: Tuple[int, int, int, int, int],
    *,
    capacity: int,
    page_size: int = 64,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
    shared_prefix_len: int = 0,
) -> str:
    """Rank KV layouts for a decode mix; returns ``"dense"``,
    ``"paged:head_aligned"`` or ``"paged:interleaved"``. Thin wrapper over
    :func:`repro.kernels.plan.resolve_kv_layout` (which owns the scoring and
    the cache) — kept as the legacy entry point for benchmarks/engines."""
    return plan_lib.resolve_kv_layout(
        shape,
        capacity=capacity,
        page_size=page_size,
        dtype_bytes=dtype_bytes,
        backend=backend,
        shared_prefix_len=shared_prefix_len,
    )
