"""Public jit'd attention ops: impl dispatch, padding, custom VJP.

Three implementations behind one API:

  * ``pallas``    — the NUMA-aware Pallas kernels (flash_attention.py /
                    flash_attention_bwd.py / decode_attention.py). Real
                    Mosaic lowering on TPU; ``interpret=True`` elsewhere.
  * ``xla_flash`` — chunked online-softmax in pure jnp (lax.scan over KV
                    chunks). Differentiable, remat-friendly, O(S·chunk)
                    memory. Used for the multi-pod dry-run (the CPU backend
                    cannot lower Mosaic) and for CPU-hosted training smokes.
  * ``xla_flash_tri`` — beyond-paper §Perf variant: causally-triangular
                    unrolled chunking that skips above-diagonal work, halving
                    attention HLO FLOPs on training shapes (see
                    EXPERIMENTS.md §Perf).
  * ``ref``       — exact attention (tests only).

``impl='auto'`` picks pallas on TPU and xla_flash elsewhere (backend
detection via ``repro.compat``).

``resolve_mapping(shape, backend)`` is the scheduling entry point: given an
attention shape it scores every (grid order x KV residency x block size)
candidate with the analytic NUMA model (``core.perf_model``, cross-validated
against ``core.cache_sim``) plus the static HBM-traffic model
(``hbm_block_fetches``) and returns the best ``MappingConfig``. Results are
LRU-cached per shape/backend — decode-ness and sliding window are part of
the key, so decode shapes resolve distinctly from prefill. Passing
``mapping=None`` (the default) to ``flash_attention`` routes through it —
there is deliberately no module-level default mapping anymore.

Serving adds the paged pair: ``paged_decode_attention`` dispatches the
page-table kernel (``paged_decode_attention.py``) the same way, and
``resolve_kv_layout`` ranks paged (head-aligned / interleaved placement)
against dense stripes with ``core.perf_model``'s paged decode estimates.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import compat
from repro.kernels import ref as ref_mod
from repro.kernels.decode_attention import flash_decode
from repro.kernels.paged_decode_attention import paged_flash_decode
from repro.kernels.flash_attention import (
    BLOCK_FIRST,
    HEAD_FIRST,
    MappingConfig,
    flash_attention_fwd,
    hbm_block_fetches,
)
from repro.kernels.flash_attention_bwd import flash_attention_bwd


def _on_tpu() -> bool:
    return compat.on_tpu()


# -----------------------------------------------------------------------------
# Mapping resolution: shape -> best NUMA-aware schedule
# -----------------------------------------------------------------------------

#: Candidate (block_m, block_n) tilings, preference-ordered. The MXU-native
#: 128x128 default first; larger variants only win when the model says so
#: (e.g. less padding waste). Sub-128 blocks are excluded — the analytic
#: model would pick them for their smaller causal-diagonal waste, but they
#: under-fill the 128x128 MXU; short sequences still clamp via min(bm, sq).
_CANDIDATE_BLOCKS = ((128, 128), (256, 128), (128, 256))

#: Grid order -> paper mapping name for the analytic model. Every emitted
#: candidate has acc_parallel=True, so both orders score as their swizzled
#: variant (the naive_* names carry perf_model's ACC-replication penalty for
#: schedules we never emit); residency is decided by the candidate filter
#: plus the exact HBM-traffic tie-break, not by the analytic proxy.
_PAPER_NAME = {
    HEAD_FIRST: "swizzled_head_first",
    BLOCK_FIRST: "swizzled_block_first",
}


def _topology_for(backend: str):
    from repro.core import numa

    if backend == "gpu":
        return numa.MI300X
    # TPU and CPU alike schedule for the megacore TPU target: CPU hosts run
    # the kernels in interpret mode, and using the same topology guarantees
    # dry-runs pick the same mapping the real hardware would.
    return numa.TPU_V5P_MEGACORE


@functools.lru_cache(maxsize=1024)
def _resolve_mapping_cached(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    dtype_bytes: int,
    backend: str,
    vmem_budget_bytes: int,
    decode: bool,
    window: Optional[int],
) -> MappingConfig:
    from repro.core import perf_model
    from repro.core.cache_sim import AttentionWorkload
    from repro.core.swizzle import AttentionGrid

    topo = _topology_for(backend)
    group = max(1, num_q_heads // max(num_kv_heads, 1))
    # A sliding window bounds the KV each row actually touches: score (and
    # choose blocks for) the live span, rounded up to a whole tile, not the
    # full cache. Decode shapes attend every prior position, so they score
    # non-causal — a causal model would halve their tile count and pick
    # systematically undersized blocks.
    causal = not decode
    if window is not None and window > 0:
        seq_kv = min(seq_kv, -(-(window + (0 if decode else seq_q)) // 128) * 128)

    def _clamp(block, seq):
        # Never emit a block shorter than the sequence rounded up to the
        # sublane quantum (16 covers bf16's 16 and f32's 8): ops pads the
        # sequence to the block size, and a non-multiple-of-sublane block
        # only works in interpret mode — Mosaic rejects the layout.
        return min(block, max(16, -(-seq // 16) * 16))

    best = None  # (time, traffic, candidate_rank, config)
    rank = 0
    for bm, bn in _CANDIDATE_BLOCKS:
        bm_eff = _clamp(bm, seq_q)
        bn_eff = _clamp(bn, seq_kv)
        for order in (HEAD_FIRST, BLOCK_FIRST):
            for kv_resident in (True, False):
                cand = MappingConfig(
                    order=order,
                    kv_resident=kv_resident,
                    acc_parallel=True,
                    block_m=bm_eff,
                    block_n=bn_eff,
                    vmem_budget_bytes=vmem_budget_bytes,
                )
                if kv_resident and not cand.resolve_resident(
                    seq_kv, head_dim, dtype_bytes
                ):
                    # Over-budget residency degenerates to streaming; keep
                    # only the honest streaming candidate.
                    continue
                # perf_model.estimate models a square (seq_kv x seq_kv)
                # launch: it recomputes blocks_per_head from wl.seq_len, so
                # feed it the same convention. For rectangular shapes
                # (bucketed prefill vs long cache) the analytic time is a
                # square proxy; the exact rectangular traffic enters via the
                # tie-break below.
                grid = AttentionGrid(
                    batch=batch,
                    num_q_heads=num_q_heads,
                    blocks_per_head=-(-seq_kv // bm_eff),
                    group_size=group,
                )
                wl = AttentionWorkload(
                    grid=grid,
                    seq_len=seq_kv,
                    head_dim=head_dim,
                    block_m=bm_eff,
                    block_n=bn_eff,
                    causal=causal,
                    dtype_bytes=dtype_bytes,
                )
                est = perf_model.estimate(_PAPER_NAME[order], wl, topo)
                traffic = hbm_block_fetches(
                    batch=batch,
                    num_q_heads=num_q_heads,
                    num_kv_heads=num_kv_heads,
                    seq_q=seq_q,
                    seq_kv=seq_kv,
                    head_dim=head_dim,
                    dtype_bytes=dtype_bytes,
                    mapping=cand,
                )["total_bytes"]
                key = (est.time, traffic, rank)
                rank += 1
                if best is None or key < best[0]:
                    best = (key, cand)
    return best[1]


def resolve_mapping(
    shape: Tuple[int, int, int, int, int, int],
    backend: Optional[str] = None,
    *,
    dtype_bytes: int = 2,
    vmem_budget_bytes: int = MappingConfig.vmem_budget_bytes,
    decode: bool = False,
    window: Optional[int] = None,
) -> MappingConfig:
    """Pick the best ``MappingConfig`` for an attention shape.

    ``shape`` is ``(batch, num_q_heads, num_kv_heads, seq_q, seq_kv,
    head_dim)``; ``backend`` defaults to the host's jit target. The resolver
    prefers the paper's swizzled head-first residency exactly when the K/V of
    one head fits the VMEM budget (``MappingConfig.resolve_resident``), and
    falls back to a streamed head-first sweep otherwise; block sizes are
    chosen by the HBM-traffic model. Results are LRU-cached.

    ``decode`` / ``window`` are part of the cache key and the scoring:
    decode shapes score non-causal (every prior position is live) and a
    sliding window truncates the scored KV span — so a decode-over-long-
    cache shape resolves to a different schedule than a prefill of the same
    nominal (seq_q, seq_kv).
    """
    b, hq, hkv, sq, skv, d = (int(x) for x in shape)
    return _resolve_mapping_cached(
        b, hq, hkv, sq, skv, d,
        int(dtype_bytes),
        backend or compat.default_backend(),
        int(vmem_budget_bytes),
        bool(decode),
        int(window) if window else None,
    )


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# -----------------------------------------------------------------------------
# Pallas path with custom VJP
# -----------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _pallas_attention(q, k, v, causal, window, softcap, scale, mapping, interpret):
    o, _ = flash_attention_fwd(
        q, k, v, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return o


def _pallas_attention_fwd(q, k, v, causal, window, softcap, scale, mapping, interpret):
    o, lse = flash_attention_fwd(
        q, k, v, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return o, (q, k, v, o, lse)


def _pallas_attention_bwd(causal, window, softcap, scale, mapping, interpret, res, do):
    q, k, v, o, lse = res
    dq, dk, dv = flash_attention_bwd(
        q, k, v, o, lse, do, mapping=mapping, causal=causal, window=window,
        softcap=softcap, scale=scale, interpret=interpret,
    )
    return dq, dk, dv


_pallas_attention.defvjp(_pallas_attention_fwd, _pallas_attention_bwd)


# -----------------------------------------------------------------------------
# XLA flash (scan over KV chunks) — dry-run / CPU path
# -----------------------------------------------------------------------------


def _xla_flash(q, k, v, *, causal, window, softcap, scale, kv_len, chunk=1024,
               unroll=False, q_offset=0):
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    chunk = min(chunk, skv)
    nc = -(-skv // chunk)
    kp = _pad_to(k, 2, chunk).reshape(b, hkv, nc, chunk, d)
    vp = _pad_to(v, 2, chunk).reshape(b, hkv, nc, chunk, d)
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    if scale is None:
        scale = 1.0 / d**0.5
    # Rows sit at absolute positions q_offset + i (prefix-extension prefill:
    # the query block starts after an already-cached prefix).
    rows = q_offset + jnp.arange(sq)[:, None]

    def step(carry, xs):
        m_prev, l_prev, acc = carry
        kc, vc, off = xs
        s = jnp.einsum(
            "bhgqd,bhcd->bhgqc", qg, kc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        ) * scale
        if softcap is not None and softcap > 0:
            s = softcap * jnp.tanh(s / softcap)
        cols = off + jnp.arange(chunk)[None, :]
        mask = cols < kv_len
        if causal:
            mask &= cols <= rows
        if window is not None and window > 0:
            mask &= cols > rows - window
        s = jnp.where(mask[None, None, None], s, ref_mod.NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vc.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc), None

    init = (
        jnp.full((b, hkv, g, sq, 1), ref_mod.NEG_INF, jnp.float32),
        jnp.zeros((b, hkv, g, sq, 1), jnp.float32),
        jnp.zeros((b, hkv, g, sq, d), jnp.float32),
    )
    xs = (
        jnp.moveaxis(kp, 2, 0),
        jnp.moveaxis(vp, 2, 0),
        jnp.arange(nc) * chunk,
    )
    (m_fin, l_fin, acc), _ = jax.lax.scan(step, init, xs,
                                          unroll=nc if unroll else 1)
    l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
    o = (acc / l_safe).reshape(b, hq, sq, d)
    return o.astype(q.dtype)


def _xla_flash_tri(q, k, v, *, causal, window, softcap, scale, kv_len, chunk=1024,
                   q_offset=0):
    """Causal-triangular variant: q chunk i only attends kv[: (i+1)*chunk].

    Unrolled over q chunks with per-iteration static shapes, so the
    above-diagonal half of the score matrix is never built — the compiled
    HLO carries ~half the attention FLOPs of the scan variant on causal
    training shapes. Falls back to the scan variant when not causal, when
    q/kv lengths differ, or when the query block is offset (prefix-cache
    extension prefill).
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if not causal or sq != skv or sq % chunk or q_offset:
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=kv_len, chunk=chunk, q_offset=q_offset,
        )
    nq = sq // chunk
    outs = []
    for i in range(nq):
        qi = q[:, :, i * chunk : (i + 1) * chunk]
        hi = (i + 1) * chunk
        lo = 0
        if window is not None and window > 0:
            lo = max(0, (i * chunk - window + 1) // chunk * chunk)
        ki = k[:, :, lo:hi]
        vi = v[:, :, lo:hi]
        # positions are absolute: shift rows by q_offset via kv_len masking
        oi = _xla_flash_offset(
            qi, ki, vi, abs_q=i * chunk, abs_k=lo, causal=True, window=window,
            softcap=softcap, scale=scale, kv_len=min(kv_len, hi), chunk=chunk,
        )
        outs.append(oi)
    return jnp.concatenate(outs, axis=2)


def _xla_flash_offset(
    q, k, v, *, abs_q, abs_k, causal, window, softcap, scale, kv_len, chunk
):
    """One (q-chunk x kv-prefix) tile with absolute position masking."""
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    g = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    qg = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum(
        "bhgqd,bhcd->bhgqc", qg, k.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    ) * scale
    if softcap is not None and softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    rows = abs_q + jnp.arange(sq)[:, None]
    cols = abs_k + jnp.arange(skv)[None, :]
    mask = cols < kv_len
    if causal:
        mask &= cols <= rows
    if window is not None and window > 0:
        mask &= cols > rows - window
    s = jnp.where(mask[None, None, None], s, ref_mod.NEG_INF)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhgqc,bhcd->bhgqd", p / jnp.where(l == 0, 1, l),
                   v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


# -----------------------------------------------------------------------------
# Public API
# -----------------------------------------------------------------------------


def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    mapping: Optional[MappingConfig] = None,
    impl: str = "auto",
    chunk_unroll: bool = False,
    q_offset: int = 0,
) -> jnp.ndarray:
    """Multi-head / grouped-query attention. q: (B,Hq,Sq,D); k,v: (B,Hkv,Skv,D).

    ``mapping=None`` auto-selects the NUMA-aware schedule for this shape via
    :func:`resolve_mapping`.

    ``q_offset`` places the query block at absolute positions
    ``[q_offset, q_offset + Sq)`` against a longer KV (prefix-extension
    prefill over a shared-prefix cache). Supported on the xla/ref paths; the
    Pallas forward does not carry the offset yet, so a nonzero offset routes
    to the XLA flash path (ROADMAP: paged prefill kernel).
    """
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla_flash"
    if q_offset and impl == "pallas":
        impl = "xla_flash"
    b, hq, sq, d = q.shape
    skv = k.shape[2]
    if impl == "ref":
        return ref_mod.attention(
            q, k, v, causal=causal, window=window, softcap=softcap, scale=scale,
            q_offset=q_offset,
        )
    if impl == "xla_flash":
        return _xla_flash(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=skv, unroll=chunk_unroll, q_offset=q_offset,
        )
    if impl == "xla_flash_tri":
        return _xla_flash_tri(
            q, k, v, causal=causal, window=window, softcap=softcap,
            scale=scale, kv_len=skv, q_offset=q_offset,
        )
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")

    if mapping is None:
        mapping = resolve_mapping(
            (b, hq, k.shape[1], sq, skv, d),
            dtype_bytes=q.dtype.itemsize,
        )
    bm, bn = mapping.block_m, mapping.block_n
    qp = _pad_to(q, 2, bm)
    kp = _pad_to(k, 2, bn)
    vp = _pad_to(v, 2, bn)
    interpret = compat.use_interpret()
    o = _pallas_attention(
        qp, kp, vp, causal, window, softcap, scale, mapping, interpret
    )
    return o[:, :, :sq]


def decode_attention(
    q: jnp.ndarray,
    k_cache: jnp.ndarray,
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Single-token decode. q: (B,Hq,D); caches: (B,Hkv,Smax,D); lengths: (B,)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla" or impl == "ref":
        return ref_mod.decode_attention(
            q, k_cache, v_cache, lengths, softcap=softcap, scale=scale, window=window
        )
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    b, hq, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    # The KV chunk is the resolver's block_n for this decode shape (decode
    # and window are part of the resolution key, so a windowed decode picks
    # its schedule independently of the prefill of the same cache).
    mapping = resolve_mapping(
        (b, hq, hkv, 1, smax, d),
        dtype_bytes=q.dtype.itemsize, decode=True, window=window,
    )
    chunk = min(mapping.block_n, smax)
    if smax % chunk:
        # Decode is the serving hot loop: prefer a chunk that divides the
        # cache (largest sublane-multiple divisor <= block_n) so no copy
        # happens per tick. Only truly odd capacities pay the pad-to-chunk
        # copy; the padded tail sits beyond every ``lengths`` entry, so
        # masking never admits it.
        divisor = next(
            (c for c in range(chunk, 7, -1) if smax % c == 0 and c % 8 == 0),
            None,
        )
        if divisor is not None:
            chunk = divisor
        else:
            k_cache = _pad_to(k_cache, 2, chunk)
            v_cache = _pad_to(v_cache, 2, chunk)
    return flash_decode(
        q, k_cache, v_cache, lengths,
        softcap=softcap, scale=scale, window=window, chunk=chunk,
        interpret=compat.use_interpret(),
    )


def paged_decode_attention(
    q: jnp.ndarray,
    k_pages: jnp.ndarray,
    v_pages: jnp.ndarray,
    page_table: jnp.ndarray,
    lengths: jnp.ndarray,
    *,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    window: Optional[int] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Paged single-token decode. q: (B,Hq,D); k/v_pages: (Hkv,P,ps,D)
    head-major; page_table: (B,max_pages) physical ids (null-page padded);
    lengths: (B,). The pallas path consumes the page table natively via
    scalar prefetch; xla/ref gathers a dense view first (oracle/dry-run)."""
    if impl == "auto":
        impl = "pallas" if _on_tpu() else "xla"
    if impl == "xla" or impl == "ref":
        return ref_mod.paged_decode_attention(
            q, k_pages, v_pages, page_table, lengths,
            softcap=softcap, scale=scale, window=window,
        )
    if impl != "pallas":
        raise ValueError(f"unknown impl {impl!r}")
    return paged_flash_decode(
        q, k_pages, v_pages, page_table, lengths,
        softcap=softcap, scale=scale, window=window,
        interpret=compat.use_interpret(),
    )


# -----------------------------------------------------------------------------
# KV-layout resolution: paged vs dense, placement policy
# -----------------------------------------------------------------------------


@functools.lru_cache(maxsize=256)
def _resolve_kv_layout_cached(
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    mean_len: int,
    capacity: int,
    page_size: int,
    head_dim: int,
    dtype_bytes: int,
    backend: str,
    shared_prefix_len: int,
) -> Tuple[str, float, float]:
    from repro.core import perf_model

    topo = _topology_for(backend)
    dense = perf_model.estimate_dense_decode(
        batch=batch, num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
        capacity=capacity, head_dim=head_dim, dtype_bytes=dtype_bytes,
        topo=topo,
    )
    candidates = {"dense": dense.time}
    for policy in ("head_aligned", "interleaved"):
        est = perf_model.estimate_paged_decode(
            batch=batch, num_q_heads=num_q_heads, num_kv_heads=num_kv_heads,
            mean_len=mean_len, page_size=page_size, head_dim=head_dim,
            dtype_bytes=dtype_bytes, topo=topo, policy=policy,
            shared_prefix_len=shared_prefix_len,
        )
        candidates[f"paged:{policy}"] = est.time
    best = min(candidates, key=candidates.get)
    return best, candidates[best], candidates["dense"]


def resolve_kv_layout(
    shape: Tuple[int, int, int, int, int],
    *,
    capacity: int,
    page_size: int = 64,
    dtype_bytes: int = 2,
    backend: Optional[str] = None,
    shared_prefix_len: int = 0,
) -> str:
    """Rank KV layouts for a decode mix; returns ``"dense"``,
    ``"paged:head_aligned"`` or ``"paged:interleaved"``.

    ``shape`` is ``(batch, num_q_heads, num_kv_heads, mean_len, head_dim)``
    — the decode batch and its mean live sequence length; ``capacity`` is
    the dense per-slot stripe the paged layout would replace. Scored with
    ``core.perf_model``'s paged/dense decode estimates (page-granular
    traffic, once-per-domain shared-prefix reuse, link-cost for remote
    pages), the decode analogue of :func:`resolve_mapping`'s ranking."""
    b, hq, hkv, mean_len, head_dim = (int(x) for x in shape)
    best, _, _ = _resolve_kv_layout_cached(
        b, hq, hkv, mean_len, int(capacity), int(page_size),
        head_dim, int(dtype_bytes),
        backend or compat.default_backend(),
        int(shared_prefix_len),
    )
    return best
