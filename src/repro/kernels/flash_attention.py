"""FlashAttention-2 Pallas TPU kernels with NUMA-aware grid scheduling.

This is the TPU-native translation of the paper's Swizzled Head-first
Mapping. On a GPU the mapping strategy is a workgroup-ID remap; on TPU the
same freedom lives in (a) the *grid iteration order* and (b) the BlockSpec
``index_map``s, because the Pallas pipeline skips the HBM->VMEM copy of any
block whose index is unchanged between consecutive grid steps (revisiting).

Two structural axes, mirroring paper §3.2/3.3:

  order="head_first"   grid (b, h, m, ...) — all row blocks of one head
                       before the next head: one ACC at a time per core.
  order="block_first"  grid (b, m, h, ...) — heads cycle fastest: the
                       paper's fragmented baseline; no operand survives
                       between consecutive grid steps.

  kv_resident=True     the whole K/V of the current (batch, kv-head) is a
                       single VMEM-resident block, revisited across every
                       q-block (and every q-head of a GQA group). K/V is
                       fetched from HBM ONCE per ACC — the TPU analogue of
                       the paper's 97 % L2 hit rate. Requires
                       2*S*D*dtype <= vmem budget.
  kv_resident=False    K/V streamed in (block_n, D) tiles (classic FA2);
                       under head_first the Q block is still revisited
                       across the KV sweep.

``hbm_block_fetches`` computes, statically, how many HBM block copies each
configuration performs — the dry-run "hit rate" analogue reported in
benchmarks (no hardware counters needed).

Megacore: ``acc_parallel=True`` marks the batch/head grid dimensions
``PARALLEL`` so a two-core chip splits the grid along ACC boundaries
(swizzled); ``False`` leaves them ARBITRARY (sequential, single-ACC-stream).

All kernels validate in ``interpret=True`` mode against ``ref.py`` (see
tests/test_flash_attention.py for the shape x dtype x flag sweeps).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat
from repro.core import swizzle

NEG_INF = -1e30

HEAD_FIRST = "head_first"
BLOCK_FIRST = "block_first"


@dataclasses.dataclass(frozen=True)
class MappingConfig:
    """NUMA-aware scheduling configuration for the attention kernels.

    The paper's four strategies map onto TPU as:
      swizzled_head_first : order=head_first, kv_resident=True,  acc_parallel=True
      naive_head_first    : order=head_first, kv_resident=False, acc_parallel=False
      swizzled_block_first: order=block_first, kv_resident=True, acc_parallel=True
      naive_block_first   : order=block_first, kv_resident=False, acc_parallel=False
    (block_first + kv_resident thrashes by construction: the resident block
    changes at every grid step — kept for the paper's baseline measurements.)
    """

    order: str = HEAD_FIRST
    kv_resident: bool = True
    acc_parallel: bool = True
    block_m: int = 128
    block_n: int = 128
    # VMEM budget for the resident K/V copy (per core); beyond this the
    # wrapper falls back to streaming. ~half of v5e VMEM, leaving room for
    # double-buffered Q/O and accumulators.
    vmem_budget_bytes: int = 64 * 1024 * 1024
    # KV-sweep traversal for the *streaming* path (sawtooth wavefront,
    # ROADMAP 5(a)): "linear" walks tiles 0..num_n-1 every sweep;
    # "sawtooth" serpentines so consecutive sweeps share their boundary
    # tile and Pallas skips its HBM->VMEM copy. Ignored when the K/V is
    # VMEM-resident (there is no per-tile sweep to reorder).
    traversal: str = swizzle.LINEAR

    def resolve_resident(self, skv: int, head_dim: int, dtype_bytes: int) -> bool:
        if not self.kv_resident:
            return False
        return 2 * skv * head_dim * dtype_bytes <= self.vmem_budget_bytes


PAPER_MAPPINGS = {
    "swizzled_head_first": MappingConfig(order=HEAD_FIRST, kv_resident=True, acc_parallel=True),
    "naive_head_first": MappingConfig(order=HEAD_FIRST, kv_resident=False, acc_parallel=False),
    "swizzled_block_first": MappingConfig(order=BLOCK_FIRST, kv_resident=True, acc_parallel=True),
    "naive_block_first": MappingConfig(order=BLOCK_FIRST, kv_resident=False, acc_parallel=False),
}


def _dim_semantics(order: str, acc_parallel: bool, ndims: int):
    """PARALLEL on the leading (batch, head) dims when ACC-aligned."""
    par = compat.PARALLEL
    arb = compat.ARBITRARY
    if not acc_parallel:
        return (arb,) * ndims
    if order == HEAD_FIRST:
        # (b, h, ...) — split cores at ACC boundaries.
        return (par, par) + (arb,) * (ndims - 2)
    # block_first: (b, m, h, ...) — b parallel only (m-split would stripe
    # ACCs across cores; that *is* the naive scheme, expressed by
    # acc_parallel=False).
    return (par,) + (arb,) * (ndims - 1)


def _block_mask(
    rows,  # (bm, 1) absolute row ids
    cols,  # (1, bn) absolute col ids
    *,
    causal: bool,
    window: Optional[int],
    kv_len: int,
):
    mask = cols < kv_len  # padding guard
    if causal:
        mask &= cols <= rows
    if window is not None and window > 0:
        mask &= cols > rows - window
    return mask


def _apply_softcap(s, softcap: Optional[float]):
    if softcap is not None and softcap > 0:
        return softcap * jnp.tanh(s / softcap)
    return s


# -----------------------------------------------------------------------------
# Forward, streaming K/V (classic FA2; order decides revisiting behaviour)
# -----------------------------------------------------------------------------


def _fwd_stream_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, softcap, kv_len, num_n, block_m, block_n, order,
    traversal,
):
    if order == HEAD_FIRST:
        m_idx = pl.program_id(2)
    else:
        m_idx = pl.program_id(1)
    # n_seq is the *position in the sweep* (init/emit anchors); n_idx is
    # the KV tile this step actually loads — under sawtooth odd sweeps
    # walk the tiles in reverse, mirroring the kv BlockSpec index_map.
    n_seq = pl.program_id(3)
    n_idx = swizzle.kv_tile_order(traversal, m_idx, n_seq, num_n)

    @pl.when(n_seq == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = m_idx * block_m + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)
    cols = n_idx * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)

    # Block-level relevance (causal / window / padding) — skip compute, not
    # the copy (the grid is rectangular on TPU; see kv_resident=True for the
    # variant that skips the work *and* the traffic).
    q_start = m_idx * block_m
    kv_start = n_idx * block_n
    relevant = kv_start < kv_len
    if causal:
        relevant &= kv_start <= q_start + block_m - 1
    if window is not None and window > 0:
        relevant &= kv_start + block_n - 1 > q_start - window

    @pl.when(relevant)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale
        s = _apply_softcap(s, softcap)
        mask = _block_mask(rows, cols, causal=causal, window=window, kv_len=kv_len)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0:1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_ref[:, 0:1] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(n_seq == num_n - 1)
    def _emit():
        l = l_ref[:, 0:1]
        l_safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / l_safe).astype(o_ref.dtype)
        lse = m_ref[:, 0] + jnp.log(jnp.where(l[:, 0] == 0.0, 1.0, l[:, 0]))
        lse_ref[0, 0] = jnp.where(l[:, 0] == 0.0, NEG_INF, lse)


# -----------------------------------------------------------------------------
# Forward, VMEM-resident K/V (the paper-faithful TPU schedule)
# -----------------------------------------------------------------------------


def _fwd_resident_kernel(
    q_ref, k_ref, v_ref, o_ref, lse_ref,
    *, scale, causal, window, softcap, kv_len, block_m, block_n, order,
):
    if order == HEAD_FIRST:
        m_idx = pl.program_id(2)
    else:
        m_idx = pl.program_id(1)

    skv = k_ref.shape[2]
    num_n = skv // block_n
    q = q_ref[0, 0].astype(jnp.float32)
    rows = m_idx * block_m + jax.lax.broadcasted_iota(jnp.int32, (block_m, 1), 0)

    q_start = m_idx * block_m
    # Work-skipping: only the causally/window-relevant KV chunk range is
    # visited — the resident layout makes the *compute* sub-quadratic-per-
    # block without paying rectangular-grid copies.
    if causal:
        n_hi = jnp.minimum(
            (q_start + block_m + block_n - 1) // block_n, num_n
        ).astype(jnp.int32)
    else:
        n_hi = jnp.int32(num_n)
    if window is not None and window > 0:
        n_lo = jnp.maximum((q_start - window + 1) // block_n, 0).astype(jnp.int32)
    else:
        n_lo = jnp.int32(0)

    def body(n, carry):
        m_prev, l_prev, acc = carry
        k = k_ref[0, 0, pl.ds(n * block_n, block_n), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(n * block_n, block_n), :].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        s = s * scale
        s = _apply_softcap(s, softcap)
        cols = n * block_n + jax.lax.broadcasted_iota(jnp.int32, (1, block_n), 1)
        mask = _block_mask(rows, cols, causal=causal, window=window, kv_len=kv_len)
        s = jnp.where(mask, s, NEG_INF)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc = acc * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        return m_new, l_new, acc

    d = q_ref.shape[-1]
    init = (
        jnp.full((block_m, 1), NEG_INF, jnp.float32),
        jnp.zeros((block_m, 1), jnp.float32),
        jnp.zeros((block_m, d), jnp.float32),
    )
    m_fin, l_fin, acc = jax.lax.fori_loop(n_lo, n_hi, body, init)
    l_safe = jnp.where(l_fin == 0.0, 1.0, l_fin)
    o_ref[0, 0] = (acc / l_safe).astype(o_ref.dtype)
    lse = m_fin[:, 0] + jnp.log(l_safe[:, 0])
    lse_ref[0, 0] = jnp.where(l_fin[:, 0] == 0.0, NEG_INF, lse)


# -----------------------------------------------------------------------------
# pallas_call builders
# -----------------------------------------------------------------------------


def _fwd_cost(b, hq, sq, skv, d, causal, dtype_bytes):
    frac = 0.5 if causal and sq == skv else 1.0
    flops = 4.0 * b * hq * sq * skv * d * frac
    bytes_accessed = dtype_bytes * b * (2 * hq * sq * d + 2 * hq * skv * d)
    return pl.CostEstimate(
        flops=int(flops), bytes_accessed=int(bytes_accessed), transcendentals=int(b * hq * sq * skv * frac)
    )


def flash_attention_fwd(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    *,
    mapping: MappingConfig = MappingConfig(),
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    scale: Optional[float] = None,
    kv_len: Optional[int] = None,
    interpret: bool = False,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Pallas FA2 forward. q: (B,Hq,Sq,D), k/v: (B,Hkv,Skv,D).

    Returns (o, lse). Sq/Skv must be multiples of the block sizes (the ops.py
    wrapper pads); ``kv_len`` masks padding keys.
    """
    b, hq, sq, d = q.shape
    _, hkv, skv, _ = k.shape
    if hq % hkv:
        raise ValueError(f"Hq={hq} not divisible by Hkv={hkv}")
    group = hq // hkv
    if scale is None:
        scale = 1.0 / d**0.5
    if kv_len is None:
        kv_len = skv
    bm, bn = mapping.block_m, mapping.block_n
    bm = min(bm, sq)
    bn = min(bn, skv)
    if sq % bm or skv % bn:
        raise ValueError(f"Sq={sq}/Skv={skv} not divisible by blocks {bm}/{bn}")
    num_m, num_n = sq // bm, skv // bn
    resident = mapping.resolve_resident(skv, d, q.dtype.itemsize)

    if mapping.order == HEAD_FIRST:
        def gidx(b_, h_, m_):
            return b_, h_, m_
    else:
        def gidx(b_, m_, h_):
            return b_, h_, m_

    out_shape = [
        jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        jax.ShapeDtypeStruct((b, hq, sq), jnp.float32),
    ]

    if resident:
        grid = (b, hq, num_m) if mapping.order == HEAD_FIRST else (b, num_m, hq)

        def q_idx(*g):
            b_, h_, m_ = gidx(*g)
            return (b_, h_, m_, 0)

        def kv_idx(*g):
            b_, h_, m_ = gidx(*g)
            return (b_, h_ // group, 0, 0)

        kernel = functools.partial(
            _fwd_resident_kernel,
            scale=scale, causal=causal, window=window, softcap=softcap,
            kv_len=kv_len, block_m=bm, block_n=bn, order=mapping.order,
        )
        fn = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, bm, d), q_idx),
                pl.BlockSpec((1, 1, skv, d), kv_idx),
                pl.BlockSpec((1, 1, skv, d), kv_idx),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, bm, d), q_idx),
                pl.BlockSpec((1, 1, bm), lambda *g: gidx(*g)),
            ],
            out_shape=out_shape,
            compiler_params=compat.tpu_compiler_params(
                dimension_semantics=_dim_semantics(
                    mapping.order, mapping.acc_parallel, len(grid)
                ),
            ),
            cost_estimate=_fwd_cost(b, hq, sq, skv, d, causal, q.dtype.itemsize),
            interpret=interpret,
            name=f"fa2_fwd_resident_{mapping.order}",
        )
        return tuple(fn(q, k, v))

    # streaming
    grid = (
        (b, hq, num_m, num_n)
        if mapping.order == HEAD_FIRST
        else (b, num_m, hq, num_n)
    )

    def q_idx(*g):
        b_, h_, m_ = gidx(*g[:3])
        return (b_, h_, m_, 0)

    def kv_idx(*g):
        b_, h_, m_ = gidx(*g[:3])
        n_ = swizzle.kv_tile_order(mapping.traversal, m_, g[3], num_n)
        return (b_, h_ // group, n_, 0)

    kernel = functools.partial(
        _fwd_stream_kernel,
        scale=scale, causal=causal, window=window, softcap=softcap,
        kv_len=kv_len, num_n=num_n, block_m=bm, block_n=bn, order=mapping.order,
        traversal=mapping.traversal,
    )
    fn = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bm, d), q_idx),
            pl.BlockSpec((1, 1, bn, d), kv_idx),
            pl.BlockSpec((1, 1, bn, d), kv_idx),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bm, d), q_idx),
            pl.BlockSpec((1, 1, bm), lambda *g: gidx(*g[:3])),
        ],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bm, d), jnp.float32),
            pltpu.VMEM((bm, 128), jnp.float32),
            pltpu.VMEM((bm, 128), jnp.float32),
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=_dim_semantics(
                mapping.order, mapping.acc_parallel, len(grid)
            ),
        ),
        cost_estimate=_fwd_cost(b, hq, sq, skv, d, causal, q.dtype.itemsize),
        interpret=interpret,
        name=f"fa2_fwd_stream_{mapping.order}",
    )
    return tuple(fn(q, k, v))


# -----------------------------------------------------------------------------
# Static HBM-traffic model (the dry-run analogue of the paper's hit rates)
# -----------------------------------------------------------------------------


def hbm_block_fetches(
    *,
    batch: int,
    num_q_heads: int,
    num_kv_heads: int,
    seq_q: int,
    seq_kv: int,
    head_dim: int,
    dtype_bytes: int = 2,
    mapping: MappingConfig = MappingConfig(),
) -> dict:
    """Bytes each operand is copied HBM->VMEM under a mapping, computed from
    the grid order + index maps (Pallas skips copies for revisited blocks).

    This is exact for the compiled pipeline (no cache stochasticity on TPU)
    and is what benchmarks/fig13 reports for the TPU port alongside the
    MI300X simulator numbers.
    """
    bm, bn = mapping.block_m, mapping.block_n
    num_m = -(-seq_q // bm)
    num_n = -(-seq_kv // bn)
    q_bytes = seq_q * head_dim * dtype_bytes
    kv_bytes = 2 * seq_kv * head_dim * dtype_bytes  # K and V, whole sequence
    kv_tile_bytes = 2 * bn * head_dim * dtype_bytes  # K and V, one (bn, D) tile

    resident = mapping.resolve_resident(seq_kv, head_dim, dtype_bytes)
    if resident:
        # The resident block is the whole (Skv, D) K/V of one kv head, copied
        # as a unit whenever its grid index changes between consecutive steps.
        if mapping.order == HEAD_FIRST:
            # KV block revisited across all m of a head AND across the g
            # q-heads of its group: fetched once per (batch, kv head).
            kv_fetches = batch * num_kv_heads
        else:
            # (b, m, h): the resident block swaps inside every m sweep, so
            # each (batch, q-block) re-fetches every kv head — the thrashing
            # baseline of paper Fig. 8. Consecutive q-heads of one GQA group
            # share the block index, so the pipeline still skips those
            # copies (num_kv_heads fetches per sweep, not num_q_heads); with
            # a single kv head the index never changes at all.
            if num_kv_heads == 1:
                kv_fetches = batch
            else:
                kv_fetches = batch * num_m * num_kv_heads
        kv_traffic = kv_fetches * kv_bytes
    else:
        # Streaming: the full num_n-tile K/V sweep is refetched for every
        # (q-head, q-block) pair under either order (no cache between HBM and
        # VMEM on TPU; order only changes which ACC is live, not the traffic).
        kv_fetches = batch * num_q_heads * num_m * num_n
        if (mapping.traversal == swizzle.SAWTOOTH
                and mapping.order == HEAD_FIRST and num_n > 1):
            # Serpentine sweeps share their boundary tile: the last tile of
            # sweep m is the first tile of sweep m+1, so Pallas skips its
            # copy — one tile saved per consecutive-sweep boundary. Within a
            # q-head that is num_m - 1 boundaries; across the g q-heads of a
            # GQA group (same kv head, consecutive under head_first) the
            # head boundary also matches when num_m is even (the last sweep
            # ends where the next head's first sweep starts).
            group = max(1, num_q_heads // max(num_kv_heads, 1))
            saved_per_kv = (num_m - 1) * group + (
                (group - 1) if num_m % 2 == 0 else 0
            )
            kv_fetches -= batch * num_kv_heads * saved_per_kv
        kv_traffic = kv_fetches * kv_tile_bytes
    # Q: each (bm, D) block is copied once per (batch, q-head, q-block) —
    # under head_first the block is revisited across the whole KV sweep, and
    # under block_first it still changes only when m does.
    q_traffic = batch * num_q_heads * num_m * (bm * head_dim * dtype_bytes)
    ideal = batch * (num_kv_heads * kv_bytes + num_q_heads * q_bytes)
    total = kv_traffic + q_traffic
    return {
        "kv_bytes": kv_traffic,
        "q_bytes": q_traffic,
        "total_bytes": total,
        "ideal_bytes": ideal,
        "reuse_efficiency": ideal / total,
    }
