"""Gradient compression for cross-replica reduction: int8 + error feedback.

At 1000+ nodes the data-parallel gradient all-reduce dominates the step's
collective bytes. This module provides:

  * ``quantize`` / ``dequantize`` — blockwise symmetric int8 with per-block
    f32 scales (4x compression on the wire),
  * ``ErrorFeedback`` — residual accumulator so quantization error is
    re-injected next step (EF-SGD; keeps convergence),
  * ``compressed_psum`` — a shard_map-compatible reduction: quantize ->
    psum int32 accumulation of int8 payloads -> dequantize with max-scale.
    Under plain pjit the all-reduce is XLA-inserted and cannot be re-typed,
    so compression must be explicit: train_step exposes
    ``grad_compression="int8"`` which reduces DP gradients through this path
    inside a shard_map over the data axes.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

BLOCK = 2048


class Compressed(NamedTuple):
    q: jnp.ndarray       # int8 payload, shape = padded flat
    scale: jnp.ndarray   # f32 per-block scales


def quantize(x: jnp.ndarray, block: int = BLOCK) -> Compressed:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale[:, 0])


def dequantize(c: Compressed, shape, dtype=jnp.float32) -> jnp.ndarray:
    flat = (c.q.astype(jnp.float32) * c.scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape).astype(dtype)


class ErrorFeedback(NamedTuple):
    residual: Any  # tree like grads


def init_error_feedback(grads_shape) -> ErrorFeedback:
    return ErrorFeedback(
        residual=jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape)
    )


def compress_with_feedback(
    grads, ef: ErrorFeedback
) -> Tuple[Any, ErrorFeedback]:
    """Quantize (grad + residual); stash the new residual. Returns the
    dequantized tree (what the wire would deliver) + updated feedback."""

    def one(g, r):
        target = g.astype(jnp.float32) + r
        c = quantize(target)
        deq = dequantize(c, g.shape)
        return deq.astype(g.dtype), target - deq

    g_leaves, treedef = jax.tree_util.tree_flatten(grads)
    r_leaves = treedef.flatten_up_to(ef.residual)
    outs = [one(g, r) for g, r in zip(g_leaves, r_leaves)]
    deq = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    res = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return deq, ErrorFeedback(residual=res)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Inside shard_map: reduce ``x`` over ``axis_name`` with an int8 wire
    format. Payload rides as int32 (psum-able); scales reduce by max."""
    c = quantize(x)
    scale_max = jax.lax.pmax(c.scale, axis_name)
    # Re-quantize against the shared scale so the integer sum is coherent.
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    q = jnp.clip(jnp.round(blocks / scale_max[:, None]), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    deq = (total.astype(jnp.float32) * scale_max[:, None] / n.astype(jnp.float32))
    return deq.reshape(-1)[: flat.size].reshape(x.shape).astype(x.dtype)
