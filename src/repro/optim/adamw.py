"""AdamW with decoupled weight decay, global-norm clipping, cosine schedule.

Self-contained (no optax in this environment). Optimizer state mirrors the
parameter tree, so parameter PartitionSpecs apply verbatim to both moments —
ZeRO-style sharded optimizer state falls out of the same sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: Tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # Moment storage dtype. bf16 moments cut optimizer HBM by half — the
    # lever that fits llama3-405b training on a single v5e-256 pod (see
    # EXPERIMENTS.md §Perf); updates are still computed in f32.
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init(params, cfg: "AdamWConfig" = None) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype) if cfg is not None else jnp.float32
    zeros = lambda p: jnp.zeros(p.shape, dtype=dt)
    return OptState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
    )


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def _decayable(path) -> bool:
    """No decay on norms / scalars / 1-D vectors (biases, gates)."""
    for entry in reversed(path):
        if hasattr(entry, "key"):
            k = str(entry.key)
            return not (k.endswith("_r") or "norm" in k or k.startswith("ln"))
    return True


def update(
    cfg: AdamWConfig, params, grads, state: OptState
) -> Tuple[Any, OptState, Dict[str, jnp.ndarray]]:
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    b1, b2 = cfg.betas
    step = state.step + 1
    lr = schedule(cfg, step)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(path, p, g, mu, nu):
        mdt = mu.dtype
        g = g.astype(jnp.float32) * clip
        mu = b1 * mu.astype(jnp.float32) + (1 - b1) * g
        nu = b2 * nu.astype(jnp.float32) + (1 - b2) * g * g
        step_dir = (mu / bc1) / (jnp.sqrt(nu / bc2) + cfg.eps)
        if _decayable(path):
            step_dir = step_dir + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype)
        return new_p, mu.astype(mdt), nu.astype(mdt)

    paths_and_p, treedef = jax.tree_util.tree_flatten_with_path(params)
    g_leaves = treedef.flatten_up_to(grads)
    mu_leaves = treedef.flatten_up_to(state.mu)
    nu_leaves = treedef.flatten_up_to(state.nu)
    new_p, new_m, new_n = [], [], []
    for (path, p), g, mu, nu in zip(paths_and_p, g_leaves, mu_leaves, nu_leaves):
        p2, m2, n2 = upd(path, p, g, mu, nu)
        new_p.append(p2)
        new_m.append(m2)
        new_n.append(n2)
    new_params = jax.tree_util.tree_unflatten(treedef, new_p)
    new_mu = jax.tree_util.tree_unflatten(treedef, new_m)
    new_nu = jax.tree_util.tree_unflatten(treedef, new_n)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, OptState(step=step, mu=new_mu, nu=new_nu), metrics
