"""repro subpackage."""
