"""repro subpackage."""
