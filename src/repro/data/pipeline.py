"""Deterministic, shardable, checkpointable data pipeline.

Production posture: the loader is a pure function of (seed, step, shard), so
* any worker can reproduce any batch — restart/elastic-rescale safe,
* no coordinator state beyond the step counter (which rides the checkpoint),
* per-pod sharding falls out of slicing the global batch.

Two sources:
  * ``SyntheticLM`` — Zipf-distributed token documents with EOS framing and
    a learnable-structure flavor (repeated n-grams) so loss actually falls
    during the example runs; used by tests/examples/benchmarks.
  * ``MemmapLM`` — flat token file (np.memmap) with deterministic strided
    sampling; drop-in for real corpora.

Batches are {"tokens": (B, S[, K]) int32, "targets": same, "mask": f32}.
Targets are tokens shifted one position (next-token prediction); the final
position is masked.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    vocab: int = 32000
    num_codebooks: int = 1
    path: Optional[str] = None      # set => MemmapLM
    ngram_vocab: int = 64           # synthetic structure strength


class SyntheticLM:
    """Deterministic synthetic corpus: batch = f(seed, step, shard)."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        if cfg.global_batch % num_shards:
            raise ValueError("global_batch must divide by num_shards")
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard])
        )
        shape = (self.local_batch, cfg.seq_len + 1)
        if cfg.num_codebooks > 1:
            shape = shape + (cfg.num_codebooks,)
        # Zipf body with periodic structure: documents repeat a small n-gram
        # alphabet so a capable model can reduce loss quickly.
        zipf = rng.zipf(1.3, size=shape)
        tokens = (zipf % max(cfg.vocab - 2, 2)) + 1
        # overlay: every other document is a repeated 8-gram
        motif_len = 8
        motif = rng.integers(1, min(cfg.ngram_vocab, cfg.vocab - 1),
                             size=(self.local_batch, motif_len) + shape[2:])
        reps = -(-(cfg.seq_len + 1) // motif_len)
        pattern = np.tile(motif, (1, reps) + (1,) * (len(shape) - 2))[:, : cfg.seq_len + 1]
        structured = rng.random(self.local_batch) < 0.5
        tokens[structured] = pattern[structured]
        tokens = tokens.astype(np.int32)
        inputs = tokens[:, :-1]
        targets = tokens[:, 1:]
        mask = np.ones(targets.shape[:2], np.float32)
        return {"tokens": inputs, "targets": targets, "mask": mask}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class MemmapLM:
    """Flat-token-file corpus with deterministic strided sampling."""

    def __init__(self, cfg: DataConfig, shard: int = 0, num_shards: int = 1):
        assert cfg.path is not None
        self.cfg = cfg
        self.shard = shard
        self.num_shards = num_shards
        self.local_batch = cfg.global_batch // num_shards
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n_windows = (len(self.data) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.shard])
        )
        idx = rng.integers(0, self.n_windows, size=self.local_batch)
        starts = idx * cfg.seq_len
        rows = np.stack([self.data[s : s + cfg.seq_len + 1] for s in starts])
        return {
            "tokens": rows[:, :-1].astype(np.int32),
            "targets": rows[:, 1:].astype(np.int32),
            "mask": np.ones((self.local_batch, cfg.seq_len), np.float32),
        }


def make_pipeline(cfg: DataConfig, shard: int = 0, num_shards: int = 1):
    if cfg.path:
        return MemmapLM(cfg, shard, num_shards)
    return SyntheticLM(cfg, shard, num_shards)


def data_config_for(model: ModelConfig, seq_len: int, global_batch: int,
                    seed: int = 0) -> DataConfig:
    return DataConfig(
        seq_len=seq_len,
        global_batch=global_batch,
        seed=seed,
        vocab=model.vocab,
        num_codebooks=model.num_codebooks,
    )
