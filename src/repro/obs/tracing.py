"""Step-level tracing: engine spans + per-request lifecycle events.

Two record streams, one tracer:

  * **Spans** — nested wall-clock intervals around the serving phases
    (``step`` > ``schedule`` / ``flush`` / ``decode``), recorded by the
    ``LLMEngine.step`` instrumentation. Nesting is positional: a span
    opened while another is live gets ``depth = parent.depth + 1``.
  * **Request lifecycle events** — instants on a request's timeline
    (``arrival -> admitted -> first_token -> ... -> finish``, with
    ``preempt`` / ``resume`` in between and one ``tokens`` event per
    streamed emission). These give *measured* TTFT and inter-token
    latencies — the numbers ``SchedulerStats.modeled_tok_s`` only
    predicts — via :meth:`Tracer.request_latencies`.

Export is Chrome ``trace_event`` JSON (:meth:`Tracer.to_chrome_trace`),
loadable in Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:
engine spans on one track, each request as an async (``b``/``e``) slice
with its lifecycle instants riding on it.

:class:`NullTracer` is the disabled path: ``span()`` returns one shared
no-op context manager and the event methods do nothing, so a disabled
engine records no span objects per step.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional, Tuple

__all__ = ["NULL_SPAN", "NullTracer", "SpanRecord", "Tracer"]

#: Request lifecycle event names, in their only legal order of first
#: occurrence (``preempt``/``resume``/``tokens`` may repeat).
ARRIVAL = "arrival"
ADMITTED = "admitted"
RESUME = "resume"
PREEMPT = "preempt"
FIRST_TOKEN = "first_token"
TOKENS = "tokens"
FINISH = "finish"


@dataclasses.dataclass
class SpanRecord:
    """One closed span: ``[t0, t1)`` seconds on the tracer's clock."""

    name: str
    t0: float
    t1: float
    depth: int
    args: Dict

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _Span:
    """Context manager recording one span; created per ``span()`` call
    (only when tracing is enabled — the null path shares one no-op)."""

    __slots__ = ("_tracer", "name", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, args: Dict):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self):
        self._depth = len(self._tracer._stack)
        self._tracer._stack.append(self)
        self._t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc):
        t1 = self._tracer._clock()
        self._tracer._stack.pop()
        self._tracer.spans.append(
            SpanRecord(self.name, self._t0, t1, self._depth, self.args)
        )
        return False


class Tracer:
    """Span + lifecycle recorder with a Chrome ``trace_event`` exporter."""

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self._clock = clock
        self._stack: List[_Span] = []
        self.spans: List[SpanRecord] = []
        #: uid -> [(event, t, args)] in record order.
        self.requests: Dict[int, List[Tuple[str, float, Dict]]] = {}
        #: free-form instants outside any request ((name, t, args)).
        self.instants: List[Tuple[str, float, Dict]] = []
        self.t_start = clock()

    # -- recording ---------------------------------------------------------

    def span(self, name: str, **args) -> _Span:
        """Context manager timing one phase; nests positionally."""
        return _Span(self, name, args)

    def instant(self, name: str, **args) -> None:
        self.instants.append((name, self._clock(), args))

    def request_event(self, uid: int, event: str, **args) -> None:
        """Record one lifecycle instant for request ``uid``."""
        self.requests.setdefault(int(uid), []).append(
            (event, self._clock(), args)
        )

    def reset(self) -> None:
        """Drop recorded spans/events (a load harness resets after
        warmup); open spans and the clock origin survive."""
        self.spans.clear()
        self.requests.clear()
        self.instants.clear()
        self.t_start = self._clock()

    # -- derived latencies -------------------------------------------------

    def request_lifecycle(self, uid: int) -> List[Tuple[str, float, Dict]]:
        return list(self.requests.get(int(uid), ()))

    def request_latencies(self) -> Dict[int, Dict[str, object]]:
        """Measured per-request latencies from the lifecycle stream.

        Per uid: ``ttft`` (arrival -> first streamed token), ``e2e``
        (arrival -> finish), ``queue`` (arrival -> first admission), and
        ``itl`` — one interval per generated token after the first. A
        ``tokens`` emission carrying ``n`` tokens ``dt`` after the
        previous emission contributes ``n`` intervals of ``dt / n`` (the
        tick amortizes over the tokens it produced), so percentiles are
        per *token*, not per step. Requests missing an event (still
        running, never admitted) report ``None`` for the latencies that
        need it.
        """
        out: Dict[int, Dict[str, object]] = {}
        for uid, events in self.requests.items():
            first = {}
            for name, t, args in events:
                first.setdefault(name, t)
            arrival = first.get(ARRIVAL)
            ft = first.get(FIRST_TOKEN)
            fin = first.get(FINISH)
            adm = first.get(ADMITTED, first.get(RESUME))
            itl: List[float] = []
            prev = None
            for name, t, args in events:
                if name != TOKENS:
                    continue
                n = max(int(args.get("n", 1)), 1)
                if prev is not None:
                    itl.extend([(t - prev) / n] * n)
                elif n > 1:
                    # The first emission's extra tokens (beyond the very
                    # first token) still cost inter-token time ~0 within
                    # the tick; count them so token totals reconcile.
                    itl.extend([0.0] * (n - 1))
                prev = t
            def delta(a, b):
                # `is not None`, not truthiness: t == 0.0 is a real time.
                return (a - b) if (a is not None and b is not None) else None

            out[uid] = {
                "ttft": delta(ft, arrival),
                "e2e": delta(fin, arrival),
                "queue": delta(adm, arrival),
                "itl": itl,
                "preemptions": sum(1 for n, _, _ in events if n == PREEMPT),
            }
        return out

    # -- Chrome trace_event export ----------------------------------------

    def to_chrome_trace(self) -> Dict:
        """Chrome ``trace_event`` JSON (the dict; ``json.dump`` it or use
        :meth:`write_chrome_trace`). Engine spans are complete (``X``)
        events on tid 0; each request is an async ``b``/``e`` pair with
        its lifecycle instants, on its own tid so Perfetto lays requests
        out as parallel tracks."""
        base = self.t_start
        us = lambda t: round((t - base) * 1e6, 3)  # noqa: E731
        events: List[Dict] = [{
            "name": "process_name", "ph": "M", "pid": 1,
            "args": {"name": "repro.serving.LLMEngine"},
        }, {
            "name": "thread_name", "ph": "M", "pid": 1, "tid": 0,
            "args": {"name": "engine loop"},
        }]
        for s in self.spans:
            events.append({
                "name": s.name, "cat": "engine", "ph": "X",
                "ts": us(s.t0), "dur": round(s.duration * 1e6, 3),
                "pid": 1, "tid": 0, "args": s.args,
            })
        for name, t, args in self.instants:
            events.append({
                "name": name, "cat": "engine", "ph": "i", "s": "p",
                "ts": us(t), "pid": 1, "tid": 0, "args": args,
            })
        for uid, evs in sorted(self.requests.items()):
            tid = uid + 1
            events.append({
                "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
                "args": {"name": f"request {uid}"},
            })
            for name, t, args in evs:
                if name == ARRIVAL:
                    events.append({
                        "name": f"request {uid}", "cat": "request",
                        "ph": "b", "id": uid, "ts": us(t),
                        "pid": 1, "tid": tid, "args": args,
                    })
                elif name == FINISH:
                    events.append({
                        "name": f"request {uid}", "cat": "request",
                        "ph": "e", "id": uid, "ts": us(t),
                        "pid": 1, "tid": tid, "args": args,
                    })
                events.append({
                    "name": name, "cat": "lifecycle", "ph": "i", "s": "t",
                    "ts": us(t), "pid": 1, "tid": tid, "args": args,
                })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write_chrome_trace(self, path: str) -> str:
        """Write the Chrome trace JSON; returns the absolute path."""
        path = os.path.abspath(path)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


class _NullSpan:
    """The shared do-nothing context manager."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


#: One instance serves every disabled ``span()`` call — the "no span
#: objects allocated per step" half of the telemetry-off contract.
NULL_SPAN = _NullSpan()


class NullTracer(Tracer):
    """Disabled tracer: shared no-op span, event methods do nothing."""

    enabled = False

    def span(self, name: str, **args):
        return NULL_SPAN

    def instant(self, name: str, **args) -> None:
        pass

    def request_event(self, uid: int, event: str, **args) -> None:
        pass
