"""Model-vs-measured drift detection for the decode hot loop.

Every perf claim this repo makes flows through ``core.perf_model``'s
analytic constants (``COMBINE_LAUNCH_OVERHEAD_S``, bandwidth tiers, the
occupancy model). ROADMAP item 5(b) names the risk: nothing flags when
those constants drift from what the machine actually does. This module
is the hook that keeps them honest:

  * :class:`DriftCollector` rides inside ``LLMEngine.step`` (when
    telemetry is on) and folds each measured decode-step wall time into
    a ``(batch, context-bucket)`` cell — a bounded-memory histogram per
    cell, no per-step allocation beyond the observe.
  * :meth:`DriftCollector.report` juxtaposes each cell's measured p50 /
    mean against the model's prediction for that (batch, mean context)
    and emits a calibration table. ``ratio = measured / modeled``: ~1
    means the constants hold; a drifting ratio is the regression signal
    every future perf PR gets judged by (CI uploads the table from the
    load harness).

Interpret-mode CPU runs will show large ratios — the model prices TPU/
GPU-class HBM, not a Python interpreter — which is fine: drift detection
is about the *trend* of the ratio per cell across PRs, not its absolute
value on any one host.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple

from repro.obs.metrics import Histogram

__all__ = ["DriftCollector", "DriftReport", "NullDriftCollector",
           "context_bucket"]

#: Sub-microsecond modeled times are treated as "model says free" and
#: reported with ratio None instead of a division blow-up — the same
#: near-zero discipline as ``SchedulerStats`` (PR 7 satellite).
MIN_MODELED_S = 1e-9


def context_bucket(mean_len: float) -> int:
    """Bucket a live mean context length to the next power of two (>= 1),
    so cells stay few and stable as batches age."""
    n = max(int(mean_len), 1)
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass
class _Cell:
    """One (batch, context-bucket) calibration cell."""

    hist: Histogram
    len_sum: float = 0.0

    def mean_len(self) -> float:
        return self.len_sum / self.hist.count if self.hist.count else 0.0


class DriftCollector:
    """Measured decode-step times, bucketed by (batch, context)."""

    enabled = True

    def __init__(self):
        self._cells: Dict[Tuple[int, int], _Cell] = {}

    def record(self, batch: int, mean_len: float, seconds: float,
               ticks: int = 1) -> None:
        """Fold one measured decode launch into its cell.

        ``ticks`` is the number of scan ticks the launch fused (the
        ``steps_per_sync`` hot loop syncs the host once per N tokens):
        the wall time is amortized to ``seconds / ticks`` per tick and
        observed ``ticks`` times, so per-tick cells stay comparable
        across N and the sample count keeps meaning "decode ticks"."""
        ticks = max(int(ticks), 1)
        key = (int(batch), context_bucket(mean_len))
        cell = self._cells.get(key)
        if cell is None:
            cell = self._cells[key] = _Cell(
                Histogram(f"decode_step_b{key[0]}_ctx{key[1]}")
            )
        per_tick = seconds / ticks
        for _ in range(ticks):
            cell.hist.observe(per_tick)
            cell.len_sum += float(mean_len)

    @property
    def num_samples(self) -> int:
        return sum(c.hist.count for c in self._cells.values())

    def reset(self) -> None:
        self._cells.clear()

    def report(
        self, model_fn: Callable[[int, float], float]
    ) -> "DriftReport":
        """Calibration table: measured vs ``model_fn(batch, mean_len)``
        seconds per decode step, one row per populated cell."""
        rows: List[Dict] = []
        for (batch, ctx), cell in sorted(self._cells.items()):
            mean_len = cell.mean_len()
            modeled = float(model_fn(batch, mean_len))
            measured = cell.hist.quantile(0.5)
            rows.append({
                "batch": batch,
                "ctx_bucket": ctx,
                "mean_len": mean_len,
                "samples": cell.hist.count,
                "measured_p50_s": measured,
                "measured_mean_s": cell.hist.mean,
                "measured_p99_s": cell.hist.quantile(0.99),
                "modeled_s": modeled,
                "ratio": (measured / modeled) if modeled > MIN_MODELED_S
                         else None,
            })
        return DriftReport(rows=rows)


@dataclasses.dataclass
class DriftReport:
    """The calibration table (one row per (batch, context) cell)."""

    rows: List[Dict]

    def to_dict(self) -> Dict:
        return {"rows": self.rows}

    def worst_ratio(self) -> Optional[float]:
        """The cell furthest from the model (max measured/modeled), or
        None when no cell has a usable modeled time."""
        ratios = [r["ratio"] for r in self.rows if r["ratio"] is not None]
        return max(ratios) if ratios else None

    def render(self) -> str:
        """Fixed-width calibration table for logs/CI."""
        if not self.rows:
            return "drift: no decode samples recorded"
        cols = ("batch", "ctx", "n", "measured p50", "modeled", "ratio")
        lines = [
            "Drift: measured decode step vs perf_model prediction",
            "  ".join(f"{c:>12}" for c in cols),
        ]
        for r in self.rows:
            ratio = f"{r['ratio']:.1f}x" if r["ratio"] is not None else "n/a"
            lines.append("  ".join(f"{v:>12}" for v in (
                r["batch"], r["ctx_bucket"], r["samples"],
                f"{r['measured_p50_s'] * 1e3:.3f}ms",
                f"{r['modeled_s'] * 1e6:.2f}us", ratio,
            )))
        return "\n".join(lines)


class NullDriftCollector(DriftCollector):
    """Disabled collector: ``record`` does nothing, reports are empty."""

    enabled = False

    def record(self, batch: int, mean_len: float, seconds: float,
               ticks: int = 1) -> None:
        pass
