"""Dependency-free metrics registry: counters, gauges, histograms.

Every number this repo reported before PR 7 was *modeled* — the analytic
NUMA decode model in ``core.perf_model``. This module is the measured
half: a tiny instrument registry the serving path can update at decode
rates without touching labels, dicts, or allocation on the hot path.

Design contract (enforced by the ``obs-no-hot-loop-allocs`` lint rule):

  * **Pre-bound instruments.** ``registry.counter(name)`` /
    ``gauge(name)`` / ``histogram(name)`` are *registration* calls — run
    once at construction time, returning the instrument object. Hot-loop
    code holds the instrument and calls ``.inc()`` / ``.set()`` /
    ``.observe()``; it never looks an instrument up per step.
  * **Zero-cost when disabled.** :class:`NullRegistry` returns the same
    shared no-op singletons from every registration call, so a disabled
    engine threads real-looking instruments whose methods do nothing and
    allocates no metric objects per step.
  * **Mergeable histograms.** Fixed boundaries mean two histograms (two
    engines, two runs) merge by adding bucket counts — associative and
    order-independent, property-tested in ``tests/test_obs.py``.

Export surfaces: ``snapshot()`` (plain dicts, JSON-safe),
``render_prometheus()`` (text exposition), and
:func:`write_json_artifact` — the one artifact schema every benchmark
writes through (``benchmarks/common.save_result`` delegates here).
"""

from __future__ import annotations

import bisect
import json
import math
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "ARTIFACT_SCHEMA",
    "Counter",
    "Gauge",
    "Histogram",
    "LATENCY_BOUNDARIES",
    "MetricsRegistry",
    "NULL_COUNTER",
    "NULL_GAUGE",
    "NULL_HISTOGRAM",
    "NullRegistry",
    "write_json_artifact",
]

#: Default histogram boundaries for second-scale serving latencies:
#: ~exponential from 10us to 100s, dense around the ms-to-s band where
#: decode steps and TTFT live.
LATENCY_BOUNDARIES: Tuple[float, ...] = tuple(
    b * s
    for s in (1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0)
    for b in (1.0, 2.0, 5.0)
) + (100.0,)


class Counter:
    """Monotone counter. ``inc`` is the only mutator."""

    __slots__ = ("name", "help", "value")
    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n

    def snapshot(self) -> Dict:
        return {"type": "counter", "value": self.value}

    def reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "help", "value")
    kind = "gauge"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, n: float = 1.0) -> None:
        self.value += n

    def snapshot(self) -> Dict:
        return {"type": "gauge", "value": self.value}

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Fixed-boundary histogram with streaming percentile estimates.

    ``boundaries`` are cumulative upper edges (Prometheus ``le``
    semantics: bucket ``i`` counts observations ``v <= boundaries[i]``,
    with an implicit ``+Inf`` overflow bucket). Tracking ``min``/``max``
    alongside the counts tightens :meth:`quantile`'s interpolation at the
    distribution's edges — the first bucket interpolates from the
    observed min, the overflow bucket up to the observed max — so exact
    quantiles on in-range data are recovered to within one bucket width.

    Histograms with identical boundaries :meth:`merge` by adding counts:
    associative, commutative, and equal to observing the union stream.
    """

    __slots__ = ("name", "help", "boundaries", "counts", "sum", "count",
                 "min", "max")
    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 boundaries: Optional[Sequence[float]] = None):
        bs = tuple(float(b) for b in (boundaries or LATENCY_BOUNDARIES))
        if list(bs) != sorted(set(bs)):
            raise ValueError(
                f"histogram {name}: boundaries must be strictly increasing"
            )
        self.name = name
        self.help = help
        self.boundaries = bs
        self.counts = [0] * (len(bs) + 1)  # last slot = +Inf overflow
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf

    def observe(self, v: float) -> None:
        v = float(v)
        self.counts[bisect.bisect_left(self.boundaries, v)] += 1
        self.sum += v
        self.count += 1
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Streaming estimate of the ``q``-quantile by linear
        interpolation inside the holding bucket (clamped to the observed
        min/max, which makes single-bucket and edge cases exact)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"q must be in [0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if seen + c >= rank:
                lo = self.boundaries[i - 1] if i > 0 else self.min
                hi = self.boundaries[i] if i < len(self.boundaries) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                frac = (rank - seen) / c
                return lo + frac * (hi - lo)
            seen += c
        return self.max  # pragma: no cover - rank <= count always lands

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other`` into ``self`` (same boundaries required)."""
        if self.boundaries != other.boundaries:
            raise ValueError(
                f"cannot merge histograms {self.name} / {other.name}: "
                "boundary mismatch"
            )
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.sum += other.sum
        self.count += other.count
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)
        return self

    def snapshot(self) -> Dict:
        cum, buckets = 0, {}
        for i, b in enumerate(self.boundaries):
            cum += self.counts[i]
            buckets[repr(b)] = cum
        buckets["+Inf"] = cum + self.counts[-1]
        return {
            "type": "histogram",
            "count": self.count,
            "sum": self.sum,
            "mean": self.mean,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
            "buckets": buckets,
        }

    def reset(self) -> None:
        self.counts = [0] * (len(self.boundaries) + 1)
        self.sum = 0.0
        self.count = 0
        self.min = math.inf
        self.max = -math.inf


class MetricsRegistry:
    """Name -> instrument map with idempotent registration.

    Registering the same name twice returns the existing instrument (so
    layers can share counters without plumbing); registering it as a
    different kind is a programming error and raises.
    """

    enabled = True

    def __init__(self):
        self._instruments: "Dict[str, object]" = {}

    def _register(self, cls, name: str, help: str, **kw):
        inst = self._instruments.get(name)
        if inst is None:
            inst = cls(name, help, **kw)
            self._instruments[name] = inst
        elif not isinstance(inst, cls):
            raise TypeError(
                f"metric {name!r} already registered as {inst.kind}, "
                f"requested {cls.kind}"
            )
        return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  boundaries: Optional[Sequence[float]] = None) -> Histogram:
        return self._register(Histogram, name, help, boundaries=boundaries)

    def snapshot(self) -> Dict[str, Dict]:
        """Plain-dict view of every instrument (JSON-safe)."""
        return {n: i.snapshot() for n, i in sorted(self._instruments.items())}

    def reset(self) -> None:
        """Zero every instrument in place (instrument identity survives —
        pre-bound references stay valid, which is the point: a load
        harness resets after warmup without rebuilding the engine)."""
        for inst in self._instruments.values():
            inst.reset()

    def render_prometheus(self) -> str:
        """Prometheus text exposition (``# HELP`` / ``# TYPE`` + samples)."""
        lines: List[str] = []
        for name, inst in sorted(self._instruments.items()):
            pname = _prom_name(name)
            if inst.help:
                lines.append(f"# HELP {pname} {inst.help}")
            lines.append(f"# TYPE {pname} {inst.kind}")
            if isinstance(inst, Histogram):
                cum = 0
                for i, b in enumerate(inst.boundaries):
                    cum += inst.counts[i]
                    lines.append(f'{pname}_bucket{{le="{b:g}"}} {cum}')
                lines.append(
                    f'{pname}_bucket{{le="+Inf"}} {cum + inst.counts[-1]}'
                )
                lines.append(f"{pname}_sum {inst.sum:g}")
                lines.append(f"{pname}_count {inst.count}")
            else:
                lines.append(f"{pname} {inst.value:g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def write_json(self, path: str, extra: Optional[Dict] = None) -> str:
        """Write this registry's snapshot as a schema'd JSON artifact."""
        return write_json_artifact(
            os.path.splitext(os.path.basename(path))[0],
            payload=extra, metrics=self,
            dirpath=os.path.dirname(os.path.abspath(path)),
        )


def _prom_name(name: str) -> str:
    return "".join(c if c.isalnum() or c == "_" else "_" for c in name)


# -----------------------------------------------------------------------------
# No-op instruments: the disabled path allocates nothing per step
# -----------------------------------------------------------------------------


class _NullCounter(Counter):
    __slots__ = ()

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullGauge(Gauge):
    __slots__ = ()

    def set(self, v: float) -> None:
        pass

    def inc(self, n: float = 1.0) -> None:
        pass


class _NullHistogram(Histogram):
    __slots__ = ()

    def observe(self, v: float) -> None:
        pass


#: Shared no-op singletons: every registration on a :class:`NullRegistry`
#: returns one of these, so disabled telemetry binds real-looking
#: instruments without ever allocating per engine, let alone per step.
NULL_COUNTER = _NullCounter("null")
NULL_GAUGE = _NullGauge("null")
NULL_HISTOGRAM = _NullHistogram("null", boundaries=(1.0,))


class NullRegistry(MetricsRegistry):
    """Disabled registry: hands out the shared no-op singletons."""

    enabled = False

    def counter(self, name: str, help: str = "") -> Counter:
        return NULL_COUNTER

    def gauge(self, name: str, help: str = "") -> Gauge:
        return NULL_GAUGE

    def histogram(self, name: str, help: str = "",
                  boundaries: Optional[Sequence[float]] = None) -> Histogram:
        return NULL_HISTOGRAM

    def snapshot(self) -> Dict[str, Dict]:
        return {}

    def render_prometheus(self) -> str:
        return ""


# -----------------------------------------------------------------------------
# The one artifact schema
# -----------------------------------------------------------------------------

ARTIFACT_SCHEMA = "repro.obs/v1"

#: Default artifact root, mirroring ``benchmarks.common.ARTIFACTS``.
_DEFAULT_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "artifacts", "benchmarks"
)


def write_json_artifact(
    name: str,
    payload=None,
    *,
    metrics: Optional[MetricsRegistry] = None,
    dirpath: Optional[str] = None,
    kind: str = "benchmark",
) -> str:
    """Write ``artifacts/benchmarks/<name>.json`` in the uniform envelope.

    Every benchmark and the load harness emit through this one function,
    so downstream tooling can read any artifact without per-file schema
    knowledge: ``{"schema", "name", "kind", "created_unix", "payload",
    "metrics"}`` where ``metrics`` is a registry snapshot (empty when no
    registry is passed). Returns the absolute path written.
    """
    dirpath = os.path.abspath(dirpath or _DEFAULT_DIR)
    os.makedirs(dirpath, exist_ok=True)
    path = os.path.join(dirpath, f"{name}.json")
    doc = {
        "schema": ARTIFACT_SCHEMA,
        "name": name,
        "kind": kind,
        "created_unix": time.time(),
        "payload": payload,
        "metrics": metrics.snapshot() if metrics is not None else {},
    }
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, default=str)
    return path
