"""``repro.obs``: the serving telemetry subsystem (PR 7).

Four layers, one bundle:

  * :mod:`repro.obs.metrics` — dependency-free metrics registry
    (counters / gauges / fixed-bucket histograms with streaming
    percentiles; Prometheus text + JSON artifact export);
  * :mod:`repro.obs.tracing` — step-level spans and per-request
    lifecycle events, exported as Chrome ``trace_event`` JSON
    (Perfetto-loadable) and yielding *measured* TTFT / inter-token
    latencies;
  * :mod:`repro.obs.drift` — the model-vs-measured calibration table
    that keeps ``core.perf_model``'s analytic constants honest;
  * :class:`Telemetry` — the bundle ``LLMEngine`` threads. The default
    is :data:`NULL_TELEMETRY`: shared no-op instruments, a shared no-op
    span, a no-op drift collector — zero objects allocated per step when
    observability is off.

Usage::

    from repro.obs import Telemetry
    tel = Telemetry.create()
    engine = LLMEngine(cfg, params, telemetry=tel)
    ...
    print(tel.metrics.render_prometheus())
    tel.tracer.write_chrome_trace("artifacts/traces/serve.json")
    print(tel.drift.report(engine.drift_model_fn()).render())
"""

from __future__ import annotations

import dataclasses

from repro.obs.drift import DriftCollector, DriftReport, NullDriftCollector
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NullRegistry,
    write_json_artifact,
)
from repro.obs.tracing import NullTracer, SpanRecord, Tracer

__all__ = [
    "Counter", "DriftCollector", "DriftReport", "Gauge", "Histogram",
    "MetricsRegistry", "NULL_TELEMETRY", "NullDriftCollector",
    "NullRegistry", "NullTracer", "SpanRecord", "Telemetry", "Tracer",
    "write_json_artifact",
]


@dataclasses.dataclass(frozen=True)
class Telemetry:
    """The bundle the serving path threads: metrics + tracer + drift."""

    metrics: MetricsRegistry
    tracer: Tracer
    drift: DriftCollector

    @property
    def enabled(self) -> bool:
        return self.metrics.enabled

    @classmethod
    def create(cls) -> "Telemetry":
        """A live (recording) telemetry bundle."""
        return cls(MetricsRegistry(), Tracer(), DriftCollector())

    @classmethod
    def disabled(cls) -> "Telemetry":
        """The shared no-op bundle (:data:`NULL_TELEMETRY`)."""
        return NULL_TELEMETRY

    def reset(self) -> None:
        """Zero metrics, drop spans/events/drift samples in place —
        instrument identity survives, so pre-bound references stay live
        (a load harness resets after warmup without rebuilding)."""
        self.metrics.reset()
        self.tracer.reset()
        self.drift.reset()


#: The module-wide disabled bundle every un-instrumented engine shares.
NULL_TELEMETRY = Telemetry(NullRegistry(), NullTracer(), NullDriftCollector())
