"""Serving scheduler: admission, fairness, and preemption *policy*.

Everything the pre-PR-5 engines decided inline — who enters the batch,
in what order, who gets evicted under page pressure — lives here, behind
a backend-agnostic protocol, so the dense and paged execution backends
are pure mechanism:

  * **Admission** walks the ready queue in (effective-priority, arrival)
    order with head-of-line blocking: the first request the backend
    cannot hold ends the round (skipping it forever would starve large
    requests). Preempted requests re-enter first — their pages were taken
    from them, they do not re-queue behind new arrivals.
  * **Fairness** is priority + FCFS with aging: a request's effective
    priority grows by one per ``aging_rounds`` scheduling rounds it waits,
    so any fixed-priority stream is eventually outranked — no starvation
    (property-tested in ``tests/test_scheduler.py``).
  * **Page budget / prefix-match scoring**: before touching the backend's
    allocator, the scheduler prices the request — pages needed minus
    prefix-cache matches (``backend.quote``) plus decode headroom — and
    declines it when the budget cannot fit free + evictable capacity.
    The backend's ``try_admit`` stays authoritative (it may still return
    None), but the *decision* is policy, not mechanism.
  * **NUMA/occupancy awareness**: growing the decode batch only helps
    until the (batch x kv-head) grid covers the topology's NUMA domains
    with full waves; past that point the analytic decode model
    (``core.perf_model.estimate_dense_decode`` / ``estimate_paged_decode``
    via ``backend.decode_time_model``) shows marginal tokens/s gains
    collapsing. The scheduler computes the smallest batch whose modeled
    aggregate throughput stops improving and refuses to admit beyond it
    (``occupancy_cap``) — admission is throughput-aware, not just
    capacity-aware. The model is injectable for tests.
  * **Preemption policy**: ``choose_victim`` picks the lowest-priority,
    newest active row — the backend only executes the eviction.

``SchedulerStats`` is the observable summary ``LLMEngine.step`` keeps
up to date: tokens/s, prefix hit rate, preemptions, page occupancy.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import List, Optional, Sequence, Tuple

#: Admission verdict distinct from "does not fit": the request's prefix
#: matches pages the *current* flush is about to publish — admit it next
#: round (as an extend) instead of prefilling the shared prefix twice.
DEFERRED = object()

#: Denominators below this are "no time measured/modeled", not a rate:
#: guards every tokens/s division (a denormal decode_time_model result
#: used to print as 10^15 modeled tok/s — PR 7 satellite fix).
MIN_RATE_DENOM_S = 1e-9


def safe_rate(count: float, seconds: float) -> float:
    """``count / seconds`` with the near-zero denominator reported as
    0.0 (unknown) instead of inf/garbage."""
    return count / seconds if seconds > MIN_RATE_DENOM_S else 0.0


def default_choose_victim(candidates: Sequence[Tuple[int, int, int]],
                          protect: int = -1) -> Optional[int]:
    """The preemption rule, shared by the scheduler and standalone
    backends: among active ``(priority, submit_order, row)`` rows, evict
    the lowest priority, newest among ties; never ``protect`` (the row
    whose decode triggered the pressure)."""
    pool = [
        (prio, -order, row)
        for prio, order, row in candidates
        if row != protect
    ]
    if not pool:
        return None
    return min(pool)[2]


@dataclasses.dataclass
class SchedulerStats:
    """Serving counters surfaced by ``LLMEngine.stats()`` / ``step()``.

    The throughput fields are deliberately three *different* numbers and
    must never be conflated (PR 7 satellite):

      * ``tokens_per_s`` — tokens over total engine wall time (prefill,
        scheduling, and host bookkeeping included);
      * ``measured_tok_s`` — tokens over *decode-phase* wall time only:
        the apples-to-apples measurement for ``modeled_tok_s``;
      * ``modeled_tok_s`` — ``core.perf_model``'s analytic prediction at
        the current batch. A near-zero modeled tick reports 0.0 (unknown)
        rather than a 10^15 tok/s artifact (:func:`safe_rate`).

    ``prefix_hit_rate`` is ``None`` when the backend has no prefix cache
    at all (dense stripes) — distinct from a real 0.0 hit rate on a
    paged engine whose trace simply never shared a prefix.
    """

    kv_layout: str = "dense"
    running: int = 0
    waiting: int = 0
    completed: int = 0
    tokens_generated: int = 0
    elapsed_s: float = 0.0
    tokens_per_s: float = 0.0
    prefix_hit_rate: Optional[float] = None
    page_occupancy: float = 0.0    # used / total pages (dense: used slots)
    preemptions: int = 0
    resumed_tokens: int = 0
    prefill_launches: int = 0
    batched_prefills: int = 0
    occupancy_cap: int = 0         # scheduler's modeled max useful batch
    modeled_tok_s: float = 0.0     # perf_model tokens/s at current batch
    measured_tok_s: float = 0.0    # tokens / measured decode wall time
    decode_elapsed_s: float = 0.0  # decode-phase wall time (measured)
    steps_per_sync: int = 1        # fused decode ticks per host sync (live)
    num_devices: int = 1           # serving-mesh width (1 = single device)
    kv_dtype: str = "fp32"         # pool storage dtype (fp32/int8/fp8)
    demoted_pages: int = 0         # pages demoted device -> host tier
    promoted_pages: int = 0        # pages promoted host tier -> device
    host_bytes_resident: int = 0   # host-tier bytes currently held

    def summary(self) -> str:
        prefix = ("n/a" if self.prefix_hit_rate is None
                  else f"{self.prefix_hit_rate:.2f}")
        mesh = f" x{self.num_devices}dev" if self.num_devices > 1 else ""
        tier = ""
        if self.demoted_pages or self.promoted_pages:
            tier = (f" | tier {self.demoted_pages} demoted / "
                    f"{self.promoted_pages} promoted "
                    f"({self.host_bytes_resident} host bytes)")
        dtype = f" {self.kv_dtype}" if self.kv_dtype != "fp32" else ""
        return (
            f"[{self.kv_layout}{dtype}{mesh} N={self.steps_per_sync}] "
            f"{self.completed} done / {self.running} "
            f"running / {self.waiting} waiting | "
            f"{self.tokens_generated} tokens in {self.elapsed_s:.2f}s "
            f"({self.tokens_per_s:.1f} tok/s wall, measured decode "
            f"{self.measured_tok_s:.1f}, modeled "
            f"{self.modeled_tok_s:.0f}) | prefix hit "
            f"{prefix} | occupancy "
            f"{self.page_occupancy:.2f} (cap {self.occupancy_cap}) | "
            f"{self.preemptions} preemptions "
            f"({self.resumed_tokens} tokens resumed) | "
            f"{self.prefill_launches} prefill launches "
            f"({self.batched_prefills} batched)"
            f"{tier}"
        )


@dataclasses.dataclass
class _Waiting:
    req: object
    arrival: int
    rounds_waited: int = 0


class Scheduler:
    """Admission / fairness / preemption policy over an execution backend.

    The backend protocol (``serving.backends`` implements it; tests drive
    fakes): ``rows``, ``num_active``, ``try_admit(req, resume_tokens,
    pending_hashes) -> record | None | DEFERRED``, optional ``quote(req)
    -> (total_pages, matched_pages)`` + ``free_pages`` / ``evictable_pages``
    / ``reserve_pages`` (and ``sync_reserve_pages`` when fused multi-step
    decode grows rows between syncs) for the page budget, optional
    ``decode_time_model(batch, mean_len=...) -> seconds`` for the
    occupancy cap (batch-only models also accepted), optional
    ``prefill_time_saved(req) -> seconds`` for the admission tie-break.
    """

    def __init__(self, *, aging_rounds: int = 4, decode_time_model=None):
        if aging_rounds < 1:
            raise ValueError("aging_rounds must be >= 1")
        self.aging_rounds = aging_rounds
        self._decode_time_model = decode_time_model
        self._waiting: List[_Waiting] = []
        self._requeue: "deque[Tuple[object, List]]" = deque()
        self._arrival = 0
        # Occupancy cap memo, keyed by the live-mean-context bucket the
        # batch is currently in (None = backend exposes no live lengths).
        self._occupancy_cap: dict = {}

    # -- queue state -------------------------------------------------------

    def add(self, req) -> None:
        self._waiting.append(_Waiting(req, self._arrival))
        self._arrival += 1

    def requeue(self, req, generated: Sequence) -> None:
        """Re-enter a preempted request at the front (its generated tokens
        replay through the extend path on re-admission)."""
        self._requeue.appendleft((req, list(generated)))

    @property
    def num_waiting(self) -> int:
        return len(self._waiting) + len(self._requeue)

    def has_work(self) -> bool:
        return bool(self._waiting or self._requeue)

    # -- policy ------------------------------------------------------------

    def _effective_priority(self, w: _Waiting) -> int:
        return w.req.priority + w.rounds_waited // self.aging_rounds

    def _prefill_savings(self, backend, req) -> float:
        """Modeled prefill seconds saved by admitting ``req`` now (prefix
        reuse about to be exploited). Zero for backends without the hook
        (dense slots, test fakes) so the FCFS order is unchanged there."""
        saved = getattr(backend, "prefill_time_saved", None)
        return float(saved(req)) if saved is not None else 0.0

    def _ranked(self, backend=None) -> List[_Waiting]:
        """(effective-priority, modeled-prefill-savings, arrival) order:
        within a priority class the candidate whose admission saves the
        most modeled prefill time (largest live prefix-cache hit) goes
        first; arrival breaks the remaining ties (FCFS)."""
        return sorted(
            self._waiting,
            key=lambda w: (
                -self._effective_priority(w),
                -(self._prefill_savings(backend, w.req)
                  if backend is not None else 0.0),
                w.arrival,
            ),
        )

    def page_budget_ok(self, backend, req) -> bool:
        """Price an admission before touching the allocator: fresh pages
        (prefix-cache matches deducted) plus decode headroom must fit the
        backend's free + evictable capacity. Backends without a page pool
        (dense slots) always pass — their row check is in try_admit."""
        quote = getattr(backend, "quote", None)
        if quote is None:
            return True
        total, matched = quote(req)
        fresh = total - matched
        budget = backend.free_pages + backend.evictable_pages
        # Fused multi-step decode grows every active row by up to N tokens
        # between host syncs; ``sync_reserve_pages`` prices that headroom
        # (it degenerates to ``reserve_pages`` at N == 1).
        reserve = getattr(backend, "sync_reserve_pages", None)
        if reserve is None:
            reserve = getattr(backend, "reserve_pages", 0)
        return fresh + reserve <= budget

    @staticmethod
    def _live_mean_len(backend) -> Optional[float]:
        """Mean context length over the backend's live rows, or None when
        the backend exposes no live lengths (dense fakes, empty batch)."""
        lengths = getattr(backend, "lengths", None)
        active = getattr(backend, "active", None)
        if lengths is None or active is None:
            return None
        try:
            live = lengths[active]
        except Exception:
            return None
        if getattr(live, "size", 0) == 0:
            return None
        return float(live.mean())

    def occupancy_cap(self, backend) -> int:
        """Largest decode batch before modeled aggregate tokens/s starts
        *declining* — the NUMA-occupancy point past which another row
        costs more (tail-domain contention, combine overhead) than its
        token is worth. A bandwidth-bound linear model (time ~ batch)
        keeps tokens/s flat, so the cap stays at ``backend.rows`` — the
        gate only binds when the model says occupancy actually hurts.

        Re-priced as the batch *ages*: the sweep is evaluated at the live
        mean sequence length (bucketed to powers of two so the memo stays
        small), not the admission-time length — a batch that has grown
        long contexts has a different occupancy knee than a fresh one.
        Backends without live lengths (or models that only take ``batch``)
        fall back to the model's own default shape; backends without any
        model fall back to their row count."""
        from repro.obs.drift import context_bucket

        mean_len = self._live_mean_len(backend)
        bucket = None if mean_len is None else context_bucket(mean_len)
        cached = self._occupancy_cap.get(bucket)
        if cached is not None:
            return cached
        model = self._decode_time_model or getattr(
            backend, "decode_time_model", None
        )
        cap = backend.rows
        if model is not None:
            best = 0.0
            for b in range(1, backend.rows + 1):
                if bucket is None:
                    t = model(b)
                else:
                    try:
                        t = model(b, mean_len=float(bucket))
                    except TypeError:  # injected batch-only test models
                        t = model(b)
                tok_s = b / t if t > 0 else float("inf")
                if tok_s < best * (1.0 - 1e-9):
                    cap = b - 1
                    break
                best = max(best, tok_s)
        self._occupancy_cap[bucket] = max(cap, 1)
        return self._occupancy_cap[bucket]

    def _admission_ok(self, backend, req) -> bool:
        if backend.num_active >= self.occupancy_cap(backend):
            return False
        return self.page_budget_ok(backend, req)

    def schedule(self, backend, records: List) -> List:
        """One admission round: drain preempted work first, then the ready
        queue in (effective-priority, arrival) order, head-of-line
        blocking, stopping at the occupancy cap. Admission *records* are
        appended to ``records`` (caller-owned so a mid-round backend error
        still leaves the already-claimed rows visible for flushing) and
        must be flushed by the caller before the next decode tick."""
        pending = set()

        def take(rec):
            records.append(rec)
            pending.update(rec.get("pending_publish", ()))

        while self._requeue:
            req, toks = self._requeue[0]
            if not self._admission_ok(backend, req):
                break
            try:
                rec = backend.try_admit(
                    req, resume_tokens=toks, pending_hashes=pending
                )
            except ValueError:
                # Poison request: eject it so one bad entry cannot wedge
                # the queue head forever, then surface the error.
                self._requeue.popleft()
                raise
            if rec is None or rec is DEFERRED:
                break
            self._requeue.popleft()
            take(rec)
        if not self._requeue:
            for w in self._ranked(backend):
                if not self._admission_ok(backend, w.req):
                    break
                try:
                    rec = backend.try_admit(w.req, pending_hashes=pending)
                except ValueError:
                    self._waiting.remove(w)
                    raise
                if rec is None or rec is DEFERRED:
                    break
                self._waiting.remove(w)
                take(rec)
        for w in self._waiting:
            w.rounds_waited += 1
        return records

    def choose_victim(
        self, candidates: Sequence[Tuple[int, int, int]], protect: int = -1
    ) -> Optional[int]:
        """Preemption policy (see :func:`default_choose_victim`)."""
        return default_choose_victim(candidates, protect)

    def choose_steps_per_sync(self, backend) -> int:
        """Adaptive fused-decode depth (ROADMAP 3's remaining half): pick
        the smallest power-of-two N whose amortized host-sync overhead
        drops under 10% of the *live batch's* modeled decode tick
        (``perf_model.choose_steps_per_sync``). A deep batch with long
        contexts has slow ticks — N stays small and preemption stays
        responsive; a shallow batch with fast ticks is host-bound — N
        grows until the sync cost amortizes. Backends without a decode
        model keep the engine's current N."""
        from repro.core import perf_model

        model = self._decode_time_model or getattr(
            backend, "decode_time_model", None
        )
        if model is None:
            return max(int(getattr(backend, "steps_per_sync", 1)), 1)
        batch = max(backend.num_active, 1)
        mean_len = self._live_mean_len(backend)
        try:
            tick = (model(batch) if mean_len is None
                    else model(batch, mean_len=mean_len))
        except TypeError:  # injected batch-only test models
            tick = model(batch)
        return perf_model.choose_steps_per_sync(decode_tick_s=float(tick))
