"""Serving package: the ``LLMEngine`` facade and its layers.

Public API (PR 5): ``LLMEngine`` + ``SamplingParams`` / ``Request`` /
``RequestOutput`` / ``SchedulerStats``. ``serving.scheduler`` owns
admission/fairness/preemption policy, ``serving.backends`` the dense and
paged cache mechanism, ``serving.sampling`` the on-device batched
sampler. ``ServingEngine`` / ``PagedServingEngine`` are deprecated shims.
"""

from repro.serving.engine import (
    LLMEngine,
    PagedServingEngine,
    Request,
    RequestOutput,
    Result,
    SamplingParams,
    ServingEngine,
)
from repro.serving.scheduler import Scheduler, SchedulerStats

__all__ = [
    "LLMEngine", "Request", "RequestOutput", "Result", "SamplingParams",
    "Scheduler", "SchedulerStats", "ServingEngine", "PagedServingEngine",
]
