"""Serving request/response types: the public surface of the LLMEngine.

``SamplingParams`` carries everything that varies per request at decode
time (temperature / top-k / top-p / stop tokens / token budget / seed);
``Request`` binds a prompt to its params and scheduling priority; and
``RequestOutput`` is the incremental unit ``LLMEngine.step`` streams back
— the tokens appended *this* step plus the accumulated output and, once a
request terminates, its ``finish_reason``.

``Request`` also accepts the pre-PR-5 keyword surface (``max_new_tokens``,
``eos_id``, ``temperature``) so the deprecated ``ServingEngine`` /
``PagedServingEngine`` shims stay drop-in: those keywords build the
equivalent ``SamplingParams``.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

#: ``RequestOutput.finish_reason`` values.
FINISH_STOP = "stop"       # a stop token was sampled (it is included)
FINISH_LENGTH = "length"   # max_tokens generated


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decode policy, applied on device by ``serving.sampling``.

    ``temperature == 0`` is exact greedy (bitwise ``argmax``, no RNG).
    ``top_k == 0`` / ``top_p == 1.0`` disable those filters. ``seed`` keys
    this request's sample stream: outputs are reproducible for a given
    (params, prompt) no matter which batch rows the request shares a tick
    with, and resume after preemption continues the same stream (the
    stream position is the number of tokens generated so far). ``seed=None``
    lets the engine derive one from the request uid.
    """

    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    max_tokens: int = 32
    stop_token_ids: Tuple[int, ...] = ()
    seed: Optional[int] = None

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 disables), got {self.top_k}")
        if not 0.0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {self.max_tokens}")
        object.__setattr__(self, "stop_token_ids",
                           tuple(int(t) for t in self.stop_token_ids))


class Request:
    """One generation request: ``uid`` + prompt + sampling + priority.

    Either pass ``sampling=SamplingParams(...)`` or the legacy keywords
    (``max_new_tokens`` / ``eos_id`` / ``temperature`` — the pre-facade
    ``Request`` fields), which are converted; mixing both is an error.
    Higher ``priority`` is admitted sooner and survives preemption longer.
    """

    __slots__ = ("uid", "prompt", "sampling", "priority", "_hash_cache")

    def __init__(
        self,
        uid: int,
        prompt: np.ndarray,
        sampling: Optional[SamplingParams] = None,
        priority: int = 0,
        *,
        max_new_tokens: Optional[int] = None,
        eos_id: Optional[int] = None,
        temperature: Optional[float] = None,
        seed: Optional[int] = None,
    ):
        legacy = (max_new_tokens is not None or eos_id is not None
                  or temperature is not None or seed is not None)
        if sampling is not None and legacy:
            raise ValueError(
                "pass either sampling=SamplingParams(...) or the legacy "
                "max_new_tokens/eos_id/temperature keywords, not both"
            )
        if sampling is None:
            sampling = SamplingParams(
                temperature=0.0 if temperature is None else temperature,
                max_tokens=32 if max_new_tokens is None else max_new_tokens,
                stop_token_ids=() if eos_id is None else (int(eos_id),),
                seed=seed,
            )
        self.uid = int(uid)
        self.prompt = np.asarray(prompt)
        self.sampling = sampling
        self.priority = int(priority)
        self._hash_cache = {}

    def page_hashes(self, page_size: int):
        """The prompt's chained page hashes (``cache.prefix``), memoized:
        the scheduler prices prefix matches every round a request waits,
        so the O(prompt) hash pass must not repeat per tick. The prompt
        is treated as immutable after construction."""
        if page_size not in self._hash_cache:
            from repro.cache.prefix import page_hashes

            self._hash_cache[page_size] = page_hashes(self.prompt, page_size)
        return self._hash_cache[page_size]

    # Legacy field surface (the backends' admission math and the deprecated
    # shims read these).
    @property
    def max_new_tokens(self) -> int:
        return self.sampling.max_tokens

    @property
    def eos_id(self) -> Optional[int]:
        ids = self.sampling.stop_token_ids
        return ids[0] if ids else None

    @property
    def temperature(self) -> float:
        return self.sampling.temperature

    def clone(self) -> "Request":
        return Request(self.uid, self.prompt.copy(), self.sampling,
                       self.priority)

    def __repr__(self):
        return (f"Request(uid={self.uid}, prompt_len={len(self.prompt)}, "
                f"sampling={self.sampling}, priority={self.priority})")


@dataclasses.dataclass
class RequestOutput:
    """One streamed increment of a request's generation.

    ``new_tokens`` holds only the tokens appended since the previous
    emission for this request (replayed tokens after a preemption resume
    are *not* re-streamed); ``tokens`` is the full accumulated output.
    ``finish_reason`` is ``None`` while decoding, else ``FINISH_STOP`` /
    ``FINISH_LENGTH``. ``text`` is the detokenized form of ``new_tokens``
    when the engine was built with a ``detokenizer`` hook, else ``None``.
    """

    uid: int
    prompt_len: int
    new_tokens: List
    tokens: List
    finished: bool = False
    finish_reason: Optional[str] = None
    text: Optional[str] = None
