"""Batched serving engine: continuous batching over jitted prefill/decode.

Slot-based continuous batching (vLLM-style control plane, dense KV cache):
  * fixed ``num_slots`` concurrent sequences, each owning a cache stripe,
  * new requests prefill into free slots (prefill is jitted per bucketed
    prompt length to bound compilation),
  * one fused decode step advances every active slot each tick; finished
    sequences (EOS / max_tokens) free their slot immediately,
  * deterministic greedy or temperature sampling.

The decode path is the paper-relevant one: ``kernels.decode_attention``
fetches each KV head once per (batch, kv-head) grid cell — the ACC insight
applied to serving. The engine is mesh-transparent: pass sharded caches and
jitted fns and it drives the distributed case identically.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kernel_ops
from repro.models import transformer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) or (S, K)
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List
    prompt_len: int


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 8,
        cache_len: int = 2048,
        prompt_buckets=(128, 512, 2048),
        rng_seed: int = 0,
        mapping: Optional[str] = None,
    ):
        # ``mapping`` overrides the config's kernel-schedule policy for this
        # engine: "auto" (resolve_mapping per shape) or a PAPER_MAPPINGS name.
        if mapping is not None and mapping != cfg.mapping_name:
            cfg = dataclasses.replace(cfg, mapping_name=mapping)
        self.cfg = cfg
        self.params = params
        if cfg.mapping_name != "auto":
            # Fail fast on a bad pinned name (otherwise surfaces mid-trace).
            from repro.kernels.flash_attention import PAPER_MAPPINGS

            PAPER_MAPPINGS[cfg.mapping_name]
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.prompt_buckets = tuple(b for b in prompt_buckets if b <= cache_len)
        self.caches = transformer.init_caches(
            params, cfg, num_slots, cache_len,
            image_len=cfg.vision_tokens or 0,
        )
        self.lengths = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_out: List[List] = [[] for _ in range(num_slots)]
        self.results: List[Result] = []
        self.rng = np.random.default_rng(rng_seed)

        self._decode = jax.jit(
            lambda params, tok, caches, lengths: transformer.decode_step(
                params, cfg, tok, caches, lengths
            )
        )
        self._prefill = {}

    # ------------------------------------------------------------------

    @property
    def mapping(self):
        """The engine's advertised kernel schedule (stats, capacity
        planning): the pinned paper mapping, or — under "auto" — what
        resolve_mapping picks for the steady-state prefill shape (all
        ``num_slots`` stripes attending ``cache_len`` keys). Resolved
        lazily; the attention layers still re-resolve per traced shape."""
        if self.cfg.mapping_name != "auto":
            from repro.kernels.flash_attention import PAPER_MAPPINGS

            return PAPER_MAPPINGS[self.cfg.mapping_name]
        return kernel_ops.resolve_mapping(
            (self.num_slots, self.cfg.n_heads, self.cfg.n_kv_heads,
             self.cache_len, self.cache_len, self.cfg.head_dim),
            dtype_bytes=jnp.dtype(self.cfg.compute_dtype).itemsize,
        )

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill:
            cfg = self.cfg

            def f(params, tokens, last_positions):
                return transformer.prefill(
                    params, cfg, tokens, cache_len=self.cache_len,
                    last_positions=last_positions,
                )

            self._prefill[bucket] = jax.jit(f)
        return self._prefill[bucket]

    def _bucket_for(self, n: int) -> int:
        for b in self.prompt_buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds buckets {self.prompt_buckets}")

    def _write_slot_cache(self, slot: int, new_caches):
        """Copy a single-sequence prefilled cache into the slot stripe.

        Cache leaves carry batch at axis 1 for scanned stacks
        ((n_periods, B, ...)) and axis 0 for remainder layers.
        """

        def assign(dst, src):
            return dst.at[:, slot : slot + 1].set(src.astype(dst.dtype))

        def assign_rem(dst, src):
            return dst.at[slot : slot + 1].set(src.astype(dst.dtype))

        self.caches = {
            "scanned": jax.tree.map(assign, self.caches["scanned"], new_caches["scanned"]),
            "rem": jax.tree.map(assign_rem, self.caches["rem"], new_caches["rem"]),
        }

    def submit(self, req: Request) -> bool:
        """Prefill a request into a free slot; False if engine is full."""
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            return False
        slot = int(free[0])
        n = len(req.prompt)
        bucket = self._bucket_for(n)
        tok = np.asarray(req.prompt)
        pad_width = [(0, bucket - n)] + [(0, 0)] * (tok.ndim - 1)
        padded = np.pad(tok, pad_width)[None]  # (1, bucket[, K])
        logits, caches1 = self._prefill_fn(bucket)(
            self.params, jnp.asarray(padded), jnp.asarray([n - 1], jnp.int32)
        )
        self._write_slot_cache(slot, caches1)
        self.lengths[slot] = n
        self.active[slot] = True
        self.slot_req[slot] = req
        self.slot_out[slot] = []
        first = self._sample_host(np.asarray(logits)[0], req)
        self._pending_first = getattr(self, "_pending_first", {})
        self._pending_first[slot] = first
        return True

    def _sample_host(self, logits: np.ndarray, req: Request):
        if req.temperature <= 0:
            return np.argmax(logits, axis=-1)
        p = np.exp((logits - logits.max(-1, keepdims=True)) / req.temperature)
        p /= p.sum(-1, keepdims=True)
        if logits.ndim == 1:
            return self.rng.choice(len(p), p=p)
        return np.array([self.rng.choice(p.shape[-1], p=row) for row in p])

    def step(self):
        """One decode tick for all active slots."""
        if not self.active.any():
            return
        pend = getattr(self, "_pending_first", {})
        tok = np.zeros(
            (self.num_slots,) + (() if self.cfg.num_codebooks == 1 else (self.cfg.num_codebooks,)),
            np.int32,
        )
        for slot in range(self.num_slots):
            if not self.active[slot]:
                continue
            if slot in pend:
                nxt = pend.pop(slot)
            else:
                nxt = self.slot_out[slot][-1]
            tok[slot] = nxt
        self.lengths = self.lengths + self.active.astype(np.int32)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches, jnp.asarray(self.lengths)
        )
        logits = np.asarray(logits)
        for slot in range(self.num_slots):
            if not self.active[slot]:
                continue
            req = self.slot_req[slot]
            self.slot_out[slot].append(tok[slot].copy())
            nxt = self._sample_host(logits[slot], req)
            done = len(self.slot_out[slot]) >= req.max_new_tokens
            if req.eos_id is not None and np.ndim(nxt) == 0 and int(nxt) == req.eos_id:
                done = True
                if len(self.slot_out[slot]) < req.max_new_tokens:
                    self.slot_out[slot].append(np.asarray(nxt))  # include EOS
            if done:
                self.results.append(
                    Result(uid=req.uid, tokens=list(self.slot_out[slot]),
                           prompt_len=len(req.prompt))
                )
                self.active[slot] = False
                self.slot_req[slot] = None
            else:
                self._pending_first[slot] = nxt

    def run(self, requests: List[Request]) -> List[Result]:
        """Drive until all requests complete (continuous batching)."""
        queue = list(requests)
        while queue or self.active.any():
            while queue and self.submit(queue[0]):
                queue.pop(0)
            self.step()
        return self.results
