"""Batched serving engines: continuous batching over jitted prefill/decode.

Two control planes over the same model stack:

``ServingEngine`` — slot-based continuous batching, dense KV cache:
  * fixed ``num_slots`` concurrent sequences, each owning a cache stripe,
  * new requests prefill into free slots (prefill is jitted per bucketed
    prompt length to bound compilation),
  * one fused decode step advances every active slot each tick; finished
    sequences (EOS / max_tokens) free their slot immediately,
  * deterministic greedy or temperature sampling.

``PagedServingEngine`` — the serving-scale control plane (PR 2): KV lives
in a pool of fixed-size pages (``cache.pool``), so
  * admission is by free-page count, not slot count: a request enters when
    its prompt's pages (minus any prefix-cache reuse) fit the pool,
  * decode appends per-token: a sequence grows one page at a time instead
    of reserving a ``cache_len`` stripe up front,
  * common prefixes are prefilled once: ``cache.prefix`` hash-chains full
    pages, and later requests reuse the physical pages and prefill only
    their tail — the **extend phase**: the paged prefill kernel reads the
    prefix K/V straight from the page table (no gather, no dense copy),
    driven by one engine-resolved ``AttentionPlan`` per (tail-bucket,
    prefix-page-bucket, rows) jit key; prefix page counts bucket to powers
    of two so compilations stay O(log smax) under diverse prefix lengths,
  * ready admissions **batch** (PR 4): ``run`` first *admits* every
    request the pool can hold (reserving rows and pages), then launches
    one tail prefill per shared jit key with the admitted rows stacked on
    the batch axis — the kernel already takes ``(B,)`` prefix/tail
    lengths, so four same-bucket admissions cost one launch instead of
    four. Outputs are bit-exact vs one-at-a-time submission (rows are
    independent); prefix pages publish at the flush, and a request whose
    prefix is about to be published by the *same* flush defers one round
    (``DEFERRED``) so it still extends off the shared pages instead of
    re-prefilling them,
  * pool exhaustion first evicts idle prefix-cache pages, then preempts
    the lowest-priority active sequence — which later **resumes**: its
    generated tokens are replayed through the same extend path instead of
    restarting the decode from scratch,
  * pages are head-major (``cache.layout.HEAD_ALIGNED``): a KV head's
    pages live in that head's domain stripe, so the paged decode kernel's
    (batch, kv-head) grid cells only touch local pages — the paper's
    WG->XCD co-location carried into serving.

All kernel scheduling flows through ``kernels.plan`` (PR 3): the engines
never thread mapping names or query offsets — they resolve
``AttentionPlan``s and hand them to ``transformer.prefill``; the model
layers resolve their own plans for the other phases. Engines are
mesh-transparent: pass sharded caches and jitted fns and they drive the
distributed case identically.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.pool import NULL_PAGE, OutOfPages, PagePool, SequencePages
from repro.cache.prefix import PrefixCache, page_hashes
from repro.configs.base import ModelConfig
from repro.kernels import plan as plan_lib
from repro.models import transformer


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # (S,) or (S, K)
    max_new_tokens: int = 32
    eos_id: Optional[int] = None
    temperature: float = 0.0
    priority: int = 0             # higher survives preemption longer


@dataclasses.dataclass
class Result:
    uid: int
    tokens: List
    prompt_len: int


class ServingEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_slots: int = 8,
        cache_len: int = 2048,
        prompt_buckets=(128, 512, 2048),
        rng_seed: int = 0,
        mapping: Optional[str] = None,
    ):
        # ``mapping`` overrides the config's kernel-schedule policy for this
        # engine: "auto" (plan-resolved per shape) or a paper mapping name.
        # ``with_mapping`` validates a pinned name at construction (fail
        # fast) instead of mid-trace.
        cfg = plan_lib.with_mapping(cfg, mapping)
        self.cfg = cfg
        self.params = params
        self.num_slots = num_slots
        self.cache_len = cache_len
        self.prompt_buckets = tuple(b for b in prompt_buckets if b <= cache_len)
        self.caches = transformer.init_caches(
            params, cfg, num_slots, cache_len,
            image_len=cfg.vision_tokens or 0,
        )
        self.lengths = np.zeros((num_slots,), np.int32)
        self.active = np.zeros((num_slots,), bool)
        self.slot_req: List[Optional[Request]] = [None] * num_slots
        self.slot_out: List[List] = [[] for _ in range(num_slots)]
        self.results: List[Result] = []
        self.rng = np.random.default_rng(rng_seed)
        self._pending_first: Dict[int, np.ndarray] = {}

        self._decode = jax.jit(
            lambda params, tok, caches, lengths: transformer.decode_step(
                params, cfg, tok, caches, lengths
            )
        )
        self._prefill = {}

    # ------------------------------------------------------------------

    @property
    def mapping(self):
        """The engine's advertised kernel schedule (stats, capacity
        planning): what the plan layer resolves for the steady-state
        prefill shape (all ``num_slots`` stripes attending ``cache_len``
        keys) under the config's policy — a pinned paper mapping passes
        through unchanged. Resolved lazily; the attention layers still
        re-resolve per traced shape."""
        return plan_lib.plan_for_config(
            self.cfg,
            (self.num_slots, self.cfg.n_heads, self.cfg.n_kv_heads,
             self.cache_len, self.cache_len, self.cfg.head_dim),
            phase=plan_lib.PREFILL,
        ).mapping

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill:
            cfg = self.cfg

            def f(params, tokens, last_positions):
                return transformer.prefill(
                    params, cfg, tokens, cache_len=self.cache_len,
                    last_positions=last_positions,
                )

            self._prefill[bucket] = jax.jit(f)
        return self._prefill[bucket]

    def _bucket_for(self, n: int) -> int:
        for b in self.prompt_buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt length {n} exceeds buckets {self.prompt_buckets}")

    def _write_slot_cache(self, slot: int, new_caches):
        """Copy a single-sequence prefilled cache into the slot stripe.

        Cache leaves carry batch at axis 1 for scanned stacks
        ((n_periods, B, ...)) and axis 0 for remainder layers.
        """

        def assign(dst, src):
            return dst.at[:, slot : slot + 1].set(src.astype(dst.dtype))

        def assign_rem(dst, src):
            return dst.at[slot : slot + 1].set(src.astype(dst.dtype))

        self.caches = {
            "scanned": jax.tree.map(assign, self.caches["scanned"], new_caches["scanned"]),
            "rem": jax.tree.map(assign_rem, self.caches["rem"], new_caches["rem"]),
        }

    def submit(self, req: Request) -> bool:
        """Prefill a request into a free slot; False if engine is full."""
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            return False
        slot = int(free[0])
        n = len(req.prompt)
        bucket = self._bucket_for(n)
        tok = np.asarray(req.prompt)
        pad_width = [(0, bucket - n)] + [(0, 0)] * (tok.ndim - 1)
        padded = np.pad(tok, pad_width)[None]  # (1, bucket[, K])
        logits, caches1 = self._prefill_fn(bucket)(
            self.params, jnp.asarray(padded), jnp.asarray([n - 1], jnp.int32)
        )
        self._write_slot_cache(slot, caches1)
        self.lengths[slot] = n
        self.active[slot] = True
        self.slot_req[slot] = req
        self.slot_out[slot] = []
        first = self._sample_host(np.asarray(logits)[0], req)
        self._pending_first[slot] = first
        return True

    def _sample_host(self, logits: np.ndarray, req: Request):
        if req.temperature <= 0:
            return np.argmax(logits, axis=-1)
        p = np.exp((logits - logits.max(-1, keepdims=True)) / req.temperature)
        p /= p.sum(-1, keepdims=True)
        if logits.ndim == 1:
            return self.rng.choice(len(p), p=p)
        return np.array([self.rng.choice(p.shape[-1], p=row) for row in p])

    def step(self):
        """One decode tick for all active slots."""
        if not self.active.any():
            return
        pend = self._pending_first
        tok = np.zeros(
            (self.num_slots,) + (() if self.cfg.num_codebooks == 1 else (self.cfg.num_codebooks,)),
            np.int32,
        )
        for slot in range(self.num_slots):
            if not self.active[slot]:
                continue
            if slot in pend:
                nxt = pend.pop(slot)
            else:
                nxt = self.slot_out[slot][-1]
            tok[slot] = nxt
        self.lengths = self.lengths + self.active.astype(np.int32)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches, jnp.asarray(self.lengths)
        )
        self._advance_rows(tok, np.asarray(logits))

    def _row_request(self, row: int) -> Request:
        return self.slot_req[row]

    def _advance_rows(self, tok, logits):
        """Shared post-decode bookkeeping: append the token just decoded,
        sample the next one, terminate on EOS / max_new_tokens."""
        for row in range(len(self.active)):
            if not self.active[row]:
                continue
            req = self._row_request(row)
            self.slot_out[row].append(tok[row].copy())
            nxt = self._sample_host(logits[row], req)
            done = len(self.slot_out[row]) >= req.max_new_tokens
            if req.eos_id is not None and np.ndim(nxt) == 0 and int(nxt) == req.eos_id:
                done = True
                if len(self.slot_out[row]) < req.max_new_tokens:
                    self.slot_out[row].append(np.asarray(nxt))  # include EOS
            if done:
                self._finish(row, req)
            else:
                self._pending_first[row] = nxt

    def _finish(self, slot: int, req: Request):
        self.results.append(
            Result(uid=req.uid, tokens=list(self.slot_out[slot]),
                   prompt_len=len(req.prompt))
        )
        self.active[slot] = False
        self.slot_req[slot] = None

    def run(self, requests: List[Request]) -> List[Result]:
        """Drive until all requests complete (continuous batching)."""
        queue = deque(requests)
        while queue or self.active.any():
            while queue and self.submit(queue[0]):
                queue.popleft()
            self.step()
        return self.results


# -----------------------------------------------------------------------------
# Paged engine
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class _SeqState:
    """One active decode row of the paged engine."""

    req: Request
    pages: SequencePages
    submit_order: int


#: Admission verdict: the request's prefix matches pages a record in the
#: *current* flush is about to publish — admit it next round (as an extend)
#: instead of prefilling the shared prefix a second time.
DEFERRED = object()


class PagedServingEngine(ServingEngine):
    """Continuous batching over the paged KV-cache subsystem.

    ``max_batch`` is only the width of the fused decode step (a jit-static
    shape); *admission* is governed by the page pool — a request enters
    when its non-shared prompt pages fit the free list with ``reserve``
    pages of decode headroom. ``num_pages`` and ``page_size`` size the
    pool; a sequence may grow to ``max_pages_per_seq`` pages
    (the page-table width, also jit-static).

    Restrictions: pure self-attention stacks only (``init_paged_caches``
    enforces it) and single-codebook token streams.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_pages: int = 128,
        page_size: int = 16,
        max_batch: int = 8,
        max_pages_per_seq: int = 16,
        prompt_buckets=(32, 64, 128),
        rng_seed: int = 0,
        mapping: Optional[str] = None,
        prefix_sharing: bool = True,
        reserve_pages: int = 1,
        batch_admissions: bool = True,
    ):
        cfg = plan_lib.with_mapping(cfg, mapping)
        if cfg.num_codebooks != 1:
            raise ValueError("paged engine supports single-codebook models")
        for b in prompt_buckets:
            if b % page_size:
                raise ValueError(
                    f"prompt bucket {b} must be a multiple of page_size {page_size}"
                )
        if num_pages - 1 < max_pages_per_seq:
            # A lone max-size sequence must always be able to grow to its
            # cap (evicting idle prefix pages on the way); otherwise decode
            # hits OutOfPages with nothing to preempt.
            raise ValueError(
                f"num_pages={num_pages} (usable {num_pages - 1}) cannot hold "
                f"one max_pages_per_seq={max_pages_per_seq} sequence"
            )
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_batch = max_batch
        self.max_pages_per_seq = max_pages_per_seq
        self.cache_len = max_pages_per_seq * page_size
        self.prompt_buckets = tuple(
            b for b in prompt_buckets if b <= self.cache_len
        )
        self.reserve_pages = reserve_pages
        self.prefix_sharing = prefix_sharing
        self.batch_admissions = batch_admissions

        self.pool = PagePool(num_pages, page_size)
        self.prefix = PrefixCache(self.pool)
        self.caches = transformer.init_paged_caches(
            params, cfg, num_pages, page_size
        )
        # Per-row state. Inactive rows keep all-null page tables and
        # length 0: the decode step writes their token into the reserved
        # null page and the kernel emits zeros for them.
        self.page_table = np.zeros((max_batch, max_pages_per_seq), np.int32)
        self.lengths = np.zeros((max_batch,), np.int32)
        self.active = np.zeros((max_batch,), bool)
        self.seqs: List[Optional[_SeqState]] = [None] * max_batch
        self.slot_out: List[List] = [[] for _ in range(max_batch)]
        self.results: List[Result] = []
        self.rng = np.random.default_rng(rng_seed)
        self._pending_first: Dict[int, np.ndarray] = {}
        self._submit_counter = 0
        # Preempted work: (request, tokens already generated). On
        # re-admission the generated tokens are replayed through the extend
        # path so decode resumes mid-stream instead of starting over.
        self._requeue: "deque[Tuple[Request, List]]" = deque()
        self.stats = {"preemptions": 0, "prefix_evictions": 0,
                      "pages_reused": 0, "prompt_pages": 0, "cow_copies": 0,
                      "extend_prefills": 0, "resumed_tokens": 0,
                      "prefill_launches": 0, "batched_prefills": 0}

        self._decode = jax.jit(
            lambda params, tok, caches, lengths, pt: transformer.decode_step(
                params, cfg, tok, caches, lengths, page_table=pt
            )
        )
        self._prefill_p: Dict = {}
        self._scatter_jit = jax.jit(self._scatter_tail)
        self._copy_jit = jax.jit(self._copy_page)

    # -- jitted cache plumbing ---------------------------------------------

    @staticmethod
    def _scatter_tail(caches, tail_caches, pids):
        """Write prefilled tails' dense K/V into freshly allocated pages.

        pids: (rows, bucket/ps) destinations, one row per admitted
        sequence in the (possibly batched) prefill; entries past a tail's
        real pages are the null page (their writes are garbage sinks by
        design — with several rows the null page takes whichever write
        lands last, all equally garbage).
        """
        flat = pids.reshape(-1)

        def s(pages, dense, scanned):
            if scanned:
                npp, rows, hkv, bucket, hd = dense.shape
                ps = pages.shape[3]
                new = dense.reshape(npp, rows, hkv, bucket // ps, ps, hd)
                new = new.transpose(0, 2, 1, 3, 4, 5).reshape(
                    npp, hkv, rows * (bucket // ps), ps, hd
                )
                return pages.at[:, :, flat].set(new.astype(pages.dtype))
            rows, hkv, bucket, hd = dense.shape
            ps = pages.shape[2]
            new = dense.reshape(rows, hkv, bucket // ps, ps, hd)
            new = new.transpose(1, 0, 2, 3, 4).reshape(
                hkv, rows * (bucket // ps), ps, hd
            )
            return pages.at[:, flat].set(new.astype(pages.dtype))

        def layer(c, t, scanned):
            return {"attn": {
                "k_pages": s(c["attn"]["k_pages"], t["attn"]["k"], scanned),
                "v_pages": s(c["attn"]["v_pages"], t["attn"]["v"], scanned),
            }}

        return {
            "scanned": tuple(
                layer(c, t, True)
                for c, t in zip(caches["scanned"], tail_caches["scanned"])
            ),
            "rem": tuple(
                layer(c, t, False)
                for c, t in zip(caches["rem"], tail_caches["rem"])
            ),
        }

    @staticmethod
    def _copy_page(caches, src, dst):
        """Physical page copy (copy-on-write), every layer at once."""

        def cp(pages, scanned):
            if scanned:
                return pages.at[:, :, dst].set(pages[:, :, src])
            return pages.at[:, dst].set(pages[:, src])

        def layer(c, scanned):
            return {"attn": {
                "k_pages": cp(c["attn"]["k_pages"], scanned),
                "v_pages": cp(c["attn"]["v_pages"], scanned),
            }}

        return {
            "scanned": tuple(layer(c, True) for c in caches["scanned"]),
            "rem": tuple(layer(c, False) for c in caches["rem"]),
        }

    # -- prefill -----------------------------------------------------------

    @staticmethod
    def _prefix_page_bucket(pages: int) -> int:
        """Bucket a live prefix page count to the next power of two: the
        page-table width is a jit constant, so bucketing bounds tail-
        prefill compilations at O(log smax) under diverse prefix lengths
        (the live length stays dynamic via ``prefix_len``)."""
        if pages <= 0:
            return 0
        return 1 << (pages - 1).bit_length()

    def _prefill_paged_fn(self, bucket: int, prefix_pages: int, rows: int = 1):
        """Jitted tail prefill, keyed by (tail bucket, prefix-page bucket,
        admitted rows) — ``rows > 1`` is the batched-admission launch: the
        admitted sequences stack on the batch axis of one call.

        The nonzero-prefix variant runs the **extend phase**: one
        engine-resolved ``AttentionPlan`` per key drives the paged prefill
        kernel, which reads prefix K/V straight from the page table — the
        pool tensors ride in as arguments, never gathered to dense.
        """
        key = (bucket, prefix_pages, rows)
        if key not in self._prefill_p:
            cfg = self.cfg

            if prefix_pages == 0:
                def f(params, tokens, last_positions):
                    return transformer.prefill(
                        params, cfg, tokens, cache_len=bucket,
                        last_positions=last_positions,
                    )
            else:
                plan = plan_lib.plan_for_config(
                    cfg,
                    (rows, cfg.n_heads, cfg.n_kv_heads, bucket,
                     prefix_pages * self.page_size + bucket, cfg.head_dim),
                    phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
                    page_size=self.page_size, prefix_pages=prefix_pages,
                )

                def f(params, tokens, last_positions, caches, page_table,
                      prefix_len):
                    return transformer.prefill(
                        params, cfg, tokens, cache_len=bucket,
                        last_positions=last_positions,
                        prefix_caches=caches, page_table=page_table,
                        prefix_len=prefix_len, plan=plan,
                    )

            self._prefill_p[key] = jax.jit(f)
        return self._prefill_p[key]

    # -- admission ---------------------------------------------------------

    def _make_room(self, pages_needed: int) -> bool:
        """Free pages until ``pages_needed`` fit: evict idle prefix-cache
        pages first (pure capacity, nothing recomputes), then report
        whether the caller should preempt."""
        short = pages_needed - self.pool.free_pages
        if short > 0 and len(self.prefix):
            self.stats["prefix_evictions"] += self.prefix.evict(short)
            short = pages_needed - self.pool.free_pages
        return short <= 0

    def _reserve(self, num_tokens: int, matched) -> Optional[SequencePages]:
        """Page-table reservation for one admission attempt: pin the matched
        prefix pages (lookup takes no references, and ``_make_room``'s
        prefix eviction would otherwise be free to recycle exactly these
        pages — they look idle until the sequence increfs them), make room,
        allocate. Returns None when the pool cannot satisfy it."""
        for p in matched:
            self.pool.incref(p)
        try:
            need = self.pool.pages_needed(num_tokens) - len(matched)
            if not self._make_room(need + self.reserve_pages):
                return None
            try:
                return self.pool.allocate_sequence(
                    num_tokens, shared_prefix=matched
                )
            except OutOfPages:
                return None
        finally:
            for p in matched:
                self.pool.decref(p)

    def submit(self, req: Request, resume_tokens: Sequence = ()) -> bool:
        """Admit a request if a decode row and its pages are available.

        One-at-a-time entry point (kept for callers driving the engine by
        hand): admit, then launch its prefill immediately. ``run`` instead
        admits every ready request first and flushes the launches grouped
        by jit key (:meth:`_launch_prefills`).
        """
        rec = self._admit(req, resume_tokens)
        if rec is None:
            return False
        self._launch_prefills([rec])
        return True

    def _admit(self, req: Request, resume_tokens: Sequence = (),
               pending_hashes=()):
        """Reserve a decode row and pages for a request; no prefill yet.

        Prefix-cache lookup happens first: shared full pages are reused
        (prefilled once, by whoever computed them) and only the tail is
        prefilled — through the paged prefill kernel, which reads the
        prefix straight from its pages. Returns an admission record for
        :meth:`_launch_prefills`; None when the pool/rows cannot hold the
        request; or :data:`DEFERRED` when the request's next unmatched
        prefix page is in ``pending_hashes`` (pages a record admitted
        earlier in the *same* flush will publish) — admitting it now would
        re-prefill a prefix that is one flush away from being shareable.
        The row is claimed here (so subsequent admissions in the same
        flush see it taken); the caller must flush before the next decode
        step.

        ``resume_tokens``: tokens a preempted run of this request already
        generated. They are replayed through the same extend path (they are
        just more prompt from the cache's point of view), so decode resumes
        mid-stream instead of restarting from scratch.
        """
        free_rows = np.flatnonzero(~self.active)
        if len(free_rows) == 0:
            return None
        tok = np.asarray(req.prompt)
        if tok.ndim != 1:
            raise ValueError("paged engine expects flat token prompts")
        orig_n = len(tok)
        if len(resume_tokens):
            tok = np.concatenate(
                [tok, np.asarray([int(t) for t in resume_tokens], tok.dtype)]
            )
        n = len(tok)
        ps = self.page_size
        total_pages = self.pool.pages_needed(n)
        if total_pages > self.max_pages_per_seq:
            raise ValueError(
                f"prompt needs {total_pages} pages > "
                f"max_pages_per_seq {self.max_pages_per_seq}"
            )

        if self.pool.pages_needed(orig_n + req.max_new_tokens) > self.max_pages_per_seq:
            raise ValueError(
                f"request {req.uid}: prompt {orig_n} + max_new_tokens "
                f"{req.max_new_tokens} can outgrow max_pages_per_seq="
                f"{self.max_pages_per_seq} ({self.cache_len} tokens) "
                "mid-decode; reject at admission instead"
            )

        hashes = page_hashes(tok, ps) if self.prefix_sharing else []
        # Reuse at most (n-1)//ps pages: at least one tail token must be
        # prefilled here to produce the next-token logits.
        matched = self.prefix.lookup(hashes[: (n - 1) // ps])
        m0 = len(matched)
        if pending_hashes and m0 < (n - 1) // ps and hashes[m0] in pending_hashes:
            # The next page this prompt could share is being prefilled by a
            # record already admitted this flush: wait one round and extend
            # off the published pages instead of recomputing the prefix.
            return DEFERRED

        def fits_buckets(tail_len: int) -> bool:
            return any(tail_len <= b for b in self.prompt_buckets)

        # Validate the prefill bucket before touching the allocator (a late
        # ValueError must not leak pages).
        if not fits_buckets(n - len(matched) * ps):
            if len(resume_tokens):
                # A replay tail no bucket holds: drop replayed tokens until
                # it fits (greedy decode regenerates them exactly). The
                # prefix match for a truncated sequence is the full match
                # capped at its page count, so the fit is computable without
                # re-hashing; keep the longest replay that fits.
                m_full = len(matched)
                for keep in range(len(resume_tokens) - 1, -1, -1):
                    nk = orig_n + keep
                    mk = min(m_full, (nk - 1) // ps)
                    if fits_buckets(nk - mk * ps):
                        return self._admit(
                            req, list(resume_tokens)[:keep], pending_hashes
                        )
                # Not even the bare prompt fits (its prefix pages were
                # evicted since first admission): fall through to the
                # admission error below.
            raise ValueError(
                f"prompt tail {n - len(matched) * ps} exceeds buckets "
                f"{self.prompt_buckets}"
            )
        seq = self._reserve(n, matched)
        if seq is None and matched and fits_buckets(n):
            # Reuse blocked admission (the pinned prefix pages were the only
            # evictable capacity): fall back to prefilling from scratch so a
            # servable request is never starved by its own cached prefix.
            # Prompts only servable *through* reuse stay queued instead
            # (pages free up as sequences finish).
            matched = []
            seq = self._reserve(n, matched)
        if seq is None:
            return None
        m = len(matched)
        tail = tok[m * ps :]
        bucket = self._bucket_for(len(tail))
        self.stats["pages_reused"] += m
        self.stats["prompt_pages"] += total_pages

        # Claim the decode row now — pages and row are spoken for; the
        # prefill itself runs at flush time (_launch_prefills).
        row = int(free_rows[0])
        self.seqs[row] = _SeqState(
            req=req, pages=seq, submit_order=self._submit_counter
        )
        self._submit_counter += 1
        self.page_table[row] = NULL_PAGE
        self.page_table[row, : len(seq.pages)] = seq.pages
        self.lengths[row] = n
        self.active[row] = True
        self.slot_out[row] = list(resume_tokens)
        self.stats["resumed_tokens"] += len(resume_tokens)
        return {
            "req": req, "row": row, "seq": seq, "matched": matched,
            "tail": tail, "bucket": bucket, "n": n, "hashes": hashes,
            "mb": self._prefix_page_bucket(m) if m else 0,
        }

    def _launch_prefills(self, records) -> None:
        """Flush admitted records: one tail-prefill launch per shared
        (tail-bucket, prefix-page-bucket) jit key, admitted rows stacked on
        the batch axis — the paged prefill kernel takes per-row
        ``prefix_len`` / ``tail_len``, so rows with different live lengths
        share a launch. Rows are independent (per-row page tables, per-row
        online softmax), so outputs are bit-exact vs one launch per
        request. Prefix pages publish after each group's scatter: a record
        never reads pages whose contents this same flush still owes.
        """
        ps = self.page_size
        groups: Dict[Tuple[int, int], list] = {}
        for rec in records:
            groups.setdefault((rec["bucket"], rec["mb"]), []).append(rec)
        for (bucket, mb), grp in groups.items():
            rows = len(grp)
            padded = np.stack(
                [np.pad(r["tail"], (0, bucket - len(r["tail"]))) for r in grp]
            )
            last = jnp.asarray(
                [len(r["tail"]) - 1 for r in grp], jnp.int32
            )
            self.stats["prefill_launches"] += 1
            self.stats["batched_prefills"] += rows > 1
            if mb == 0:
                logits, tail_caches = self._prefill_paged_fn(bucket, 0, rows)(
                    self.params, jnp.asarray(padded), last
                )
            else:
                # Extend phase: each page-table row is padded to the
                # power-of-two page bucket with null pages (the kernel
                # masks them via the dynamic prefix_len), so every prefix
                # length in a bucket shares one compilation — and the pool
                # is consumed in place, no gather.
                pt = np.full((rows, mb), NULL_PAGE, np.int32)
                for i, r in enumerate(grp):
                    pt[i, : len(r["matched"])] = r["matched"]
                plens = jnp.asarray(
                    [len(r["matched"]) * ps for r in grp], jnp.int32
                )
                self.stats["extend_prefills"] += rows
                logits, tail_caches = self._prefill_paged_fn(bucket, mb, rows)(
                    self.params, jnp.asarray(padded), last, self.caches,
                    jnp.asarray(pt), plens,
                )
            # Scatter every row's tail K/V into its fresh pages (buckets
            # are page-aligned; destinations beyond a tail's real pages
            # sink into the null page).
            pids = np.full((rows, bucket // ps), NULL_PAGE, np.int32)
            for i, r in enumerate(grp):
                tail_pages = r["seq"].pages[len(r["matched"]):]
                pids[i, : len(tail_pages)] = tail_pages
            self.caches = self._scatter_jit(
                self.caches, tail_caches, jnp.asarray(pids)
            )
            logits_np = np.asarray(logits)
            for i, r in enumerate(grp):
                # Publish this prompt's full pages for later requests.
                if self.prefix_sharing:
                    nfull = r["n"] // ps
                    self.prefix.insert(
                        r["hashes"][:nfull], r["seq"].pages[:nfull]
                    )
                self._pending_first[r["row"]] = self._sample_host(
                    logits_np[i], r["req"]
                )

    # -- preemption / decode ----------------------------------------------

    def _preempt_one(self, protect: int) -> bool:
        """Evict the weakest active sequence (lowest priority, then newest)
        and requeue it with its generated-so-far tokens (replayed through
        the extend path on re-admission); never the row ``protect``."""
        victims = [
            (s.req.priority, -s.submit_order, row)
            for row, s in enumerate(self.seqs)
            if s is not None and self.active[row] and row != protect
        ]
        if not victims:
            return False
        _, _, row = min(victims)
        state = self.seqs[row]
        self.stats["preemptions"] += 1
        self.pool.release(state.pages)
        self._requeue.appendleft((state.req, list(self.slot_out[row])))
        self.active[row] = False
        self.seqs[row] = None
        self.page_table[row] = NULL_PAGE
        self.lengths[row] = 0
        self._pending_first.pop(row, None)
        self.slot_out[row] = []
        return True

    def _append_token_slot(self, row: int) -> None:
        """Reserve the next token's slot in row's page table, preempting
        others if the pool is exhausted mid-decode."""
        state = self.seqs[row]
        while True:
            try:
                _, _, cow = self.pool.append_token(state.pages)
                break
            except OutOfPages:
                if not (self._make_room(1) or self._preempt_one(row)):
                    raise OutOfPages(
                        "pool exhausted and nothing left to preempt"
                    )
        if cow is not None:
            src, dst = cow
            self.stats["cow_copies"] += 1
            # Traced page ids: one jitted copy program serves every pair.
            self.caches = self._copy_jit(
                self.caches, jnp.asarray(src, jnp.int32), jnp.asarray(dst, jnp.int32)
            )
        if state.pages.num_pages() > self.max_pages_per_seq:
            raise ValueError(
                f"sequence {state.req.uid} outgrew max_pages_per_seq="
                f"{self.max_pages_per_seq}; cap prompt+max_new_tokens at "
                f"{self.cache_len} tokens"
            )
        self.page_table[row] = NULL_PAGE
        self.page_table[row, : len(state.pages.pages)] = state.pages.pages

    def step(self):
        """One decode tick for all active rows."""
        if not self.active.any():
            return
        tok = np.zeros((self.max_batch,), np.int32)
        for row in range(self.max_batch):
            if not self.active[row]:
                continue
            if row in self._pending_first:
                nxt = self._pending_first.pop(row)
            else:
                nxt = self.slot_out[row][-1]
            tok[row] = nxt
            self._append_token_slot(row)
        self.lengths = self.lengths + self.active.astype(np.int32)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches,
            jnp.asarray(self.lengths), jnp.asarray(self.page_table),
        )
        self._advance_rows(tok, np.asarray(logits))

    def _row_request(self, row: int) -> Request:
        return self.seqs[row].req

    def _finish(self, row: int, req: Request):
        state = self.seqs[row]
        self.results.append(
            Result(uid=req.uid, tokens=list(self.slot_out[row]),
                   prompt_len=len(req.prompt))
        )
        # Pages the prefix cache references survive; the rest free now.
        self.pool.release(state.pages)
        self.active[row] = False
        self.seqs[row] = None
        self.page_table[row] = NULL_PAGE
        self.lengths[row] = 0

    def run(self, requests: List[Request]) -> List[Result]:
        """Drive until every request (including preempted ones) completes.

        With ``batch_admissions`` (the default) each scheduling round
        admits every ready request first (rows and pages reserved, in
        arrival order) and then flushes the tail prefills grouped by jit
        key — one launch per (tail-bucket, prefix-page-bucket) instead of
        one per request. ``batch_admissions=False`` keeps the legacy
        submit-one-launch-one loop (the bit-exactness oracle in tests)."""
        queue = deque(requests)
        while queue or self._requeue or self.active.any():
            if self.batch_admissions:
                records = []
                # Pages this flush will publish: a later request matching
                # one defers a round (DEFERRED) and extends off it instead
                # of re-prefilling the shared prefix.
                pending = set()

                def take(rec):
                    records.append(rec)
                    pending.update(rec["hashes"][: rec["n"] // self.page_size])

                try:
                    while self._requeue:
                        rec = self._admit(
                            self._requeue[0][0],
                            resume_tokens=self._requeue[0][1],
                            pending_hashes=pending,
                        )
                        if rec is None or rec is DEFERRED:
                            break
                        self._requeue.popleft()
                        take(rec)
                    if not self._requeue:
                        while queue:
                            rec = self._admit(queue[0], pending_hashes=pending)
                            if rec is None or rec is DEFERRED:
                                break
                            queue.popleft()
                            take(rec)
                finally:
                    # Flush even when a later _admit raises (oversized
                    # prompt, bucket overflow): rows admitted this round
                    # are already claimed and must not reach a decode step
                    # — or a caller that catches the error — unprefilled.
                    if records:
                        self._launch_prefills(records)
            else:
                while self._requeue and self.submit(
                    self._requeue[0][0], resume_tokens=self._requeue[0][1]
                ):
                    self._requeue.popleft()
                if not self._requeue:
                    while queue and self.submit(queue[0]):
                        queue.popleft()
            if not self.active.any():
                if queue or self._requeue:
                    raise OutOfPages(
                        "pool too small for any queued request; grow "
                        "num_pages or shrink prompts"
                    )
                break
            self.step()
        return self.results

    # -- introspection -----------------------------------------------------

    @property
    def mapping(self):
        """Resolved decode-shape schedule (decode & window are part of the
        plan key, so this differs from the prefill resolution)."""
        return plan_lib.plan_for_config(
            self.cfg,
            (self.max_batch, self.cfg.n_heads, self.cfg.n_kv_heads,
             1, self.cache_len, self.cfg.head_dim),
            phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED,
            page_size=self.page_size,
        ).mapping

    @property
    def kv_layout(self) -> str:
        """What the analytic model would pick for this engine's steady
        state (paged head-aligned vs interleaved vs dense stripes)."""
        live = self.lengths[self.active]
        mean_len = int(live.mean()) if live.size else self.cache_len // 2
        return plan_lib.resolve_kv_layout(
            (self.max_batch, self.cfg.n_heads, self.cfg.n_kv_heads,
             max(mean_len, 1), self.cfg.head_dim),
            capacity=self.cache_len,
            page_size=self.page_size,
            dtype_bytes=jnp.dtype(self.cfg.compute_dtype).itemsize,
        )

    def prefix_stats(self) -> Dict[str, float]:
        reused = self.stats["pages_reused"]
        total = self.stats["prompt_pages"]
        return {
            "prefix_entries": float(len(self.prefix)),
            "pages_reused": float(reused),
            "prompt_pages": float(total),
            "prefix_hit_rate": reused / total if total else 0.0,
            "preemptions": float(self.stats["preemptions"]),
            "resumed_tokens": float(self.stats["resumed_tokens"]),
            "extend_prefills": float(self.stats["extend_prefills"]),
            "prefill_launches": float(self.stats["prefill_launches"]),
            "batched_prefills": float(self.stats["batched_prefills"]),
            "cow_copies": float(self.stats["cow_copies"]),
            "free_pages": float(self.pool.free_pages),
        }
