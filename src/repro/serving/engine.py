"""``LLMEngine``: the one public serving entry point.

Pre-PR-5 the serving API was two sibling engine classes with duplicated
submit/step/run lifecycles, greedy-only host sampling, and a blocking
``run()`` as the only entry point — callers picked dense vs paged by
picking a class. The facade collapses that:

  * ``LLMEngine(cfg, params, kv_layout="auto")`` — layout is a *policy*
    resolved through the plan layer (``kernels.plan.resolve_kv_layout``,
    the paper's NUMA decode model), not a class choice; models the paged
    subsystem cannot hold (multi-codebook, SSM/hybrid, cross-attention)
    fall back to dense automatically;
  * ``add_request(...)`` / ``step() -> list[RequestOutput]`` — continuous
    batching with **streaming** outputs: each tick emits the tokens it
    appended, and terminating requests carry a ``finish_reason``;
  * ``generate(requests)`` — the blocking convenience loop over ``step``;
  * sampling is per-request (``SamplingParams``) and runs **on device**:
    one jitted batched sampler per tick (``serving.sampling``), keyed per
    request so outputs are reproducible across batch compositions and
    across preemption/resume. ``temperature=0`` is exact argmax — greedy
    outputs bit-match the pre-refactor engines;
  * admission / fairness / preemption policy lives in
    ``serving.scheduler`` (page budget, priority + FCFS aging, the
    NUMA-occupancy admission cap from ``core.perf_model``); the execution
    backends (``serving.backends``) are pure cache mechanism;
  * observability is injected (``telemetry=repro.obs.Telemetry.create()``):
    ``step()`` runs under spans (schedule / flush / decode), requests get
    lifecycle events (arrival -> admitted -> first_token -> finish, with
    preempt/resume), each decode tick's wall time feeds the
    model-vs-measured drift collector, and all instruments are pre-bound
    at construction (``obs-no-hot-loop-allocs`` lint rule). The default
    is ``repro.obs.NULL_TELEMETRY`` — shared no-op instruments, no
    span/metric objects allocated per step.

``ServingEngine`` / ``PagedServingEngine`` survive as deprecated shims
over the facade; nothing outside ``repro.serving`` may construct them
(grep-enforced in ``tests/test_serving.py``).
"""

from __future__ import annotations

import asyncio
import dataclasses
import time
import warnings
from typing import Callable, Dict, Iterable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro import compat
from repro.cache.pool import OutOfPages
from repro.configs.base import ModelConfig
from repro.kernels import plan as plan_lib
from repro.obs import NULL_TELEMETRY, Telemetry
from repro.serving import sampling as sampling_lib
from repro.serving.backends import DenseBackend, PagedBackend
from repro.serving.request import (
    FINISH_LENGTH,
    FINISH_STOP,
    Request,
    RequestOutput,
    SamplingParams,
)
from repro.serving.scheduler import (
    DEFERRED,
    Scheduler,
    SchedulerStats,
    safe_rate,
)

__all__ = [
    "LLMEngine", "Request", "RequestOutput", "SamplingParams", "Result",
    "ServingEngine", "PagedServingEngine",
]

KV_LAYOUTS = ("auto", "dense", "paged")


def _paged_supported(cfg: ModelConfig) -> bool:
    """Whether the paged subsystem can hold this model: pure self-attention
    stacks, single-codebook streams (mirrors ``init_paged_caches``)."""
    if cfg.num_codebooks != 1:
        return False
    pattern, rem = cfg.pattern_for_depth()
    return all(
        spec.kind == "attn" and not spec.cross_attn
        for spec in list(pattern) + list(rem)
    )


class LLMEngine:
    """Unified serving facade: one engine, scheduler-driven, both layouts.

    ``kv_layout="auto"`` resolves dense vs paged through the plan layer's
    analytic NUMA decode model for this engine's steady-state shape;
    ``"dense"`` / ``"paged"`` pin it. Capacity knobs: ``max_batch`` decode
    rows and a ``cache_len`` dense stripe, or ``num_pages`` x
    ``page_size`` pool with ``max_pages_per_seq`` (default
    ``cache_len // page_size``) for paged. ``prompt_buckets=None`` picks
    per-layout defaults.
    """

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        kv_layout: str = "auto",
        max_batch: int = 8,
        cache_len: int = 2048,
        prompt_buckets=None,
        num_pages: int = 128,
        page_size: int = 16,
        max_pages_per_seq: Optional[int] = None,
        prefix_sharing: bool = True,
        reserve_pages: int = 1,
        batch_prefills: bool = True,
        mapping: Optional[str] = None,
        scheduler: Optional[Scheduler] = None,
        telemetry: Optional[Telemetry] = None,
        steps_per_sync=1,
        compilation_cache_dir: Optional[str] = None,
        mesh=None,
        shard_params: bool = False,
        device_hbm_bytes=None,
        kv_dtype: str = "fp32",
        host_pool_bytes=None,
        detokenizer: Optional[Callable[[Sequence], str]] = None,
    ):
        if kv_layout not in KV_LAYOUTS:
            raise ValueError(
                f"kv_layout must be one of {KV_LAYOUTS}, got {kv_layout!r}"
            )
        # Quantized pools and the host tier are paged-subsystem features:
        # force the layout rather than silently dropping the knobs when
        # "auto" would have resolved dense.
        if (kv_dtype != "fp32" or host_pool_bytes) and kv_layout == "auto":
            kv_layout = "paged"
        if (kv_dtype != "fp32" or host_pool_bytes) and kv_layout == "dense":
            raise ValueError(
                "kv_dtype / host_pool_bytes require the paged KV layout"
            )
        # "auto": the scheduler re-picks N from the live batch's modeled
        # tick time before every sync (perf_model.choose_steps_per_sync);
        # powers of two only, so the fused launcher's jit keys stay O(log)
        # and steady-state decode never retraces.
        self._auto_steps = steps_per_sync == "auto"
        if self._auto_steps:
            steps_per_sync = 1
        elif not isinstance(steps_per_sync, int) or steps_per_sync < 1:
            raise ValueError(
                f"steps_per_sync must be a positive int or 'auto', "
                f"got {steps_per_sync!r}"
            )
        # Serving mesh: an int requests that many host devices on a 1-D
        # "model" axis (lazy import — launch depends on serving); a Mesh
        # passes through; None = single-device. The backends shard only
        # the KV caches over it (head-parallel); params are replicated
        # unless ``shard_params`` opts into tensor-parallel weights —
        # replication keeps every reduction device-local, which is what
        # makes sharded decode bit-exact vs the single-device engine.
        if isinstance(mesh, int):
            from repro.launch.mesh import make_serving_mesh

            mesh = make_serving_mesh(mesh)
        # ``mapping`` overrides the config's kernel-schedule policy for
        # this engine ("auto" or a paper schedule name); ``with_mapping``
        # validates a pinned name at construction instead of mid-trace.
        cfg = plan_lib.with_mapping(cfg, mapping)
        if mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec

            import jax

            if shard_params:
                from repro.distributed import sharding as sharding_lib

                params = jax.device_put(
                    params, sharding_lib.param_shardings(
                        mesh, jax.eval_shape(lambda p: p, params))
                )
            else:
                params = jax.device_put(
                    params, NamedSharding(mesh, PartitionSpec())
                )
        if kv_layout == "auto":
            if not _paged_supported(cfg):
                kv_layout = "dense"
            else:
                pick = plan_lib.resolve_kv_layout(
                    (max_batch, cfg.n_heads, cfg.n_kv_heads,
                     max(cache_len // 2, 1), cfg.head_dim),
                    capacity=cache_len,
                    page_size=page_size,
                    dtype_bytes=jnp.dtype(cfg.compute_dtype).itemsize,
                )
                kv_layout = "paged" if pick.startswith("paged") else "dense"
        if kv_layout == "dense":
            self.backend = DenseBackend(
                cfg, params, rows=max_batch, cache_len=cache_len,
                prompt_buckets=prompt_buckets or (128, 512, 2048),
                mesh=mesh,
            )
        else:
            self.backend = PagedBackend(
                cfg, params, num_pages=num_pages, page_size=page_size,
                rows=max_batch,
                max_pages_per_seq=(
                    max_pages_per_seq
                    # Default: a sequence may use the dense-equivalent
                    # stripe, clamped to what the pool can actually hold.
                    or max(1, min(cache_len // page_size, num_pages - 1))
                ),
                prompt_buckets=prompt_buckets or (32, 64, 128),
                prefix_sharing=prefix_sharing,
                reserve_pages=reserve_pages,
                batch_prefills=batch_prefills,
                mesh=mesh,
                device_hbm_bytes=device_hbm_bytes,
                kv_dtype=kv_dtype,
                host_pool_bytes=host_pool_bytes,
            )
        self.cfg = cfg
        self.scheduler = scheduler or Scheduler()
        self.backend.choose_victim = self.scheduler.choose_victim
        self.backend.on_preempt = self._on_preempt
        #: Decode steps fused into one jitted lax.scan per sync; the host
        #: (scheduler, output flush, telemetry) intervenes every N tokens.
        self.steps_per_sync = int(steps_per_sync)
        self.backend.steps_per_sync = self.steps_per_sync
        # Persistent compilation cache (best-effort): with the scan's jit
        # keys O(1) per engine, a warm cache means steady-state serving
        # never compiles at all — across processes, not just ticks.
        compat.enable_compilation_cache(compilation_cache_dir)

        self._pending: Dict[int, np.ndarray] = {}   # row -> next token
        self._last_ticks = 0                        # live ticks, last scan
        self._streamed: Dict[int, int] = {}         # uid -> tokens emitted
        #: uid -> buffered outputs for live stream() consumers. Only uids
        #: with an open stream() generator have an entry; everything else
        #: flows through step()/generate() unchanged.
        self._stream_q: Dict[int, List[RequestOutput]] = {}
        #: Optional token->text hook: when set, every streamed
        #: RequestOutput carries ``text`` = detokenizer(new_tokens) — the
        #: incremental piece, not the whole completion.
        self._detokenizer = detokenizer
        self._completed: List[RequestOutput] = []
        self._next_uid = 0
        self._tokens_generated = 0
        self._elapsed = 0.0
        self._decode_elapsed = 0.0
        self._first_emitted: set = set()            # uids past first token

        # Telemetry: every instrument is bound HERE, once — the decode
        # hot path only touches pre-bound objects (obs-no-hot-loop-allocs
        # lint rule). The default NULL_TELEMETRY shares module-level
        # no-op singletons, so a disabled engine allocates nothing per
        # step.
        self.telemetry = telemetry or NULL_TELEMETRY
        self._tr = self.telemetry.tracer
        self._drift = self.telemetry.drift
        m = self.telemetry.metrics
        self._m_requests = m.counter(
            "serving_requests_total", "requests accepted by add_request")
        self._m_steps = m.counter(
            "serving_steps_total", "engine ticks (step() calls)")
        self._m_tokens = m.counter(
            "serving_tokens_total", "tokens streamed to callers")
        self._m_admitted = m.counter(
            "serving_admissions_total", "admission records flushed")
        self._m_preempt = m.counter(
            "serving_preemptions_total", "rows evicted under page pressure")
        self._m_finished = m.counter(
            "serving_finished_total", "requests that reached finish")
        self._h_step = m.histogram(
            "serving_step_seconds", "one full step(): schedule+flush+decode")
        self._h_schedule = m.histogram(
            "serving_schedule_seconds", "admission-policy time per step")
        self._h_flush = m.histogram(
            "serving_flush_seconds", "prefill flush time per step")
        self._h_decode = m.histogram(
            "serving_decode_step_seconds",
            "fused decode + sample + bookkeeping per tick")
        self._g_running = m.gauge(
            "serving_running", "active decode rows")
        self._g_waiting = m.gauge(
            "serving_waiting", "queued + requeued requests")
        self._m_demotions = m.counter(
            "serving_kv_demotions_total",
            "KV pages demoted device -> host tier")
        self._m_promotions = m.counter(
            "serving_kv_promotions_total",
            "KV pages promoted host tier -> device")
        self._g_device_kv = m.gauge(
            "serving_kv_device_bytes_resident",
            "device KV pool bytes held by live pages")
        self._g_host_kv = m.gauge(
            "serving_kv_host_bytes_resident",
            "host-tier KV bytes held by demoted pages")
        # Backend tier counters are monotonic totals; the engine exports
        # deltas so telemetry resets don't double-count.
        self._tier_seen = {"demoted_pages": 0, "promoted_pages": 0}

    # -- public surface ----------------------------------------------------

    @property
    def kv_layout(self) -> str:
        return self.backend.kv_layout

    @property
    def mapping(self):
        """The plan-resolved kernel schedule for the backend's steady
        state (a pinned paper schedule passes through unchanged)."""
        return self.backend.mapping

    def add_request(
        self,
        request=None,
        *,
        prompt=None,
        sampling: Optional[SamplingParams] = None,
        uid: Optional[int] = None,
        priority: Optional[int] = None,
    ) -> int:
        """Queue one request; returns its uid. Pass a :class:`Request` or
        a raw ``prompt`` (+ optional ``sampling`` / ``priority``).
        Requests that can never be served (outgrow the cache; overflow
        every prefill bucket with prefix sharing off) are rejected
        *here*, not mid-decode."""
        if request is None:
            if prompt is None:
                raise ValueError("pass a Request or a prompt")
            if uid is None:
                uid = self._next_uid
            request = Request(uid, prompt, sampling,
                              0 if priority is None else priority)
        elif (prompt is not None or sampling is not None or uid is not None
              or priority is not None):
            raise ValueError("pass either a Request or prompt/... keywords")
        self._next_uid = max(self._next_uid, request.uid + 1)
        self.backend.validate(request)
        self.scheduler.add(request)
        self._m_requests.inc()
        self._tr.request_event(request.uid, "arrival",
                               prompt_len=len(request.prompt),
                               priority=request.priority)
        return request.uid

    def step(self, max_steps: Optional[int] = None) -> List[RequestOutput]:
        """One serving sync: admit + flush prefills, then up to
        ``max_steps`` (default: the engine's ``steps_per_sync``) fused
        decode ticks in **one jitted ``lax.scan``** over every active row,
        sampled on device with per-request params. Stop-token detection
        and per-row done masks stay on device; the host reconstructs
        outputs once, here. Returns the streamed increments — one
        :class:`RequestOutput` per request that gained tokens or finished
        this sync.

        Instrumented (when telemetry is on) as one ``step`` span holding
        ``schedule`` / ``flush`` / ``decode`` child spans; the scan's wall
        time is folded into the drift collector under its live (batch,
        mean-context) cell as one sample per live scan tick."""
        if self._auto_steps:
            # Re-pick N from the live batch depth BEFORE admission: the
            # scheduler's page-budget check prices decode headroom off
            # ``backend.steps_per_sync`` (sync_reserve_pages), so the N
            # the scan will run with is the N admission was priced at.
            self.steps_per_sync = self.scheduler.choose_steps_per_sync(
                self.backend)
            self.backend.steps_per_sync = self.steps_per_sync
        n_steps = self.steps_per_sync if max_steps is None else int(max_steps)
        if n_steps < 1:
            raise ValueError(f"max_steps must be >= 1, got {max_steps}")
        t0 = time.perf_counter()
        records: List = []
        with self._tr.span("step"):
            try:
                with self._tr.span("schedule"):
                    self.scheduler.schedule(self.backend, records)
            finally:
                self._h_schedule.observe(time.perf_counter() - t0)
                # Flush even when a late admission raises (oversized
                # prompt, bucket overflow): rows admitted this round are
                # already claimed and must not reach a decode tick — or a
                # caller that catches the error — unprefilled.
                for rec in records:
                    uid = rec["req"].uid
                    self._m_admitted.inc()
                    # A row whose output list is pre-seeded was admitted
                    # with replay tokens: that is a preemption resume.
                    resumed = bool(self.backend.out[rec["row"]])
                    self._tr.request_event(
                        uid, "resume" if resumed else "admitted",
                        row=rec["row"])
                if records:
                    tf = time.perf_counter()
                    with self._tr.span("flush", rows=len(records)):
                        self._flush(records)
                    self._h_flush.observe(time.perf_counter() - tf)
            outputs: List[RequestOutput] = []
            if self.backend.active.any():
                nb = self.backend.num_active
                live = self.backend.lengths[self.backend.active]
                mean_len = float(live.mean()) if live.size else 0.0
                td = time.perf_counter()
                with self._tr.span("decode", batch=nb, steps=n_steps):
                    outputs = self._decode_tick(n_steps)
                dt = time.perf_counter() - td
                self._h_decode.observe(dt)
                self._decode_elapsed += dt
                self._drift.record(nb, mean_len, dt,
                                   ticks=self._last_ticks)
            self._emit_lifecycle(outputs)
        self._m_steps.inc()
        self._g_running.set(self.backend.num_active)
        self._g_waiting.set(self.scheduler.num_waiting)
        self._observe_tier()
        dt_all = time.perf_counter() - t0
        self._h_step.observe(dt_all)
        self._elapsed += dt_all
        return outputs

    def _emit_lifecycle(self, outputs: List[RequestOutput]) -> None:
        """Per-request lifecycle events for this tick's streamed
        increments: first_token on the first emission, one ``tokens``
        event per emission (the measured inter-token stream), finish on
        termination."""
        detok = self._detokenizer
        for o in outputs:
            if detok is not None:
                o.text = detok(o.new_tokens)
            n = len(o.new_tokens)
            if n:
                self._m_tokens.inc(n)
                if o.uid not in self._first_emitted:
                    self._first_emitted.add(o.uid)
                    self._tr.request_event(o.uid, "first_token")
                self._tr.request_event(o.uid, "tokens", n=n)
            if o.finished:
                self._m_finished.inc()
                self._first_emitted.discard(o.uid)
                self._tr.request_event(o.uid, "finish",
                                       reason=o.finish_reason,
                                       tokens=len(o.tokens))
            # Route a copy to any open stream() consumer of this uid —
            # whoever drives step() (generate, a load harness, another
            # stream), the push iterator still sees its own increments.
            buf = self._stream_q.get(o.uid)
            if buf is not None:
                buf.append(o)

    def _observe_tier(self) -> None:
        """Export the KV-tier residency picture once per step: demotion /
        promotion deltas since last observation plus bytes resident on
        each side. Separate from step() so the instruments are only
        *used* (inc/set) in the hot path, never looked up."""
        b = self.backend
        stats = getattr(b, "stats", None)
        if not stats or "demoted_pages" not in stats:
            return
        for key, ctr in (("demoted_pages", self._m_demotions),
                         ("promoted_pages", self._m_promotions)):
            delta = stats[key] - self._tier_seen[key]
            if delta:
                ctr.inc(delta)
                self._tier_seen[key] = stats[key]
        page_bytes = b.kv_pool_bytes() // max(b.pool.num_pages, 1)
        self._g_device_kv.set(b.pool.used_pages * page_bytes)
        self._g_host_kv.set(
            b.host.bytes_resident if b.host is not None else 0)

    def generate(self, requests: Iterable = ()) -> List[RequestOutput]:
        """Blocking convenience: queue ``requests``, drive :meth:`step`
        until every queued request (including preempted ones) finishes,
        and return their final outputs in completion order. If a queued
        request can never be admitted, raises ``OutOfPages`` with the
        outputs that *did* finish this call on its ``completed``
        attribute (they also remain in the engine's history)."""
        for r in requests:
            self.add_request(r)
        done: List[RequestOutput] = []
        while self.backend.active.any() or self.scheduler.has_work():
            idle_before = not self.backend.active.any()
            outs = self.step()
            done.extend(o for o in outs if o.finished)
            if idle_before and not outs and not self.backend.active.any():
                # The scheduler saw an empty engine and still admitted
                # nothing: no queued request can ever fit.
                err = OutOfPages(
                    "pool too small for any queued request; grow "
                    "num_pages or shrink prompts"
                )
                err.completed = done  # don't lose finished work
                raise err
        return done

    async def stream(
        self,
        request=None,
        *,
        prompt=None,
        sampling: Optional[SamplingParams] = None,
        priority: Optional[int] = None,
    ):
        """Push-style consumption of one request::

            async for out in engine.stream(prompt=toks, sampling=sp):
                print(out.text or out.new_tokens, end="")

        Queues the request and yields its :class:`RequestOutput`
        increments as they are produced, ending after the finished
        output. The iterator *drives* ``step()`` whenever it has nothing
        buffered; concurrent consumers (several streams, or a stream
        alongside ``generate()``) cooperate — every ``step()`` caller
        routes increments into each open stream's buffer, so each
        consumer sees exactly its own outputs regardless of who ticked
        the engine. Yields control to the event loop between ticks, so
        streams interleave under any asyncio runner. Raises
        ``OutOfPages`` (like :meth:`generate`) when the request can never
        be admitted."""
        uid = self.add_request(request, prompt=prompt, sampling=sampling,
                               priority=priority)
        q = self._stream_q.setdefault(uid, [])
        try:
            while True:
                while q:
                    out = q.pop(0)
                    yield out
                    if out.finished:
                        return
                idle_before = not self.backend.active.any()
                outs = self.step()
                if (idle_before and not outs
                        and not self.backend.active.any() and not q):
                    raise OutOfPages(
                        "pool too small for any queued request; grow "
                        "num_pages or shrink prompts"
                    )
                await asyncio.sleep(0)
        finally:
            self._stream_q.pop(uid, None)

    def close(self) -> None:
        """Teardown: release every live row and (for the paged backend)
        prove the page pool is fully free again. Raises
        :class:`repro.cache.pool.RefcountLeakError` if any path dropped a
        sequence without releasing its pages — serving tests call this so
        leaks fail loudly instead of surviving to the next admission."""
        self.backend.shutdown()

    def stats(self) -> SchedulerStats:
        b = self.backend
        prefix = b.prefix_stats() if hasattr(b, "prefix_stats") else {}
        nb = max(b.num_active, 1)
        t = b.decode_time_model(nb)
        return SchedulerStats(
            kv_layout=b.kv_layout,
            running=b.num_active,
            waiting=self.scheduler.num_waiting,
            completed=len(self._completed),
            tokens_generated=self._tokens_generated,
            elapsed_s=self._elapsed,
            tokens_per_s=safe_rate(self._tokens_generated, self._elapsed),
            # None (not 0.0) when the backend has no prefix cache at all.
            prefix_hit_rate=prefix.get("prefix_hit_rate"),
            page_occupancy=b.page_occupancy,
            preemptions=b.stats["preemptions"],
            resumed_tokens=b.stats["resumed_tokens"],
            prefill_launches=b.stats["prefill_launches"],
            batched_prefills=b.stats["batched_prefills"],
            occupancy_cap=self.scheduler.occupancy_cap(b),
            modeled_tok_s=safe_rate(nb, t),
            measured_tok_s=safe_rate(
                self._tokens_generated, self._decode_elapsed),
            decode_elapsed_s=self._decode_elapsed,
            steps_per_sync=self.steps_per_sync,
            num_devices=b.num_devices,
            kv_dtype=str(prefix.get("kv_dtype", "fp32")),
            demoted_pages=int(prefix.get("demoted_pages", 0)),
            promoted_pages=int(prefix.get("promoted_pages", 0)),
            host_bytes_resident=int(prefix.get("host_bytes_resident", 0)),
        )

    def drift_model_fn(self):
        """``(batch, mean_len) -> modeled seconds`` for
        :meth:`repro.obs.DriftCollector.report` — the backend's analytic
        decode model evaluated at the drift cell's live context."""
        model = self.backend.decode_time_model
        return lambda batch, mean_len: model(batch, mean_len=mean_len)

    def reset_metrics(self) -> None:
        """Zero telemetry *and* the engine's own wall-clock accumulators
        (load harnesses call this after warmup so measured numbers do not
        include compilation)."""
        self.telemetry.reset()
        self._elapsed = 0.0
        self._decode_elapsed = 0.0
        self._tokens_generated = 0

    # -- internals ---------------------------------------------------------

    def _on_preempt(self, row: int, req, generated: List) -> None:
        self._pending.pop(row, None)
        self.scheduler.requeue(req, generated)
        self._m_preempt.inc()
        self._tr.request_event(req.uid, "preempt", row=row,
                               generated=len(generated))

    def _seed_for(self, req) -> int:
        seed = req.sampling.seed
        return (req.uid if seed is None else seed) & 0x7FFFFFFF

    def _sampling_arrays(self, size, slots_rows):
        """``(size,)``-shaped per-slot sampling-param arrays for one
        device call. ``slots_rows``: (array slot, backend row) pairs —
        slots may be sparse (inactive rows keep inert defaults); the
        stream position is the row's generated-token count at call
        time."""
        temps = np.zeros((size,), np.float32)
        top_k = np.zeros((size,), np.int32)
        top_p = np.ones((size,), np.float32)
        seeds = np.zeros((size,), np.int32)
        pos = np.zeros((size,), np.int32)
        for slot, row in slots_rows:
            req = self.backend.row_req(row)
            sp = req.sampling
            temps[slot], top_k[slot], top_p[slot] = (
                sp.temperature, sp.top_k, sp.top_p
            )
            seeds[slot] = self._seed_for(req)
            pos[slot] = len(self.backend.out[row])
        return temps, top_k, top_p, seeds, pos

    def _flush(self, records: List) -> None:
        """Run the admitted prefills and sample each row's first token on
        device (stream position = tokens generated so far, so a resumed
        request continues its sample stream exactly)."""
        first = self.backend.flush(records)
        rows = sorted(first)
        if not rows:
            return
        logits = np.stack([first[r] for r in rows])
        params = self._sampling_arrays(len(rows), list(enumerate(rows)))
        toks = np.asarray(sampling_lib.sample_tokens(logits, *params))
        for i, r in enumerate(rows):
            self._pending[r] = toks[i]

    def _stop_array(self, rows) -> np.ndarray:
        """Per-row stop-token ids, padded with -1 to a power-of-two width
        (the width is a jit-key component — bucketing bounds the fused
        launcher's compilations). Width 0 disables on-device stop
        detection entirely: no active row has stop tokens, or the stream
        is multi-codebook (scalar-token stop semantics don't apply)."""
        b = self.backend
        if self.cfg.num_codebooks != 1:
            return np.zeros((b.rows, 0), np.int32)
        width = max(
            (len(b.row_req(r).sampling.stop_token_ids) for r in rows),
            default=0,
        )
        if width == 0:
            return np.zeros((b.rows, 0), np.int32)
        width = 1 << (width - 1).bit_length()
        stops = np.full((b.rows, width), -1, np.int32)
        for r in rows:
            ids = b.row_req(r).sampling.stop_token_ids
            stops[r, : len(ids)] = ids
        return stops

    def _decode_tick(self, n_steps: int) -> List[RequestOutput]:
        """Launch the fused scan: reserve cache room for the whole sync,
        gather per-row tokens/sampling params, run up to ``n_steps``
        decode ticks on device, and hand the results to the sanctioned
        once-per-sync host sync point (:meth:`_sync_scan`)."""
        b = self.backend
        # May preempt rows under page pressure; a preempted row drops out
        # of the scan entirely (its done mask starts True).
        b.reserve_rows(n_steps)
        rows = [r for r in range(b.rows) if b.active[r]]
        if not rows:
            self._last_ticks = 0
            return []
        shape = (b.rows,) if self.cfg.num_codebooks == 1 else (
            b.rows, self.cfg.num_codebooks)
        tok = np.zeros(shape, np.int32)
        for row in rows:
            if row in self._pending:
                nxt = self._pending.pop(row)
            else:
                nxt = b.out[row][-1]
            tok[row] = nxt
        temps, top_k, top_p, seeds, pos = self._sampling_arrays(
            b.rows, [(r, r) for r in rows])
        max_toks = np.zeros((b.rows,), np.int32)
        for r in rows:
            max_toks[r] = b.row_req(r).sampling.max_tokens
        ys, lengths_f = b.fused_decode(
            tok, pos, self._stop_array(rows), max_toks,
            temps, top_k, top_p, seeds, n_steps,
        )
        return self._sync_scan(ys, lengths_f)

    def _sync_scan(self, ys, lengths_f) -> List[RequestOutput]:
        """The once-per-sync host sync point: pull the scan's per-tick
        masks/tokens to host, replay them into per-row output lists,
        terminate finished rows, and emit the streamed increments — one
        :class:`RequestOutput` per row per sync, however many ticks ran."""
        b = self.backend
        tok_seq, nxt_seq, live, appended, fed_stop, hit_max = (
            np.asarray(y) for y in ys)
        self._last_ticks = int(live.any(axis=1).sum())
        b.commit_scan(np.asarray(lengths_f))
        outputs: List[RequestOutput] = []
        for r in range(b.rows):
            col = live[:, r]
            if not col.any():
                continue
            req = b.row_req(r)
            reason = None
            last_t = 0
            for t in range(col.shape[0]):
                if not col[t]:
                    break
                last_t = t
                b.out[r].append(tok_seq[t, r].copy())
                self._tokens_generated += 1
                # Mask priority mirrors the single-step rules: a fed stop
                # token outranks the length cap; a freshly *sampled* stop
                # token is appended (no K/V write) and terminates.
                if fed_stop[t, r]:
                    reason = FINISH_STOP
                    break
                if hit_max[t, r]:
                    reason = FINISH_LENGTH
                    break
                if appended[t, r]:
                    b.out[r].append(nxt_seq[t, r].copy())
                    self._tokens_generated += 1
                    reason = FINISH_STOP
                    break
            if reason is not None:
                outputs.append(self._finish(r, req, reason))
            else:
                self._pending[r] = nxt_seq[last_t, r]
                delta = self._delta(req.uid, b.out[r])
                if delta:
                    outputs.append(RequestOutput(
                        uid=req.uid, prompt_len=len(req.prompt),
                        new_tokens=delta, tokens=list(b.out[r]),
                    ))
        return outputs

    def _delta(self, uid: int, out: List) -> List:
        """Tokens not yet streamed for ``uid`` (replayed resume tokens
        were already emitted before the preemption — never re-streamed)."""
        emitted = self._streamed.get(uid, 0)
        if len(out) <= emitted:
            return []
        self._streamed[uid] = len(out)
        return list(out[emitted:])

    def _finish(self, row: int, req, reason: str) -> RequestOutput:
        toks = list(self.backend.out[row])
        delta = self._delta(req.uid, toks)
        self._streamed.pop(req.uid, None)
        self._pending.pop(row, None)
        self.backend.release(row)
        out = RequestOutput(
            uid=req.uid, prompt_len=len(req.prompt), new_tokens=delta,
            tokens=toks, finished=True, finish_reason=reason,
        )
        self._completed.append(out)
        return out


# -----------------------------------------------------------------------------
# Deprecated shims (kept importable; construction outside repro.serving is
# grep-enforced away in tests/test_serving.py)
# -----------------------------------------------------------------------------


@dataclasses.dataclass
class Result:
    """Legacy blocking-run result (pre-PR-5); prefer RequestOutput."""

    uid: int
    tokens: List
    prompt_len: int


class _EngineShim:
    """Thin adapter: legacy constructor surface -> ``LLMEngine``.

    ``rng_seed`` is accepted and ignored — sampling is now on-device and
    keyed per request (``SamplingParams.seed``), not by a shared host RNG.
    Unknown attributes delegate to the facade's backend (``pool``,
    ``prefix``, ``stats``, ``_prefill_p``, ...), then the facade.
    """

    def __init__(self, engine: LLMEngine):
        self._engine = engine
        self.results: List[Result] = []
        self._synced = 0

    def _sync_results(self) -> None:
        """Mirror the facade's completion history into the legacy
        ``results`` list — kept current by both run() and step(), so
        hand-driven submit()+step() loops see their finishes too."""
        done = self._engine._completed
        for o in done[self._synced:]:
            self.results.append(
                Result(uid=o.uid, tokens=list(o.tokens),
                       prompt_len=o.prompt_len)
            )
        self._synced = len(done)

    def run(self, requests) -> List[Result]:
        try:
            self._engine.generate(requests)
        finally:
            self._sync_results()
        return self.results

    def submit(self, req, resume_tokens=()) -> bool:
        """Legacy one-at-a-time admission: admit + flush immediately."""
        rec = self._engine.backend.try_admit(req, resume_tokens=resume_tokens)
        if rec is None or rec is DEFERRED:
            return False
        self._engine._flush([rec])
        return True

    def step(self) -> None:
        self._engine.step()
        self._sync_results()

    @property
    def mapping(self):
        return self._engine.mapping

    def __getattr__(self, name):
        engine = self.__dict__["_engine"]
        try:
            return getattr(engine.backend, name)
        except AttributeError:
            return getattr(engine, name)


class ServingEngine(_EngineShim):
    """DEPRECATED: use ``LLMEngine(cfg, params, kv_layout="dense")``."""

    def __init__(self, cfg, params, *, num_slots=8, cache_len=2048,
                 prompt_buckets=(128, 512, 2048), rng_seed=0, mapping=None):
        warnings.warn(
            "ServingEngine is deprecated; use LLMEngine(kv_layout='dense')",
            DeprecationWarning, stacklevel=2,
        )
        super().__init__(LLMEngine(
            cfg, params, kv_layout="dense", max_batch=num_slots,
            cache_len=cache_len, prompt_buckets=prompt_buckets,
            mapping=mapping,
        ))


class PagedServingEngine(_EngineShim):
    """DEPRECATED: use ``LLMEngine(cfg, params, kv_layout="paged")``."""

    def __init__(self, cfg, params, *, num_pages=128, page_size=16,
                 max_batch=8, max_pages_per_seq=16,
                 prompt_buckets=(32, 64, 128), rng_seed=0, mapping=None,
                 prefix_sharing=True, reserve_pages=1, batch_admissions=True):
        warnings.warn(
            "PagedServingEngine is deprecated; use "
            "LLMEngine(kv_layout='paged')",
            DeprecationWarning, stacklevel=2,
        )
        super().__init__(LLMEngine(
            cfg, params, kv_layout="paged", max_batch=max_batch,
            num_pages=num_pages, page_size=page_size,
            max_pages_per_seq=max_pages_per_seq,
            prompt_buckets=prompt_buckets, prefix_sharing=prefix_sharing,
            reserve_pages=reserve_pages, batch_prefills=batch_admissions,
            mapping=mapping,
        ))
