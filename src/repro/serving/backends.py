"""Execution backends for the serving facade: mechanism, not policy.

The pre-PR-5 ``ServingEngine`` / ``PagedServingEngine`` sibling classes
each owned a full submit/step/run lifecycle with host-side sampling baked
in. This module keeps only what actually differs between the two KV
layouts — cache plumbing — behind one protocol the scheduler and the
``LLMEngine`` facade drive:

  * ``try_admit(req, resume_tokens, pending_hashes)`` reserves a decode
    row (and, paged, its pages) and returns an admission record — or
    ``None`` (does not fit). A request whose prefix is being prefilled
    by a record admitted earlier in the *same* round shares those
    in-flight pages block-level (``flush`` orders the launches so the
    borrower's extend reads published content);
  * ``flush(records)`` runs the reserved prefills — one launch per shared
    jit key with the admitted rows stacked on the batch axis — and
    returns each row's last-position logits (sampling is the engine's
    job, on device);
  * ``reserve_rows(n)`` / ``fused_decode(...)`` / ``commit_scan(...)``
    advance up to N decode ticks in **one jitted ``lax.scan``** — decode
    kernel, on-device sampler, stop-token/max-token done masks, and the
    cache append all stay on device, so the host intervenes once per N
    tokens instead of once per token (ROADMAP item 3). Page-pool pressure
    inside ``reserve_rows`` (and the single-step ``prepare_row`` kept as
    the bit-exactness oracle) consults the injected ``choose_victim``
    policy and reports evictions through ``on_preempt`` — the backend
    executes preemption, the scheduler decides it;
  * ``release(row)`` frees a finished row; ``quote``/``free_pages``/
    ``evictable_pages``/``decode_time_model`` feed the scheduler's page
    budget and NUMA-occupancy admission policy.

``DenseBackend`` is the slot-per-sequence dense-stripe layout;
``PagedBackend`` is the paged pool with hash-chain prefix sharing,
per-token page append, COW, and head-major (NUMA head-aligned) placement
consumed natively by the paged kernels. All kernel scheduling flows
through ``kernels.plan``; the backends never thread schedule names or
query offsets.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache.pool import (
    NULL_PAGE,
    OutOfPages,
    PagePool,
    SequencePages,
    SequenceReleasedError,
)
from repro.cache import quant
from repro.cache.prefix import PrefixCache, page_hashes
from repro.cache.tier import HostPageStore
from repro.configs.base import ModelConfig
from repro.kernels import plan as plan_lib
from repro.models import transformer
from repro.serving import sampling as sampling_lib
from repro.serving.scheduler import default_choose_victim


class _SeqState:
    """One active decode row."""

    __slots__ = ("req", "pages", "submit_order")

    def __init__(self, req, pages, submit_order):
        self.req = req
        self.pages = pages
        self.submit_order = submit_order


class _Backend:
    """Shared row bookkeeping + policy hooks."""

    kv_layout: str
    rows: int
    #: Serving mesh (1-D "model" axis) the KV caches are sharded over;
    #: None = single-device. Set by :meth:`_setup_mesh`.
    mesh = None
    num_devices: int = 1

    def _setup_mesh(self, mesh, specs) -> None:
        """Place the cache tree under ``specs`` on ``mesh`` and remember
        the shardings so the jitted hot paths can re-constrain (GSPMD
        would otherwise be free to re-layout the donated scan carry).
        Head-sharded placement only moves bytes — every jitted program
        computes the same values, so sharded decode stays bit-exact."""
        from jax.sharding import NamedSharding, PartitionSpec

        self.mesh = mesh
        self.num_devices = int(mesh.devices.size) if mesh is not None else 1
        if mesh is None:
            self._cache_shardings = None
            return
        self._cache_shardings = jax.tree.map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(x, PartitionSpec),
        )
        self.caches = jax.device_put(self.caches, self._cache_shardings)

    def _constrain(self, caches):
        """Inside-jit sharding pin for the cache tree (identity off-mesh)."""
        if getattr(self, "_cache_shardings", None) is None:
            return caches
        return jax.tree.map(
            jax.lax.with_sharding_constraint, caches, self._cache_shardings
        )

    @staticmethod
    def _check_head_shards(cfg: ModelConfig, mesh) -> int:
        """Validate the head-sharded split and return the device count."""
        if mesh is None:
            return 1
        n = int(mesh.devices.size)
        if n > 1:
            from repro.distributed import sharding as sharding_lib

            # Raises with a clear message when Hkv % devices != 0.
            sharding_lib.kv_head_shards(cfg.n_kv_heads, n)
        return n

    def _init_rows(self, rows: int):
        self.rows = rows
        self.lengths = np.zeros((rows,), np.int32)
        self.active = np.zeros((rows,), bool)
        #: Generated tokens per row (includes replayed resume tokens) —
        #: row state, because preemption requeues them for replay.
        self.out: List[List] = [[] for _ in range(rows)]
        self._submit_counter = 0
        # Policy hooks, wired by LLMEngine; standalone backends fall back
        # to the default victim rule and collect orphaned preemptions.
        self.preempted: List[Tuple[object, List]] = []
        self.choose_victim: Callable = default_choose_victim
        self.on_preempt: Callable = (
            lambda row, req, toks: self.preempted.append((req, toks))
        )
        self.stats = {
            "preemptions": 0, "prefix_evictions": 0, "pages_reused": 0,
            "prompt_pages": 0, "cow_copies": 0, "extend_prefills": 0,
            "resumed_tokens": 0, "prefill_launches": 0,
            "batched_prefills": 0, "decode_traces": 0,
        }
        #: How many decode steps one engine sync fuses (set by LLMEngine;
        #: the scheduler prices page growth against it).
        self.steps_per_sync = 1
        # Fused-decode launchers, keyed (n_steps, stop-width bucket,
        # multi-codebook) — O(1) keys per engine, so steady-state decode
        # never retraces (stats["decode_traces"] counts traces).
        self._scan_cache: Dict = {}

    @property
    def num_active(self) -> int:
        return int(self.active.sum())

    def _bucket_for(self, n: int) -> int:
        for b in self.prompt_buckets:
            if n <= b:
                return b
        raise ValueError(
            f"prompt length {n} exceeds buckets {self.prompt_buckets}"
        )

    def fits_buckets(self, n: int) -> bool:
        return any(n <= b for b in self.prompt_buckets)

    # -- fused multi-step decode (the host-free hot loop) -------------------

    def reserve_rows(self, n_steps: int) -> None:
        """Reserve cache capacity for up to ``n_steps`` tokens per active
        row before a fused scan launches. Dense stripes pre-reserve every
        position at admission, so the base implementation is a no-op; the
        paged backend overrides it with page reservation."""

    def commit_scan(self, new_lengths: np.ndarray) -> None:
        """Adopt the post-scan per-row lengths. The paged backend also
        returns unconsumed reserved pages here (early stop / all-done
        exit); rows the scan finished are released by the engine *after*
        this commit, so trims always see live sequences."""
        self.lengths = np.array(new_lengths, dtype=self.lengths.dtype)

    def fused_decode(self, tok, gen, stops, max_toks,
                     temps, top_k, top_p, seeds, n_steps: int):
        """Run up to ``n_steps`` decode ticks in one jitted ``lax.scan``.

        Per scan tick, entirely on device: decode kernel -> per-request
        sampler (the same ``_sample_batch`` program the single-step path
        jits, so outputs are bit-exact) -> stop-token / max-token done-mask
        update -> cache append (paged rows write into pages reserved by
        :meth:`reserve_rows`; dense rows bump their stripe position). Rows
        finish mid-scan by freezing: their length stops advancing and (for
        paged) their page-table row nulls out so the re-fed token sinks
        into the null page — no live or shared page is ever re-written.
        A ``lax.cond`` skips the remaining ticks once every row is done.

        ``tok``: (rows,)[,K] token to feed first (the per-row pending
        sample); ``gen``: per-row generated-token counts (the sampler's
        stream position is scan-carried from here, so a fused run consumes
        the identical keyed sample stream as N single steps); ``stops``:
        (rows, W) stop-token ids padded with -1 (W == 0 disables stop
        detection — multi-codebook streams); ``max_toks``: per-row
        ``max_tokens``. Returns ``(ys, final_lengths)`` where ``ys`` are
        per-tick device arrays (fed token, next sample, live /
        appended-stop / fed-stop / hit-max masks) the engine reconstructs
        host state from once per sync, and ``final_lengths`` feeds
        :meth:`commit_scan`.
        """
        fn = self._fused_decode_fn(
            int(n_steps), int(stops.shape[1]),
            self.cfg.num_codebooks != 1,
        )
        paged = self.kv_layout == "paged"
        pt = (jnp.asarray(self.page_table) if paged
              else jnp.zeros((self.rows, 1), jnp.int32))
        carry, ys = fn(
            self.params, self.caches, pt, jnp.asarray(tok, jnp.int32),
            jnp.asarray(self.lengths), jnp.asarray(gen, jnp.int32),
            jnp.asarray(~self.active), jnp.asarray(temps),
            jnp.asarray(top_k), jnp.asarray(top_p), jnp.asarray(seeds),
            jnp.asarray(stops, jnp.int32), jnp.asarray(max_toks, jnp.int32),
        )
        self.caches = carry[0]
        return ys, carry[3]

    def _fused_decode_fn(self, n_steps: int, stop_width: int, multi: bool):
        key = (n_steps, stop_width, multi)
        fn = self._scan_cache.get(key)
        if fn is None:
            fn = self._build_fused_decode(n_steps, stop_width, multi)
            self._scan_cache[key] = fn
        return fn

    def _build_fused_decode(self, n_steps: int, stop_width: int,
                            multi: bool):
        from repro import compat

        cfg = self.cfg
        paged = self.kv_layout == "paged"
        stats = self.stats
        constrain = self._constrain

        def run(params, caches, pt, tok, lengths, gen, done,
                temps, top_k, top_p, seeds, stops, max_toks):
            # Trace-time side effect: fires once per compilation, so a
            # flat counter after warmup proves zero steady-state retraces.
            stats["decode_traces"] += 1
            caches = constrain(caches)

            def tick(carry):
                caches, pt, tok, lengths, gen, done = carry
                live = ~done
                lengths1 = lengths + live.astype(lengths.dtype)
                if paged:
                    logits, caches1 = transformer.decode_step(
                        params, cfg, tok, caches, lengths1, page_table=pt)
                else:
                    logits, caches1 = transformer.decode_step(
                        params, cfg, tok, caches, lengths1)
                # Keep the scan carry head-sharded: without the pin GSPMD
                # may re-layout the donated caches between ticks, turning
                # the device-local page walk into resharding traffic.
                caches1 = constrain(caches1)
                gen1 = gen + live.astype(gen.dtype)
                nxt = sampling_lib._sample_batch(
                    logits, temps, top_k, top_p, seeds, gen1
                ).astype(tok.dtype)
                if stop_width:
                    fed_stop = live & (tok[:, None] == stops).any(axis=1)
                    nxt_stop = (nxt[:, None] == stops).any(axis=1)
                else:
                    fed_stop = nxt_stop = jnp.zeros_like(done)
                hit_max = live & (gen1 >= max_toks)
                done_fed = fed_stop | hit_max
                # A freshly sampled stop token is recorded in the output
                # but never decoded (no K/V write) — mirror of the
                # single-step path's early-stop append.
                append_nxt = live & ~done_fed & nxt_stop
                gen2 = gen1 + append_nxt.astype(gen.dtype)
                newly = done_fed | append_nxt
                pt1 = (jnp.where(newly[:, None], jnp.int32(NULL_PAGE), pt)
                       if paged else pt)
                keep = live & ~newly
                tok1 = (jnp.where(keep[:, None], nxt, tok) if multi
                        else jnp.where(keep, nxt, tok))
                y = (tok, nxt, live, append_nxt, fed_stop, hit_max)
                return (caches1, pt1, tok1, lengths1, gen2, done | newly), y

            def skip(carry):
                # All rows done: early exit — carry is untouched and the
                # tick's masks read "nothing happened" on the host.
                tok = carry[2]
                false = jnp.zeros_like(carry[5])
                return carry, (tok, tok, false, false, false, false)

            def body(carry, _):
                return jax.lax.cond(carry[5].all(), skip, tick, carry)

            carry0 = (caches, pt, tok, lengths, gen, done)
            return jax.lax.scan(body, carry0, None, length=n_steps)

        # Donate the KV caches: the scan carry aliases its input buffers
        # in place of a copy (halves peak cache HBM on TPU/GPU; a silent
        # hint on CPU).
        return compat.donating_jit(run, donate_argnums=(1,))


# -----------------------------------------------------------------------------
# Dense slots
# -----------------------------------------------------------------------------


class DenseBackend(_Backend):
    """Slot-based dense KV: each row owns a ``cache_len`` stripe; new
    requests prefill into free slots (jitted per bucketed prompt length);
    one fused decode step advances every active slot. No preemption —
    a slot is committed until its sequence finishes."""

    kv_layout = "dense"

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        rows: int = 8,
        cache_len: int = 2048,
        prompt_buckets=(128, 512, 2048),
        mesh=None,
    ):
        self._check_head_shards(cfg, mesh)
        self.cfg = cfg
        self.params = params
        self.cache_len = cache_len
        self.prompt_buckets = tuple(b for b in prompt_buckets if b <= cache_len)
        self._init_rows(rows)
        self.caches = transformer.init_caches(
            params, cfg, rows, cache_len, image_len=cfg.vision_tokens or 0,
        )
        specs = None
        if mesh is not None:
            from repro.distributed import sharding as sharding_lib

            # (rows, Hkv, S, hd) stripes: heads on "model" (batch axes
            # resolve replicated on the 1-D serving mesh).
            specs = sharding_lib.cache_specs(cfg, mesh, self.caches)
        self._setup_mesh(mesh, specs)
        self.slot_req: List[Optional[object]] = [None] * rows
        constrain = self._constrain
        self._decode = jax.jit(
            lambda params, tok, caches, lengths: transformer.decode_step(
                params, cfg, tok, constrain(caches), lengths
            )
        )
        self._prefill = {}

    # -- capacity ----------------------------------------------------------

    def validate(self, req) -> None:
        n = len(req.prompt)
        if not self.fits_buckets(n):
            raise ValueError(
                f"prompt length {n} exceeds buckets {self.prompt_buckets}"
            )
        if n + req.max_new_tokens > self.cache_len:
            raise ValueError(
                f"request {req.uid}: prompt {n} + max_tokens "
                f"{req.max_new_tokens} exceeds the dense cache stripe "
                f"({self.cache_len} tokens)"
            )

    def decode_time_model(self, batch: int,
                          mean_len: Optional[float] = None) -> float:
        # ``mean_len`` is accepted for protocol parity with the paged
        # model (drift calibration passes the live mean context) but
        # ignored: a dense decode streams the full stripe regardless of
        # how much of it is live.
        from repro import compat
        from repro.core import perf_model

        return perf_model.estimate_dense_decode(
            batch=batch, num_q_heads=self.cfg.n_heads,
            num_kv_heads=self.cfg.n_kv_heads, capacity=self.cache_len,
            head_dim=self.cfg.head_dim,
            dtype_bytes=jnp.dtype(self.cfg.compute_dtype).itemsize,
            topo=plan_lib._topology_for(compat.default_backend()),
        ).time

    @property
    def page_occupancy(self) -> float:
        return self.num_active / self.rows if self.rows else 0.0

    def prefix_stats(self) -> Dict[str, object]:
        """Dense stripes have no prefix cache: every sharing counter is a
        structural zero and ``prefix_hit_rate`` is **None** — "no cache",
        not "a cache that never hit" (PR 7 satellite; the old facade
        silently reported 0.0 here, indistinguishable from a cold paged
        cache)."""
        return {
            "prefix_entries": 0.0,
            "pages_reused": 0.0,
            "prompt_pages": 0.0,
            "prefix_hit_rate": None,
            "prefix_lookup_hits": 0.0,
            "prefix_lookup_queries": 0.0,
            "prefix_evictions": 0.0,
            "preemptions": float(self.stats["preemptions"]),
            "resumed_tokens": float(self.stats["resumed_tokens"]),
            "prefill_launches": float(self.stats["prefill_launches"]),
            "batched_prefills": float(self.stats["batched_prefills"]),
        }

    # -- admission / prefill ----------------------------------------------

    def try_admit(self, req, resume_tokens: Sequence = (),
                  pending_hashes=()):
        if resume_tokens:
            raise ValueError("dense backend does not preempt, so it "
                             "cannot resume")
        free = np.flatnonzero(~self.active)
        if len(free) == 0:
            return None
        n = len(req.prompt)
        bucket = self._bucket_for(n)
        row = int(free[0])
        self.lengths[row] = n
        self.active[row] = True
        self.slot_req[row] = req
        self.out[row] = []
        self._submit_counter += 1
        return {"req": req, "row": row, "n": n, "bucket": bucket,
                "prompt": np.asarray(req.prompt)}

    def _prefill_fn(self, bucket: int):
        if bucket not in self._prefill:
            cfg = self.cfg

            def f(params, tokens, last_positions):
                return transformer.prefill(
                    params, cfg, tokens, cache_len=self.cache_len,
                    last_positions=last_positions,
                )

            self._prefill[bucket] = jax.jit(f)
        return self._prefill[bucket]

    def _write_slot_cache(self, slot: int, new_caches):
        """Copy a single-sequence prefilled cache into the slot stripe.

        Cache leaves carry batch at axis 1 for scanned stacks
        ((n_periods, B, ...)) and axis 0 for remainder layers.
        """

        def assign(dst, src):
            return dst.at[:, slot : slot + 1].set(src.astype(dst.dtype))

        def assign_rem(dst, src):
            return dst.at[slot : slot + 1].set(src.astype(dst.dtype))

        self.caches = {
            "scanned": jax.tree.map(
                assign, self.caches["scanned"], new_caches["scanned"]
            ),
            "rem": jax.tree.map(
                assign_rem, self.caches["rem"], new_caches["rem"]
            ),
        }

    def flush(self, records) -> Dict[int, np.ndarray]:
        """Prefill each admitted record into its slot; returns per-row
        last-position logits for the engine's first-token sample."""
        first_logits: Dict[int, np.ndarray] = {}
        for rec in records:
            n, bucket, tok = rec["n"], rec["bucket"], rec["prompt"]
            pad_width = [(0, bucket - n)] + [(0, 0)] * (tok.ndim - 1)
            padded = np.pad(tok, pad_width)[None]
            self.stats["prefill_launches"] += 1
            logits, caches1 = self._prefill_fn(bucket)(
                self.params, jnp.asarray(padded),
                jnp.asarray([n - 1], jnp.int32),
            )
            self._write_slot_cache(rec["row"], caches1)
            first_logits[rec["row"]] = np.asarray(logits)[0]
        return first_logits

    # -- decode / teardown -------------------------------------------------

    def prepare_row(self, row: int) -> None:
        pass  # dense stripes pre-reserve every position

    def decode(self, tok: np.ndarray):
        self.lengths = self.lengths + self.active.astype(np.int32)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches,
            jnp.asarray(self.lengths),
        )
        return logits

    def row_req(self, row: int):
        return self.slot_req[row]

    def release(self, row: int) -> None:
        self.active[row] = False
        self.slot_req[row] = None

    def shutdown(self) -> None:
        """Teardown: mark every slot free. Dense rows own no pool pages,
        so there is nothing to leak-check — this exists so LLMEngine.close
        is backend-agnostic."""
        for row in range(self.rows):
            self.active[row] = False
            self.slot_req[row] = None

    @property
    def mapping(self):
        """Plan-resolved steady-state prefill schedule (stats / capacity
        planning); a pinned paper schedule passes through unchanged."""
        return plan_lib.plan_for_config(
            self.cfg,
            (self.rows, self.cfg.n_heads, self.cfg.n_kv_heads,
             self.cache_len, self.cache_len, self.cfg.head_dim),
            phase=plan_lib.PREFILL,
        ).mapping


# -----------------------------------------------------------------------------
# Paged pool
# -----------------------------------------------------------------------------


class PagedBackend(_Backend):
    """Paged KV-cache backend (PR 2-4 mechanism, policy extracted).

    ``rows`` is only the width of the fused decode step (a jit-static
    shape); *capacity* is the page pool — admission succeeds when a
    request's non-shared prompt pages fit the free list with
    ``reserve_pages`` of decode headroom. Prefix sharing, per-token page
    append with COW, preemption + resume-by-replay, and head-major (NUMA
    head-aligned) placement all live here; who is admitted or evicted is
    the scheduler's call.

    Restrictions: pure self-attention stacks (``init_paged_caches``
    enforces it) and single-codebook token streams.
    """

    kv_layout = "paged"

    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        num_pages: int = 128,
        page_size: int = 16,
        rows: int = 8,
        max_pages_per_seq: int = 16,
        prompt_buckets=(32, 64, 128),
        prefix_sharing: bool = True,
        reserve_pages: int = 1,
        batch_prefills: bool = True,
        mesh=None,
        device_hbm_bytes=None,
        kv_dtype: str = "fp32",
        host_pool_bytes=None,
    ):
        if cfg.num_codebooks != 1:
            raise ValueError("paged backend supports single-codebook models")
        self.kv_dtype = quant.validate_kv_dtype(kv_dtype)
        num_devices = self._check_head_shards(cfg, mesh)
        # Per-device page budgets: each device holds a (Hkv/D)-head slice
        # of every page, so a byte budget translates to a per-device page
        # capacity — and the *pool* is one global allocator, so the
        # tightest device clamps it (a page exists on every device or on
        # none; page tables stay replicated).
        self._page_budgets = None
        if device_hbm_bytes is not None:
            budgets = (
                tuple(float(b) for b in device_hbm_bytes)
                if isinstance(device_hbm_bytes, (tuple, list))
                else (float(device_hbm_bytes),) * num_devices
            )
            if len(budgets) != num_devices:
                raise ValueError(
                    f"device_hbm_bytes has {len(budgets)} entries for "
                    f"{num_devices} devices"
                )
            slice_bytes = self._page_slice_bytes(
                cfg, page_size, num_devices, kv_dtype
            )
            caps = tuple(int(b // slice_bytes) for b in budgets)
            clamp = min(caps)
            if clamp < 1 + max_pages_per_seq:
                limit = caps.index(clamp)
                raise ValueError(
                    f"device {limit} page budget holds {clamp} pages "
                    f"({budgets[limit]:.3g} B / {slice_bytes} B per page "
                    f"slice) < 1 + max_pages_per_seq={max_pages_per_seq}"
                )
            self._page_budgets = caps
            num_pages = min(num_pages, clamp)
        for b in prompt_buckets:
            if b % page_size:
                raise ValueError(
                    f"prompt bucket {b} must be a multiple of page_size "
                    f"{page_size}"
                )
        if num_pages - 1 < max_pages_per_seq:
            # A lone max-size sequence must always be able to grow to its
            # cap (evicting idle prefix pages on the way); otherwise decode
            # hits OutOfPages with nothing to preempt.
            raise ValueError(
                f"num_pages={num_pages} (usable {num_pages - 1}) cannot "
                f"hold one max_pages_per_seq={max_pages_per_seq} sequence"
            )
        self.cfg = cfg
        self.params = params
        self.page_size = page_size
        self.max_pages_per_seq = max_pages_per_seq
        self.cache_len = max_pages_per_seq * page_size
        self.prompt_buckets = tuple(
            b for b in prompt_buckets if b <= self.cache_len
        )
        self.reserve_pages = reserve_pages
        self.prefix_sharing = prefix_sharing
        self.batch_prefills = batch_prefills
        self._init_rows(rows)

        self.pool = PagePool(num_pages, page_size)
        self.prefix = PrefixCache(self.pool)
        # Host tier: an LRU store of demoted pages behind the device pool,
        # keyed by the same chain hashes the prefix cache uses — so it is
        # only reachable with prefix sharing on (the hash chain IS the
        # promotion key; without it nothing ever demotes).
        self.host: Optional[HostPageStore] = None
        if host_pool_bytes:
            if not prefix_sharing:
                raise ValueError(
                    "host_pool_bytes requires prefix_sharing=True: demoted "
                    "pages are keyed by the prefix hash chain"
                )
            self.host = HostPageStore(
                int(host_pool_bytes),
                self._page_slice_bytes(cfg, page_size, 1, kv_dtype),
            )
        self.stats.update({
            "demoted_pages": 0, "promoted_pages": 0,
            "inflight_pages_reused": 0,
        })
        #: Same-flush block-level sharing: chain hash -> (physical page,
        #: publishing request uid) for pages admitted-but-not-yet-flushed
        #: this round. Cleared by :meth:`flush` once everything published.
        self._pending_pages: Dict[bytes, Tuple[int, int]] = {}
        self.caches = transformer.init_paged_caches(
            params, cfg, num_pages, page_size, kv_dtype=kv_dtype
        )
        specs = None
        if mesh is not None:
            from repro.distributed import sharding as sharding_lib

            specs = sharding_lib.paged_cache_specs(mesh, self.caches)
        self._setup_mesh(mesh, specs)
        # Inactive rows keep all-null page tables and length 0: the decode
        # step writes their token into the reserved null page and the
        # kernel emits zeros for them.
        self.page_table = np.zeros((rows, max_pages_per_seq), np.int32)
        self.seqs: List[Optional[_SeqState]] = [None] * rows

        constrain = self._constrain
        self._decode = jax.jit(
            lambda params, tok, caches, lengths, pt: transformer.decode_step(
                params, cfg, tok, constrain(caches), lengths, page_table=pt
            )
        )
        self._prefill_p: Dict = {}
        self._scatter_jit = jax.jit(
            lambda caches, tails, pids: constrain(
                self._scatter_tail(caches, tails, pids)
            )
        )
        self._copy_jit = jax.jit(
            lambda caches, src, dst: constrain(
                self._copy_page(caches, src, dst)
            )
        )
        self._restore_jit = jax.jit(
            lambda caches, payload, dst: constrain(
                self._restore_page(caches, payload, dst)
            )
        )

    # -- capacity ----------------------------------------------------------

    @staticmethod
    def _page_slice_bytes(cfg: ModelConfig, page_size: int,
                          num_devices: int, kv_dtype: str = "fp32") -> int:
        """Bytes one physical page occupies in ONE device's HBM: the
        (Hkv / D)-head K+V slice of that page, summed over every layer
        (one pool per attention layer, all driven by the same ids).
        Quantized pools store 1-byte codes plus one fp32 scale per
        (kv head, page) for K and V each."""
        heads_dev = -(-cfg.n_kv_heads // max(num_devices, 1))
        if kv_dtype in quant.QMAX:
            per_head = page_size * cfg.head_dim * quant.kv_itemsize(kv_dtype) + 4
        else:
            per_head = (
                page_size * cfg.head_dim
                * jnp.dtype(cfg.compute_dtype).itemsize
            )
        return 2 * cfg.n_layers * heads_dev * per_head

    def kv_pool_bytes(self) -> int:
        """Total device bytes the paged pools (+ scale metadata) occupy
        across the mesh — the capacity headline the kv_dtype knob shrinks
        (int8 lands at ~0.25x the fp32 pool)."""
        return (
            self._page_slice_bytes(
                self.cfg, self.page_size, self.num_devices, self.kv_dtype
            )
            * self.pool.num_pages * self.num_devices
        )

    @property
    def _kv_dtype_bytes(self) -> int:
        """Per-element pool bytes the perf models should price: the code
        width for quantized pools (HBM traffic shrinks with storage —
        dequant happens in VMEM), the compute itemsize otherwise."""
        if self.kv_dtype in quant.QMAX:
            return quant.kv_itemsize(self.kv_dtype)
        return jnp.dtype(self.cfg.compute_dtype).itemsize

    def device_page_budgets(self) -> Optional[Dict[str, object]]:
        """Per-device page capacities under ``device_hbm_bytes`` (None
        when no budget was given): capacities, the limiting device, and
        the effective pool size after the clamp — what the scheduler's
        ``page_budget_ok`` is implicitly pricing via ``free_pages``."""
        if self._page_budgets is None:
            return None
        caps = self._page_budgets
        return {
            "capacities": caps,
            "limiting_device": caps.index(min(caps)),
            "effective_num_pages": self.pool.num_pages,
        }

    def validate(self, req) -> None:
        tok = np.asarray(req.prompt)
        if tok.ndim != 1:
            raise ValueError("paged backend expects flat token prompts")
        n = len(tok)
        if self.pool.pages_needed(n + req.max_new_tokens) > self.max_pages_per_seq:
            raise ValueError(
                f"request {req.uid}: prompt {n} + max_new_tokens "
                f"{req.max_new_tokens} can outgrow max_pages_per_seq="
                f"{self.max_pages_per_seq} ({self.cache_len} tokens) "
                "mid-decode; reject at admission instead"
            )
        if not self.prefix_sharing and not self.fits_buckets(n):
            # With sharing on, a long prompt may still be servable through
            # a prefix match (a runtime condition, checked at admission);
            # without it the tail is always the full prompt — reject now.
            raise ValueError(
                f"prompt length {n} exceeds buckets {self.prompt_buckets}"
            )

    def quote(self, req) -> Tuple[int, int]:
        """Page-budget quote for the scheduler: (total pages the prompt
        needs, shared pages it would reuse *without allocating*). A pure
        peek — nothing is reserved, LRU order and hit-rate counters stay
        untouched (the scheduler may price a blocked request every
        round). Reuse counts device prefix-cache matches plus the
        in-flight continuation (pages a record admitted this round will
        publish at flush — the borrower increfs rather than allocates).
        Host-tier matches are deliberately **excluded**: a promoted page
        still consumes a fresh device page, so for the page budget it is
        indistinguishable from a prefill — only
        :meth:`prefill_time_saved` prices the recompute it avoids."""
        n = len(req.prompt)
        total = self.pool.pages_needed(n)
        matched = 0
        if self.prefix_sharing and n > 1:
            limit = (n - 1) // self.page_size
            hashes = req.page_hashes(self.page_size)
            matched = len(self.prefix.lookup(hashes[:limit], touch=False))
            while (matched < limit
                   and hashes[matched] in self._pending_pages):
                matched += 1
        return total, matched

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages

    @property
    def evictable_pages(self) -> int:
        return len(self.prefix)

    @property
    def page_occupancy(self) -> float:
        return self.pool.used_pages / max(self.pool.num_pages - 1, 1)

    def decode_time_model(self, batch: int,
                          mean_len: Optional[float] = None) -> float:
        # Default planning shape is half-full sequences; drift calibration
        # passes the cell's *measured* live mean context instead, so the
        # comparison prices what the machine actually decoded. On a mesh
        # the sharded estimate prices the per-device head slice plus the
        # attention-output gather.
        from repro import compat
        from repro.core import numa, perf_model

        kw = dict(
            batch=batch, num_q_heads=self.cfg.n_heads,
            num_kv_heads=self.cfg.n_kv_heads,
            mean_len=(max(int(mean_len), self.page_size) if mean_len
                      else max(self.cache_len // 2, self.page_size)),
            page_size=self.page_size, head_dim=self.cfg.head_dim,
            dtype_bytes=self._kv_dtype_bytes,
        )
        chip = plan_lib._topology_for(compat.default_backend())
        if self.num_devices > 1:
            return perf_model.estimate_sharded_paged_decode(
                mesh=numa.mesh_topology(self.num_devices, chip=chip), **kw
            ).time
        return perf_model.estimate_paged_decode(topo=chip, **kw).time

    def prefill_time_saved(self, req) -> float:
        """Modeled prefill seconds cache reuse would save this request if
        admitted *now* — the scheduler's cost-aware tie-break within a
        priority class. Priced as (full prefill) minus (extend over the
        matched prefix), both via
        :func:`core.perf_model.estimate_extend_prefill`; a host-tier
        continuation of the match adds its saved recompute **minus** the
        device<->host transfer (:func:`core.perf_model.
        estimate_tier_transfer`) — promotion is only credited where the
        link beats the FLOPs, which is exactly the demote-vs-recompute
        call the tier exists to win. Zero when nothing matches."""
        from repro import compat
        from repro.core import perf_model

        _, matched = self.quote(req)
        n = len(req.prompt)
        limit = (n - 1) // self.page_size
        host_run = 0
        if self.host is not None and matched < limit:
            hashes = req.page_hashes(self.page_size)
            for h in hashes[matched:limit]:
                if h not in self.host:
                    break
                host_run += 1
        if matched <= 0 and host_run <= 0:
            return 0.0
        prefix = min(matched * self.page_size, n - 1)
        both = min((matched + host_run) * self.page_size, n - 1)
        topo = plan_lib._topology_for(compat.default_backend())
        dtype_bytes = self._kv_dtype_bytes

        def _t(prefix_len: int) -> float:
            return perf_model.estimate_extend_prefill(
                batch=1, num_q_heads=self.cfg.n_heads,
                num_kv_heads=self.cfg.n_kv_heads,
                prefix_len=prefix_len, tail_len=n - prefix_len,
                page_size=self.page_size, head_dim=self.cfg.head_dim,
                dtype_bytes=dtype_bytes, topo=topo,
            ).time

        saved = max(_t(0) - _t(prefix), 0.0)
        if host_run > 0:
            transfer = perf_model.estimate_tier_transfer(
                host_run * self.host.page_nbytes
            )
            saved += max(_t(prefix) - _t(both) - transfer, 0.0)
        return saved

    # -- jitted cache plumbing ---------------------------------------------

    @staticmethod
    def _scatter_tail(caches, tail_caches, pids):
        """Write prefilled tails' dense K/V into freshly allocated pages.

        pids: (rows, bucket/ps) destinations, one row per admitted
        sequence in the (possibly batched) prefill; entries past a tail's
        real pages are the null page (their writes are garbage sinks by
        design — with several rows the null page takes whichever write
        lands last, all equally garbage). Quantized pools store per-page
        codes and set the destinations' scale entries in the same jitted
        program (``cache.quant.scatter_pages``); the pages axis is third
        from the end for both the flat and the scanned stacks, so one
        reshape serves both.
        """
        flat = pids.reshape(-1)

        def s(pages, scales, dense, scanned, kv_dtype):
            if scanned:
                npp, rows, hkv, bucket, hd = dense.shape
                ps = pages.shape[3]
                new = dense.reshape(npp, rows, hkv, bucket // ps, ps, hd)
                new = new.transpose(0, 2, 1, 3, 4, 5).reshape(
                    npp, hkv, rows * (bucket // ps), ps, hd
                )
            else:
                rows, hkv, bucket, hd = dense.shape
                ps = pages.shape[2]
                new = dense.reshape(rows, hkv, bucket // ps, ps, hd)
                new = new.transpose(1, 0, 2, 3, 4).reshape(
                    hkv, rows * (bucket // ps), ps, hd
                )
            return quant.scatter_pages(pages, scales, new, flat, kv_dtype)

        def layer(c, t, scanned):
            a = c["attn"]
            kv_dtype = quant.kv_dtype_of(a["k_pages"].dtype)
            kp, ks = s(a["k_pages"], a.get("k_scales"), t["attn"]["k"],
                       scanned, kv_dtype)
            vp, vs = s(a["v_pages"], a.get("v_scales"), t["attn"]["v"],
                       scanned, kv_dtype)
            out = {"k_pages": kp, "v_pages": vp}
            if ks is not None:
                out["k_scales"] = ks
                out["v_scales"] = vs
            return {"attn": out}

        return {
            "scanned": tuple(
                layer(c, t, True)
                for c, t in zip(caches["scanned"], tail_caches["scanned"])
            ),
            "rem": tuple(
                layer(c, t, False)
                for c, t in zip(caches["rem"], tail_caches["rem"])
            ),
        }

    @staticmethod
    def _copy_page(caches, src, dst):
        """Physical page copy (copy-on-write), every layer at once. The
        scale entry follows the page (``cache.quant.cow_scales``) so a
        forked quantized page dequantizes identically."""

        def cp(pages, scanned):
            if scanned:
                return pages.at[:, :, dst].set(pages[:, :, src])
            return pages.at[:, dst].set(pages[:, src])

        def layer(c, scanned):
            a = c["attn"]
            out = {
                "k_pages": cp(a["k_pages"], scanned),
                "v_pages": cp(a["v_pages"], scanned),
            }
            if "k_scales" in a:
                out["k_scales"] = quant.cow_scales(a["k_scales"], src, dst)
                out["v_scales"] = quant.cow_scales(a["v_scales"], src, dst)
            return {"attn": out}

        return {
            "scanned": tuple(layer(c, True) for c in caches["scanned"]),
            "rem": tuple(layer(c, False) for c in caches["rem"]),
        }

    @staticmethod
    def _restore_page(caches, payload, dst):
        """Inverse of :meth:`_page_payload`: write one promoted page's
        host payload (codes + scale entries, every layer) into physical
        page ``dst``. ``dst`` is traced, so one compilation serves every
        promotion."""

        def put(pages, page, scanned):
            page = jnp.asarray(page).astype(pages.dtype)
            if scanned:
                return pages.at[:, :, dst].set(page)
            return pages.at[:, dst].set(page)

        def layer(c, pl, scanned):
            a = c["attn"]
            out = {
                "k_pages": put(a["k_pages"], pl["k"], scanned),
                "v_pages": put(a["v_pages"], pl["v"], scanned),
            }
            if "k_scales" in a:
                out["k_scales"] = a["k_scales"].at[..., dst].set(
                    jnp.asarray(pl["ks"], a["k_scales"].dtype)
                )
                out["v_scales"] = a["v_scales"].at[..., dst].set(
                    jnp.asarray(pl["vs"], a["v_scales"].dtype)
                )
            return {"attn": out}

        return {
            "scanned": tuple(
                layer(c, p, True)
                for c, p in zip(caches["scanned"], payload["scanned"])
            ),
            "rem": tuple(
                layer(c, p, False)
                for c, p in zip(caches["rem"], payload["rem"])
            ),
        }

    def _page_payload(self, pid: int):
        """Host (numpy) copy of one physical page across every layer's
        pools — codes plus scale entries, the opaque payload the
        :class:`HostPageStore` holds and :meth:`_restore_page` writes
        back. Pages-axis indexing mirrors the pool layouts: scanned
        stacks carry a leading periods axis."""

        def grab(c, scanned):
            a = c["attn"]
            idx = (
                (slice(None), slice(None), pid) if scanned
                else (slice(None), pid)
            )
            out = {
                "k": np.asarray(a["k_pages"][idx]),
                "v": np.asarray(a["v_pages"][idx]),
            }
            if "k_scales" in a:
                out["ks"] = np.asarray(a["k_scales"][..., pid])
                out["vs"] = np.asarray(a["v_scales"][..., pid])
            return out

        return {
            "scanned": tuple(grab(c, True) for c in self.caches["scanned"]),
            "rem": tuple(grab(c, False) for c in self.caches["rem"]),
        }

    # -- prefill -----------------------------------------------------------

    @staticmethod
    def _prefix_page_bucket(pages: int) -> int:
        """Bucket a live prefix page count to the next power of two: the
        page-table width is a jit constant, so bucketing bounds tail-
        prefill compilations at O(log smax) under diverse prefix lengths
        (the live length stays dynamic via ``prefix_len``)."""
        if pages <= 0:
            return 0
        return 1 << (pages - 1).bit_length()

    def _prefill_paged_fn(self, bucket: int, prefix_pages: int, rows: int = 1):
        """Jitted tail prefill, keyed by (tail bucket, prefix-page bucket,
        admitted rows) — ``rows > 1`` is the batched-admission launch: the
        admitted sequences stack on the batch axis of one call.

        The nonzero-prefix variant runs the **extend phase**: one
        backend-resolved ``AttentionPlan`` per key drives the paged
        prefill kernel, which reads prefix K/V straight from the page
        table — the pool tensors ride in as arguments, never gathered to
        dense.
        """
        key = (bucket, prefix_pages, rows)
        if key not in self._prefill_p:
            cfg = self.cfg

            if prefix_pages == 0:
                def f(params, tokens, last_positions):
                    return transformer.prefill(
                        params, cfg, tokens, cache_len=bucket,
                        last_positions=last_positions,
                    )
            else:
                plan = plan_lib.plan_for_config(
                    cfg,
                    (rows, cfg.n_heads, cfg.n_kv_heads, bucket,
                     prefix_pages * self.page_size + bucket, cfg.head_dim),
                    phase=plan_lib.EXTEND, kv_layout=plan_lib.PAGED,
                    page_size=self.page_size, prefix_pages=prefix_pages,
                    kv_dtype=self.kv_dtype,
                )

                def f(params, tokens, last_positions, caches, page_table,
                      prefix_len):
                    return transformer.prefill(
                        params, cfg, tokens, cache_len=bucket,
                        last_positions=last_positions,
                        prefix_caches=caches, page_table=page_table,
                        prefix_len=prefix_len, plan=plan,
                    )

            self._prefill_p[key] = jax.jit(f)
        return self._prefill_p[key]

    # -- admission ---------------------------------------------------------

    def _make_room(self, pages_needed: int) -> bool:
        """Free pages until ``pages_needed`` fit: evict idle prefix-cache
        pages first (pure capacity — with a host tier their content
        demotes instead of being lost, so nothing recomputes either way),
        then report whether the caller should preempt."""
        short = pages_needed - self.pool.free_pages
        if short > 0 and len(self.prefix):
            on_evict = self._demote_entry if self.host is not None else None
            self.stats["prefix_evictions"] += self.prefix.evict(
                short, on_evict=on_evict
            )
            short = pages_needed - self.pool.free_pages
        return short <= 0

    def _demote_entry(self, h: bytes, pid: int) -> None:
        """Prefix-eviction hook: copy the page's payload host-side before
        the device page frees. Runs under pool pressure only (cold pages:
        prefix-cache LRU tail — which includes preempted and finished
        sequences' published prefixes)."""
        if self.host.admit(h, self._page_payload(pid)):
            self.stats["demoted_pages"] += 1

    def _promote_chain(self, hashes) -> List[int]:
        """Continue a device prefix miss into the host tier: restore the
        longest host-resident run of ``hashes`` into freshly allocated
        device pages and publish them to the device prefix cache, so the
        caller extends off them exactly as if they had never left.
        Residency stays exclusive: :meth:`HostPageStore.take` consumes
        the host copy as each device page lands. Stops early (keeping
        what it restored evictable) when the pool cannot free a page."""
        run = self.host.lookup_chain(hashes)
        pids: List[int] = []
        for h in run:
            if not self._make_room(1):
                break
            try:
                pid = self.pool.alloc()
            except OutOfPages:
                break
            if h not in self.host:
                # _make_room's own demotions overflowed the host LRU onto
                # this very entry: the run is broken, stop cleanly.
                self.pool.decref(pid)
                break
            payload = self.host.take(h)
            self.caches = self._restore_jit(
                self.caches, payload, jnp.asarray(pid, jnp.int32)
            )
            self.prefix.insert([h], [pid])
            self.pool.decref(pid)  # the prefix cache owns it now
            pids.append(pid)
            self.stats["promoted_pages"] += 1
        return pids

    def _reserve(self, num_tokens: int, matched) -> Optional[SequencePages]:
        """Page-table reservation for one admission attempt: pin the matched
        prefix pages (lookup takes no references, and ``_make_room``'s
        prefix eviction would otherwise be free to recycle exactly these
        pages — they look idle until the sequence increfs them), make room,
        allocate. Returns None when the pool cannot satisfy it."""
        for p in matched:
            self.pool.incref(p)
        try:
            need = self.pool.pages_needed(num_tokens) - len(matched)
            if not self._make_room(need + self.reserve_pages):
                return None
            try:
                return self.pool.allocate_sequence(
                    num_tokens, shared_prefix=matched
                )
            except OutOfPages:
                return None
        finally:
            for p in matched:
                self.pool.decref(p)

    def try_admit(self, req, resume_tokens: Sequence = (),
                  pending_hashes=()):
        """Reserve a decode row and pages for a request; no prefill yet.

        Prefix-cache lookup happens first: shared full pages are reused
        (prefilled once, by whoever computed them) and only the tail is
        prefilled — through the paged prefill kernel, which reads the
        prefix straight from its pages. The match then continues
        block-level through pages a record admitted earlier in the
        *same* round will publish at flush (the borrower shares those
        in-flight pages instead of re-prefilling them; :meth:`flush`
        orders its launch after the publisher's), and finally into the
        host tier, promoting the longest demoted run back into fresh
        device pages. Returns an admission record for :meth:`flush`;
        None when the pool/rows cannot hold the request.
        ``pending_hashes`` is accepted for protocol compatibility but
        unused — the backend's own in-flight page map is authoritative.
        The row is claimed here (so subsequent admissions in the same
        round see it taken); the caller must flush before the next
        decode tick.

        ``resume_tokens``: tokens a preempted run of this request already
        generated. They are replayed through the same extend path (they
        are just more prompt from the cache's point of view), so decode
        resumes mid-stream instead of restarting from scratch.
        """
        free_rows = np.flatnonzero(~self.active)
        if len(free_rows) == 0:
            return None
        tok = np.asarray(req.prompt)
        if tok.ndim != 1:
            raise ValueError("paged backend expects flat token prompts")
        orig_n = len(tok)
        if len(resume_tokens):
            tok = np.concatenate(
                [tok, np.asarray([int(t) for t in resume_tokens], tok.dtype)]
            )
        n = len(tok)
        ps = self.page_size
        total_pages = self.pool.pages_needed(n)
        if total_pages > self.max_pages_per_seq:
            raise ValueError(
                f"prompt needs {total_pages} pages > "
                f"max_pages_per_seq {self.max_pages_per_seq}"
            )
        self.validate(req)

        if not self.prefix_sharing:
            hashes = []
        elif len(resume_tokens):
            hashes = page_hashes(tok, ps)  # replay extends the stream
        else:
            hashes = req.page_hashes(ps)   # memoized on the request
        # Reuse at most (n-1)//ps pages: at least one tail token must be
        # prefilled here to produce the next-token logits.
        limit = (n - 1) // ps
        matched = self.prefix.lookup(hashes[:limit])
        after: set = set()
        if self.prefix_sharing:
            # Continue block-level through same-round in-flight pages: the
            # borrower increfs the publisher's pages and records the
            # dependency so flush publishes before it extends.
            inflight = 0
            while (len(matched) < limit
                   and hashes[len(matched)] in self._pending_pages):
                pid, owner = self._pending_pages[hashes[len(matched)]]
                matched.append(pid)
                after.add(owner)
                inflight += 1
            self.stats["inflight_pages_reused"] += inflight
            # ... and finally into the host tier: promote the longest
            # demoted run back into fresh device pages.
            if self.host is not None and len(matched) < limit:
                matched.extend(
                    self._promote_chain(hashes[len(matched):limit])
                )

        # Validate the prefill bucket before touching the allocator (a late
        # ValueError must not leak pages).
        if not self.fits_buckets(n - len(matched) * ps):
            if len(resume_tokens):
                # A replay tail no bucket holds: drop replayed tokens until
                # it fits (decode regenerates them exactly — the sampler is
                # keyed per request and stream position). The prefix match
                # for a truncated sequence is the full match capped at its
                # page count, so the fit is computable without re-hashing;
                # keep the longest replay that fits.
                m_full = len(matched)
                for keep in range(len(resume_tokens) - 1, -1, -1):
                    nk = orig_n + keep
                    mk = min(m_full, (nk - 1) // ps)
                    if self.fits_buckets(nk - mk * ps):
                        return self.try_admit(
                            req, list(resume_tokens)[:keep], pending_hashes
                        )
                # Not even the bare prompt fits (its prefix pages were
                # evicted since first admission): fall through to the
                # admission error below.
            raise ValueError(
                f"prompt tail {n - len(matched) * ps} exceeds buckets "
                f"{self.prompt_buckets}"
            )
        seq = self._reserve(n, matched)
        if seq is None and matched and self.fits_buckets(n):
            # Reuse blocked admission (the pinned prefix pages were the only
            # evictable capacity): fall back to prefilling from scratch so a
            # servable request is never starved by its own cached prefix.
            # Prompts only servable *through* reuse stay queued instead
            # (pages free up as sequences finish).
            matched = []
            after = set()
            seq = self._reserve(n, matched)
        if seq is None:
            return None
        m = len(matched)
        tail = tok[m * ps :]
        bucket = self._bucket_for(len(tail))
        self.stats["pages_reused"] += m
        self.stats["prompt_pages"] += total_pages

        # Claim the decode row now — pages and row are spoken for; the
        # prefill itself runs at flush time.
        row = int(free_rows[0])
        self.seqs[row] = _SeqState(
            req=req, pages=seq, submit_order=self._submit_counter
        )
        self._submit_counter += 1
        self.page_table[row] = NULL_PAGE
        self.page_table[row, : len(seq.pages)] = seq.pages
        self.lengths[row] = n
        self.active[row] = True
        self.out[row] = list(resume_tokens)
        self.stats["resumed_tokens"] += len(resume_tokens)
        if self.prefix_sharing:
            # Expose the fresh full pages this record will prefill for
            # same-round block-level sharing (matched ones are already
            # published, pending, or just promoted).
            for i in range(m, n // ps):
                self._pending_pages[hashes[i]] = (seq.pages[i], req.uid)
        return {
            "req": req, "row": row, "seq": seq, "matched": matched,
            "tail": tail, "bucket": bucket, "n": n, "hashes": hashes,
            "mb": self._prefix_page_bucket(m) if m else 0,
            "pending_publish": tuple(hashes[: n // ps]),
            "after": frozenset(after),
        }

    def flush(self, records) -> Dict[int, np.ndarray]:
        """Run the admitted records' tail prefills in **dependency
        waves**: a borrower of same-round in-flight pages launches
        strictly after every record it borrows from has scattered and
        published (its ``after`` uid set), so an extend never reads pages
        whose contents this same flush still owes. Dependencies always
        point to earlier admissions, so the partition terminates.
        Within a wave, one launch per shared (tail-bucket,
        prefix-page-bucket) jit key with the admitted rows stacked on the
        batch axis (``batch_prefills=False`` launches one row at a time —
        the bit-exactness oracle in tests). Returns per-row last-position
        logits."""
        first_logits: Dict[int, np.ndarray] = {}
        todo = list(records)
        published: set = set()
        while todo:
            wave = [
                r for r in todo if r.get("after", frozenset()) <= published
            ]
            if not wave:  # unreachable by construction; never deadlock
                wave = list(todo)
            done = {id(r) for r in wave}
            todo = [r for r in todo if id(r) not in done]
            self._flush_wave(wave, first_logits)
            published.update(r["req"].uid for r in wave)
        # Everything admitted this round is now published (or matched):
        # later rounds share through the prefix cache proper.
        self._pending_pages.clear()
        return first_logits

    def _flush_wave(self, records, first_logits: Dict[int, np.ndarray]):
        """One dependency wave of :meth:`flush`: group by jit key, run
        the tail prefills, scatter each row's K/V into its fresh pages,
        publish full pages to the prefix cache. The paged prefill kernel
        takes per-row ``prefix_len`` / ``tail_len``, so rows with
        different live lengths share a launch; rows are independent
        (per-row page tables, per-row online softmax), so outputs are
        bit-exact regardless of batching."""
        ps = self.page_size
        groups: Dict[Tuple[int, int], list] = {}
        if self.batch_prefills:
            for rec in records:
                groups.setdefault((rec["bucket"], rec["mb"]), []).append(rec)
        else:
            for i, rec in enumerate(records):
                groups[(rec["bucket"], rec["mb"], i)] = [rec]
        for (bucket, mb, *_), grp in groups.items():
            rows = len(grp)
            padded = np.stack(
                [np.pad(r["tail"], (0, bucket - len(r["tail"]))) for r in grp]
            )
            last = jnp.asarray(
                [len(r["tail"]) - 1 for r in grp], jnp.int32
            )
            self.stats["prefill_launches"] += 1
            self.stats["batched_prefills"] += rows > 1
            if mb == 0:
                logits, tail_caches = self._prefill_paged_fn(bucket, 0, rows)(
                    self.params, jnp.asarray(padded), last
                )
            else:
                # Extend phase: each page-table row is padded to the
                # power-of-two page bucket with null pages (the kernel
                # masks them via the dynamic prefix_len), so every prefix
                # length in a bucket shares one compilation — and the pool
                # is consumed in place, no gather.
                pt = np.full((rows, mb), NULL_PAGE, np.int32)
                for i, r in enumerate(grp):
                    pt[i, : len(r["matched"])] = r["matched"]
                plens = jnp.asarray(
                    [len(r["matched"]) * ps for r in grp], jnp.int32
                )
                self.stats["extend_prefills"] += rows
                logits, tail_caches = self._prefill_paged_fn(bucket, mb, rows)(
                    self.params, jnp.asarray(padded), last, self.caches,
                    jnp.asarray(pt), plens,
                )
            # Scatter every row's tail K/V into its fresh pages (buckets
            # are page-aligned; destinations beyond a tail's real pages
            # sink into the null page).
            pids = np.full((rows, bucket // ps), NULL_PAGE, np.int32)
            for i, r in enumerate(grp):
                tail_pages = r["seq"].pages[len(r["matched"]):]
                pids[i, : len(tail_pages)] = tail_pages
            self.caches = self._scatter_jit(
                self.caches, tail_caches, jnp.asarray(pids)
            )
            logits_np = np.asarray(logits)
            for i, r in enumerate(grp):
                # Publish this prompt's full pages for later requests.
                if self.prefix_sharing:
                    nfull = r["n"] // ps
                    if self.host is not None:
                        # A freshly prefilled page supersedes any host
                        # copy under the same hash (the content is hash-
                        # determined): drop it so residency stays
                        # exclusive — device OR host, never both.
                        for h in r["hashes"][:nfull]:
                            self.host.discard(h)
                    self.prefix.insert(
                        r["hashes"][:nfull], r["seq"].pages[:nfull]
                    )
                first_logits[r["row"]] = logits_np[i]

    # -- preemption / decode ----------------------------------------------

    def _preempt_one(self, protect: int) -> bool:
        """Evict one active sequence — which one is the injected
        ``choose_victim`` policy's call (lowest priority, newest by
        default) — and report it through ``on_preempt`` so the scheduler
        requeues it with its generated-so-far tokens (replayed through the
        extend path on re-admission); never the row ``protect``."""
        candidates = [
            (s.req.priority, s.submit_order, row)
            for row, s in enumerate(self.seqs)
            if s is not None and self.active[row] and row != protect
        ]
        row = self.choose_victim(candidates, protect) if candidates else None
        if row is None:
            return False
        state = self.seqs[row]
        self.stats["preemptions"] += 1
        self.pool.release(state.pages)
        generated = list(self.out[row])
        self.active[row] = False
        self.seqs[row] = None
        self.page_table[row] = NULL_PAGE
        self.lengths[row] = 0
        self.out[row] = []
        self.on_preempt(row, state.req, generated)
        return True

    def reserve_rows(self, n_steps: int) -> None:
        """Reserve every active row's next ``min(n_steps, remaining)``
        token slots before a fused scan launches, preempting other rows
        under pool pressure (same retry policy as :meth:`prepare_row`,
        amortized over the whole sync). COW copies surface here — the
        scan itself never touches a shared page. Over-reserved slots
        (early stop) return to the pool in :meth:`commit_scan`."""
        for row in range(self.rows):
            if not self.active[row]:
                continue
            state = self.seqs[row]
            # Remaining output budget bounds the reservation: a scan never
            # writes past max_tokens, so never past validate()'s cap.
            remaining = state.req.max_new_tokens - len(self.out[row])
            target = state.pages.length + min(n_steps, max(remaining, 1))
            cows: List[Tuple[int, int]] = []
            while state.pages.length < target:
                try:
                    self.pool.reserve_tokens(
                        state.pages, target - state.pages.length, cows
                    )
                except OutOfPages:
                    # Partial progress is kept (seq.length advanced, COWs
                    # in ``cows``); free room and re-request the rest.
                    if not (self._make_room(1) or self._preempt_one(row)):
                        raise OutOfPages(
                            "pool exhausted and nothing left to preempt"
                        )
            for src, dst in cows:
                self.stats["cow_copies"] += 1
                self.caches = self._copy_jit(
                    self.caches, jnp.asarray(src, jnp.int32),
                    jnp.asarray(dst, jnp.int32),
                )
            self.page_table[row] = NULL_PAGE
            self.page_table[row, : len(state.pages.pages)] = state.pages.pages

    def commit_scan(self, new_lengths: np.ndarray) -> None:
        """Trim each live row's reservation down to what the scan actually
        consumed (its final length) and rebuild the page tables; unused
        reserved pages go straight back on the free list."""
        for row in range(self.rows):
            state = self.seqs[row]
            if state is None or not self.active[row]:
                continue
            want = int(new_lengths[row])
            if want < state.pages.length:
                self.pool.trim_tokens(state.pages, want)
            self.page_table[row] = NULL_PAGE
            self.page_table[row, : len(state.pages.pages)] = state.pages.pages
        self.lengths = np.array(new_lengths, dtype=self.lengths.dtype)

    @property
    def sync_reserve_pages(self) -> int:
        """Decode headroom the scheduler must price at admission: with N
        fused steps per sync, every live row (plus the candidate) can
        grow ceil(N / page_size) pages before the host next intervenes —
        a scan must never run out of pages mid-flight."""
        n = self.steps_per_sync
        if n <= 1:
            return self.reserve_pages
        per_row = -(-n // self.page_size)
        return self.reserve_pages + per_row * (self.num_active + 1)

    def prepare_row(self, row: int) -> None:
        """Reserve the next token's slot in row's page table, preempting
        others if the pool is exhausted mid-decode."""
        state = self.seqs[row]
        while True:
            try:
                _, _, cow = self.pool.append_token(state.pages)
                break
            except OutOfPages:
                if not (self._make_room(1) or self._preempt_one(row)):
                    raise OutOfPages(
                        "pool exhausted and nothing left to preempt"
                    )
        if cow is not None:
            src, dst = cow
            self.stats["cow_copies"] += 1
            # Traced page ids: one jitted copy program serves every pair.
            self.caches = self._copy_jit(
                self.caches, jnp.asarray(src, jnp.int32),
                jnp.asarray(dst, jnp.int32),
            )
        if state.pages.num_pages() > self.max_pages_per_seq:
            raise ValueError(
                f"sequence {state.req.uid} outgrew max_pages_per_seq="
                f"{self.max_pages_per_seq}; cap prompt+max_new_tokens at "
                f"{self.cache_len} tokens"
            )
        self.page_table[row] = NULL_PAGE
        self.page_table[row, : len(state.pages.pages)] = state.pages.pages

    def decode(self, tok: np.ndarray):
        self.lengths = self.lengths + self.active.astype(np.int32)
        logits, self.caches = self._decode(
            self.params, jnp.asarray(tok), self.caches,
            jnp.asarray(self.lengths), jnp.asarray(self.page_table),
        )
        return logits

    def row_req(self, row: int):
        return self.seqs[row].req

    def release(self, row: int) -> None:
        state = self.seqs[row]
        if state is None:
            # Double release used to AttributeError (or, worse, silently
            # pass once pool.release no-op'd); surface it as the typed
            # pool error so the sanitizer and callers see one family.
            raise SequenceReleasedError(
                f"release of row {row}, which holds no sequence"
            )
        # Pages the prefix cache references survive; the rest free now.
        self.pool.release(state.pages)
        self.active[row] = False
        self.seqs[row] = None
        self.page_table[row] = NULL_PAGE
        self.lengths[row] = 0

    # -- teardown / invariants ---------------------------------------------

    def live_page_refs(self) -> Dict[int, int]:
        """Pool references this backend can account for: one per live
        sequence page-table entry plus one per prefix-cache entry. The
        pool's refcounts must equal exactly this at any quiescent point."""
        refs: Dict[int, int] = {}
        for state in self.seqs:
            if state is None:
                continue
            for pid in state.pages.pages:
                refs[pid] = refs.get(pid, 0) + 1
        for pid in self.prefix.pages():
            refs[pid] = refs.get(pid, 0) + 1
        return refs

    def check_leaks(self, raise_on_leak: bool = True):
        """Audit the pool against :meth:`live_page_refs`; raises
        :class:`repro.cache.pool.RefcountLeakError` on any page whose
        refcount the live rows + prefix cache cannot explain."""
        return self.pool.check_leaks(
            self.live_page_refs(), raise_on_leak=raise_on_leak
        )

    def shutdown(self) -> None:
        """Teardown: release every live row, drain the prefix cache, then
        prove the pool is fully free. A leak here means some path dropped
        a SequencePages without releasing it."""
        for row in range(self.rows):
            if self.seqs[row] is not None:
                self.release(row)
        self.prefix.drain()
        if self.host is not None:
            self.host.drain()
        self._pending_pages.clear()
        self.pool.check_leaks()

    # -- introspection -----------------------------------------------------

    @property
    def mapping(self):
        """Resolved decode-shape schedule (decode & window are part of the
        plan key, so this differs from the prefill resolution)."""
        return self.decode_plan().mapping

    def decode_plan(self) -> plan_lib.AttentionPlan:
        """The resolved steady-state decode plan, scored jointly over
        (domain, device) when this backend runs on a mesh — exposes
        ``num_splits`` / ``split_device_pure`` for stats and tests."""
        return plan_lib.plan_for_config(
            self.cfg,
            (self.rows, self.cfg.n_heads, self.cfg.n_kv_heads,
             1, self.cache_len, self.cfg.head_dim),
            phase=plan_lib.DECODE, kv_layout=plan_lib.PAGED,
            page_size=self.page_size, num_devices=self.num_devices,
            kv_dtype=self.kv_dtype,
        )

    def modeled_kv_layout(self) -> str:
        """What the analytic model would pick for this backend's steady
        state (paged head-aligned vs interleaved vs dense stripes)."""
        live = self.lengths[self.active]
        mean_len = int(live.mean()) if live.size else self.cache_len // 2
        return plan_lib.resolve_kv_layout(
            (self.rows, self.cfg.n_heads, self.cfg.n_kv_heads,
             max(mean_len, 1), self.cfg.head_dim),
            capacity=self.cache_len,
            page_size=self.page_size,
            dtype_bytes=self._kv_dtype_bytes,
        )

    def prefix_stats(self) -> Dict[str, object]:
        reused = self.stats["pages_reused"]
        total = self.stats["prompt_pages"]
        pc = self.prefix.counters()
        return {
            "prefix_entries": float(len(self.prefix)),
            "pages_reused": float(reused),
            "prompt_pages": float(total),
            "prefix_hit_rate": reused / total if total else 0.0,
            "prefix_lookup_hits": float(pc["hits"]),
            "prefix_lookup_queries": float(pc["queries"]),
            "prefix_evictions": float(pc["evictions"]),
            "preemptions": float(self.stats["preemptions"]),
            "resumed_tokens": float(self.stats["resumed_tokens"]),
            "extend_prefills": float(self.stats["extend_prefills"]),
            "prefill_launches": float(self.stats["prefill_launches"]),
            "batched_prefills": float(self.stats["batched_prefills"]),
            "cow_copies": float(self.stats["cow_copies"]),
            "free_pages": float(self.pool.free_pages),
            "inflight_pages_reused": float(
                self.stats["inflight_pages_reused"]
            ),
            "kv_dtype": self.kv_dtype,
            "kv_pool_bytes": float(self.kv_pool_bytes()),
            "demoted_pages": float(self.stats["demoted_pages"]),
            "promoted_pages": float(self.stats["promoted_pages"]),
            "host_entries": (
                float(len(self.host)) if self.host is not None else 0.0
            ),
            "host_bytes_resident": (
                float(self.host.bytes_resident)
                if self.host is not None else 0.0
            ),
        }
