"""On-device batched sampling for the serving engine.

One jitted call samples every decode row of a tick at once, with
*per-row* sampling params (the pre-PR-5 engines sampled on the host, one
row at a time, greedy-or-temperature only):

  * ``temperature == 0`` rows take the exact ``argmax`` branch — bitwise
    identical to the host ``np.argmax`` the old engines used, which is
    what keeps the facade's greedy outputs bit-matching the pre-refactor
    engines;
  * ``temperature > 0`` rows are softmax-sampled after top-k and top-p
    (nucleus) filtering. Top-p keeps the smallest prefix of the sorted
    distribution whose mass reaches ``top_p`` (the boundary token that
    crosses the mass is included; ties at the cutoff probability are all
    kept);
  * the RNG is keyed **per request**, not per tick: row key =
    ``fold_in(fold_in(PRNGKey(seed), stream_pos), codebook)`` where
    ``stream_pos`` is how many tokens the request has generated so far.
    A request's sample stream therefore does not depend on which batch
    rows it shares a tick with, and resume-after-preemption continues the
    stream exactly.

Multi-codebook (MusicGen-style) logits ``(B, K, V)`` sample one token per
codebook with a codebook-distinct key.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: Floor applied to positive temperatures only (temperature == 0 never
#: reaches the stochastic branch); keeps the scale finite under jit.
_MIN_TEMPERATURE = 1e-6


def _sample_one(logits, temperature, top_k, top_p, key):
    """Sample one token from one row's ``(V,)`` logits."""
    v = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    scaled = logits.astype(jnp.float32) / jnp.maximum(
        temperature, _MIN_TEMPERATURE
    )
    # Top-k: keep the k largest logits (0 disables). The threshold is the
    # k-th largest value; ties with it survive.
    k = jnp.where(top_k <= 0, v, jnp.clip(top_k, 1, v))
    desc = jnp.sort(scaled)[::-1]
    kth = desc[jnp.clip(k - 1, 0, v - 1)]
    masked = jnp.where(scaled < kth, -jnp.inf, scaled)
    # Top-p over the k-filtered distribution: walking the sorted probs,
    # a token is kept while the mass *before* it is < top_p — the smallest
    # set whose mass reaches top_p, boundary token included.
    probs = jax.nn.softmax(masked)
    p_desc = jnp.sort(probs)[::-1]
    csum = jnp.cumsum(p_desc)
    keep = (csum - p_desc) < top_p
    cutoff = p_desc[jnp.maximum(jnp.sum(keep) - 1, 0)]
    masked = jnp.where(probs < cutoff, -jnp.inf, masked)

    sampled = jax.random.categorical(key, masked).astype(jnp.int32)
    return jnp.where(temperature <= 0, greedy, sampled)


@jax.jit
def _sample_batch(logits, temperature, top_k, top_p, seed, stream_pos):
    """Batched sampler: ``logits`` ``(B, V)`` or ``(B, K, V)`` ->
    ``(B,)`` / ``(B, K)`` int32 tokens. All param arrays are ``(B,)``."""

    def row_key(s, pos):
        return jax.random.fold_in(jax.random.PRNGKey(s), pos)

    keys = jax.vmap(row_key)(seed, stream_pos)
    if logits.ndim == 2:
        return jax.vmap(_sample_one)(logits, temperature, top_k, top_p, keys)

    b, num_codebooks, _ = logits.shape

    def row(lg, t, k, p, key):
        cb_keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
            key, jnp.arange(num_codebooks)
        )
        return jax.vmap(_sample_one, in_axes=(0, None, None, None, 0))(
            lg, t, k, p, cb_keys
        )

    return jax.vmap(row)(logits, temperature, top_k, top_p, keys)


def sample_tokens(logits, temperature, top_k, top_p, seed, stream_pos):
    """Sample next tokens for a batch of rows with per-row params.

    ``logits``: ``(B, V)`` float (or ``(B, K, V)`` multi-codebook).
    ``temperature``/``top_p``: ``(B,)`` float; ``top_k``/``seed``/
    ``stream_pos``: ``(B,)`` int. Returns int32 ``(B,)`` (or ``(B, K)``).
    Rows with ``temperature <= 0`` are exact argmax and consume no
    randomness.
    """
    return _sample_batch(
        jnp.asarray(logits),
        jnp.asarray(temperature, jnp.float32),
        jnp.asarray(top_k, jnp.int32),
        jnp.asarray(top_p, jnp.float32),
        jnp.asarray(seed, jnp.int32),
        jnp.asarray(stream_pos, jnp.int32),
    )
