"""repro: NUMA-aware attention scheduling (Swizzled Head-first Mapping) in
JAX/Pallas — multi-pod training + serving framework. See DESIGN.md."""

__version__ = "1.0.0"
