"""repro subpackage."""
