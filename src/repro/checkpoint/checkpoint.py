"""Sharded, atomic, async checkpointing with reshard-on-restore.

Layout (one directory per step):

    <root>/step_000123.tmp-<nonce>/   # written first
        manifest.json                 # tree structure, shapes, dtypes, meta
        <leaf-id>.shard<k>.npy        # one file per addressable shard
    <root>/step_000123/               # atomic rename when complete

Fault-tolerance properties:
  * atomicity — a checkpoint is visible iff its rename committed; crashes
    mid-write leave only .tmp dirs, which restore ignores and gc removes,
  * integrity — manifest carries per-file sizes; restore verifies,
  * multi-host — each process writes only its addressable shards; shard
    files are keyed by global index so any process count can restore,
  * elasticity — restore() takes target shardings: arrays are assembled
    from shard files and re-placed, so a 512-chip checkpoint restores onto
    any divisor mesh (see distributed/elastic.py),
  * async — save() can run in a background thread (the arrays are first
    device_get'd synchronously, then written without blocking the step).

The data-pipeline position and trainer bookkeeping ride in manifest[meta].
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np

_FLAT_SEP = "/"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _FLAT_SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = leaf
    return flat


def _save_arrays(flat: Dict[str, Any], directory: str, manifest: dict):
    for key, leaf in flat.items():
        safe = key.replace("/", "__")
        entries = []
        if isinstance(leaf, jax.Array) and hasattr(leaf, "addressable_shards"):
            shards = leaf.addressable_shards
            for sh in shards:
                fname = f"{safe}.shard{sh.index_str if hasattr(sh, 'index_str') else _idx_str(sh.index)}.npy"
                arr = np.asarray(sh.data)
                np.save(os.path.join(directory, fname), arr)
                entries.append(
                    {"file": fname, "index": _idx_json(sh.index), "shape": arr.shape}
                )
        else:
            arr = np.asarray(leaf)
            fname = f"{safe}.shard_full.npy"
            np.save(os.path.join(directory, fname), arr)
            entries.append({"file": fname, "index": None, "shape": arr.shape})
        manifest["leaves"][key] = {
            "dtype": str(np.asarray(jax.device_get(leaf)).dtype)
            if not isinstance(leaf, jax.Array)
            else str(leaf.dtype),
            "shape": list(leaf.shape),
            "shards": entries,
        }


def _idx_str(index) -> str:
    return "_".join(
        f"{s.start if s.start is not None else 0}-{s.stop if s.stop is not None else -1}"
        for s in index
    ) or "scalar"


def _idx_json(index):
    return [
        [s.start if s.start is not None else 0, s.stop if s.stop is not None else -1]
        for s in index
    ]


def save(
    root: str,
    step: int,
    tree,
    *,
    meta: Optional[dict] = None,
    async_write: bool = False,
    keep_last: int = 3,
) -> str:
    """Write a checkpoint; returns the final directory path."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    # Pull to host synchronously (cheap view for CPU; device DMA on TPU).
    flat = {k: jax.device_get(v) if not isinstance(v, jax.Array) else v
            for k, v in _flatten(tree).items()}

    def write():
        tmp = tempfile.mkdtemp(prefix=f"step_{step:08d}.tmp-", dir=root)
        manifest = {"step": step, "meta": meta or {}, "leaves": {},
                    "time": time.time()}
        try:
            _save_arrays(flat, tmp, manifest)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            _gc(root, keep_last)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return final
    write()
    return final


def _gc(root: str, keep_last: int):
    steps = sorted(d for d in os.listdir(root) if d.startswith("step_") and ".tmp" not in d)
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, d), ignore_errors=True)
    for d in os.listdir(root):
        if ".tmp-" in d:
            # stale partial writes from crashed processes
            age = time.time() - os.path.getmtime(os.path.join(root, d))
            if age > 3600:
                shutil.rmtree(os.path.join(root, d), ignore_errors=True)


def latest_step(root: str) -> Optional[int]:
    if not os.path.isdir(root):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(root)
        if d.startswith("step_") and ".tmp" not in d
        and os.path.exists(os.path.join(root, d, "manifest.json"))
    ]
    return max(steps) if steps else None


def restore(
    root: str,
    tree_like,
    *,
    step: Optional[int] = None,
    shardings=None,
) -> Any:
    """Restore into the structure of ``tree_like``; optionally re-shard.

    ``shardings``: tree of jax.sharding.Sharding matching tree_like — arrays
    are placed accordingly (elastic restore onto a different mesh).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like = _flatten(tree_like)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    out = {}
    for key, like in flat_like.items():
        entry = manifest["leaves"][key]
        full = np.zeros(entry["shape"], dtype=entry["dtype"])
        for sh in entry["shards"]:
            arr = np.load(os.path.join(d, sh["file"]))
            if sh["index"] is None:
                full = arr
            else:
                idx = tuple(
                    slice(a, None if b == -1 else b) for a, b in sh["index"]
                )
                full[idx] = arr
        if key in flat_shard and flat_shard[key] is not None:
            out[key] = jax.device_put(full, flat_shard[key])
        else:
            out[key] = jax.numpy.asarray(full)
    # Rebuild the original structure.
    paths_leaves = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path, _ in paths_leaves[0]:
        key = _FLAT_SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(out[key])
    restored = jax.tree_util.tree_unflatten(paths_leaves[1], leaves)
    return restored, manifest["meta"], step
