"""JAX version / backend compatibility layer.

Every JAX API whose surface has churned across the versions this repo
supports is funnelled through here, so a JAX bump is a one-file change:

  * ``tpu_compiler_params(...)`` — Pallas TPU compiler params. Newer JAX
    exposes ``pltpu.CompilerParams`` and a ``GridDimensionSemantics`` enum;
    0.4.x exposes ``pltpu.TPUCompilerParams`` taking the literal strings
    ``"parallel"`` / ``"arbitrary"``. Callers always pass the string
    constants :data:`PARALLEL` / :data:`ARBITRARY`; this shim converts to
    whatever the installed JAX wants.
  * ``make_mesh(...)`` — ``jax.make_mesh`` grew an ``axis_types=`` kwarg
    (with ``jax.sharding.AxisType``) after 0.4.37. Callers pass the string
    names ``"auto"`` / ``"explicit"`` / ``"manual"``; on JAX without axis
    types the kwarg is dropped (0.4.x meshes behave like all-Auto).
  * backend / interpret detection — ``default_backend()`` / ``on_tpu()`` /
    ``use_interpret()`` centralize the "can this host lower Mosaic?" test
    that the kernels, ops dispatch and models previously duplicated.
  * ``enable_compilation_cache(...)`` — the persistent compilation cache
    moved from ``jax.experimental.compilation_cache.initialize_cache`` to
    plain config flags across the supported range; the serving engine calls
    this once so steady-state decode never recompiles across processes.
  * ``donating_jit(...)`` — ``jax.jit`` with ``donate_argnums`` that stays
    quiet on backends where donation is unsupported (CPU XLA warns
    "Some donated buffers were not usable" on every call).

Supported-JAX policy (see ROADMAP.md): oldest supported is 0.4.37 (the
container's pinned toolchain); the shims are written against the 0.5-0.7
renames so a newer host works unmodified. No other module may reference
``CompilerParams`` / ``TPUCompilerParams`` / ``AxisType`` directly —
the ``compat-only-versioned-jax`` linter rule (``repro.analysis.lint``,
run by CI as ``python -m repro.analysis --strict`` and by tier-1 via
``tests/test_mapping_resolver.py``) enforces this over the AST.
"""

from __future__ import annotations

import functools
import inspect
import warnings
from typing import Callable, Optional, Sequence, Tuple

import jax
from jax.experimental.pallas import tpu as pltpu


def _parse_version(v: str) -> Tuple[int, ...]:
    parts = []
    for p in v.split("."):
        if not p.isdigit():
            break
        parts.append(int(p))
    return tuple(parts) or (0,)


JAX_VERSION: Tuple[int, ...] = _parse_version(jax.__version__)

# Grid-dimension semantics, spelled as the lowercase strings the 0.4.x
# dataclass accepts. ``tpu_compiler_params`` upgrades them to the enum on
# newer JAX.
PARALLEL = "parallel"
ARBITRARY = "arbitrary"

# Mesh axis types, spelled as strings; upgraded to jax.sharding.AxisType
# members when the installed JAX has them.
AXIS_AUTO = "auto"
AXIS_EXPLICIT = "explicit"
AXIS_MANUAL = "manual"

# The params dataclass was renamed TPUCompilerParams -> CompilerParams.
_COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)
_DIM_SEMANTICS_ENUM = getattr(pltpu, "GridDimensionSemantics", None)


def _convert_dim_semantics(dims):
    if dims is None:
        return None
    if _DIM_SEMANTICS_ENUM is None:
        # Old JAX: pass the literal strings through (and downgrade any
        # enum-ish values a caller might hand us).
        return tuple(getattr(d, "name", str(d)).lower() for d in dims)
    out = []
    for d in dims:
        if isinstance(d, str):
            d = getattr(_DIM_SEMANTICS_ENUM, d.upper())
        out.append(d)
    return tuple(out)


def tpu_compiler_params(
    *, dimension_semantics: Optional[Sequence] = None, **kwargs
):
    """Build the Pallas TPU compiler-params object for the installed JAX.

    ``dimension_semantics`` entries are the :data:`PARALLEL` /
    :data:`ARBITRARY` strings (enum members also accepted). Remaining
    kwargs (``vmem_limit_bytes``, ...) are forwarded unchanged.
    """
    return _COMPILER_PARAMS_CLS(
        dimension_semantics=_convert_dim_semantics(dimension_semantics),
        **kwargs,
    )


def _axis_type(name):
    axis_type_enum = getattr(jax.sharding, "AxisType", None)
    if axis_type_enum is None:
        return None
    if isinstance(name, axis_type_enum):
        return name
    return {
        AXIS_AUTO: axis_type_enum.Auto,
        AXIS_EXPLICIT: axis_type_enum.Explicit,
        AXIS_MANUAL: axis_type_enum.Manual,
    }[str(name).lower()]


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[Sequence] = None,
    devices=None,
) -> jax.sharding.Mesh:
    """``jax.make_mesh`` that tolerates the ``axis_types`` API gap.

    ``axis_types`` entries are :data:`AXIS_AUTO` / :data:`AXIS_EXPLICIT` /
    :data:`AXIS_MANUAL` strings. On JAX without ``jax.sharding.AxisType``
    (<= 0.4.x) the argument is dropped: those versions have no explicit
    sharding mode, so every axis already behaves like ``Auto``.
    """
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if axis_types is not None:
        sig = inspect.signature(jax.make_mesh)
        if "axis_types" in sig.parameters and _axis_type(axis_types[0]) is not None:
            kwargs["axis_types"] = tuple(_axis_type(t) for t in axis_types)
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def enable_compilation_cache(cache_dir: Optional[str] = None) -> bool:
    """Turn on JAX's persistent compilation cache, best-effort.

    Returns True when a cache directory is active afterwards. The API
    surface moved across the supported range (``initialize_cache(path)``
    on 0.4.x, ``jax.config.update("jax_compilation_cache_dir", ...)`` plus
    threshold flags later), so every path is attempted and failures are
    swallowed: the cache is a steady-state-latency optimization, never a
    correctness dependency.
    """
    active = False
    if cache_dir is not None:
        try:
            jax.config.update("jax_compilation_cache_dir", cache_dir)
            active = True
        except Exception:
            try:
                from jax.experimental.compilation_cache import (
                    compilation_cache as _cc,
                )
                _cc.initialize_cache(cache_dir)
                active = True
            except Exception:
                pass
    else:
        active = getattr(
            jax.config, "jax_compilation_cache_dir", None
        ) is not None
    # Cache even tiny/fast compilations (the decode scan body is small on
    # CPU CI but the retrace guarantee must still be exercised there).
    for flag, value in (
        ("jax_persistent_cache_min_compile_time_secs", 0.0),
        ("jax_persistent_cache_min_entry_size_bytes", -1),
    ):
        try:
            jax.config.update(flag, value)
        except Exception:
            pass
    return active


def donating_jit(
    fn: Callable,
    *,
    donate_argnums: Sequence[int] = (),
    static_argnums: Sequence[int] = (),
) -> Callable:
    """``jax.jit`` with buffer donation, quiet where donation is a no-op.

    On TPU/GPU the donated KV-cache buffers are reused in place (the decode
    scan's carry aliases its input, halving peak HBM for the caches). CPU
    XLA cannot alias them and emits a ``UserWarning`` per call; that
    warning is filtered here so CI logs stay readable — behaviour is
    unchanged either way (donation is an optimization hint).
    """
    jitted = jax.jit(
        fn,
        donate_argnums=tuple(donate_argnums),
        static_argnums=tuple(static_argnums),
    )

    @functools.wraps(fn)
    def call(*args, **kwargs):
        with warnings.catch_warnings():
            warnings.filterwarnings(
                "ignore", message=".*[Dd]onat", category=UserWarning
            )
            return jitted(*args, **kwargs)

    call.jitted = jitted
    return call


def default_backend() -> str:
    """The platform jit lowers to by default: 'tpu' | 'gpu' | 'cpu'."""
    return jax.default_backend()


def on_tpu() -> bool:
    return default_backend() == "tpu"


def use_interpret(backend: Optional[str] = None) -> bool:
    """True when Pallas TPU kernels must run in interpret mode.

    Mosaic lowering exists only on TPU; every other backend (CPU hosts,
    dry-runs, CI) gets the Python interpreter so the same kernel code is
    runnable everywhere.
    """
    return (backend or default_backend()) != "tpu"
