"""The jitted training step: loss, grads, microbatching, optimizer, sharding.

Built for the production mesh:
  * donated (params, opt_state) — in-place buffers at 405B scale,
  * microbatch gradient accumulation (``lax.scan``) so global batch is
    decoupled from per-device memory; the scan also naturally overlaps the
    DP reduce-scatter of microbatch k with the backward of k+1 under XLA
    latency hiding,
  * remat policy on the scanned layer body (set in transformer.forward),
  * optional int8 gradient compression with error feedback
    (optim/grad_compress.py) on the explicitly-reduced path,
  * MoE aux losses folded with configurable coefficients.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import layers, transformer
from repro.optim import adamw, grad_compress


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: adamw.AdamWConfig = adamw.AdamWConfig()
    microbatches: int = 1
    moe_lb_coef: float = 0.01
    moe_z_coef: float = 1e-3
    remat: bool = True
    grad_compression: str = "none"   # "none" | "int8_ef"
    # Kernel-schedule policy for the attention layers: None keeps the model
    # config's own policy; "auto" resolves the NUMA-aware plan per shape
    # (kernels/plan.py); a paper mapping name pins a fixed A/B
    # configuration for ablations.
    attn_mapping: Optional[str] = None


def loss_fn(
    params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
    *, tcfg: TrainConfig, shard_moe=lambda t: t,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = transformer.forward(
        params, cfg, batch["tokens"],
        image_embeds=batch.get("image_embeds"),
        remat=tcfg.remat, shard_moe=shard_moe,
    )
    if cfg.num_codebooks > 1:
        # logits (B,S,K,V); targets (B,S,K)
        loss, metrics = layers.softmax_cross_entropy(
            logits, batch["targets"],
            batch["mask"][..., None] * jnp.ones_like(batch["targets"], jnp.float32),
            z_loss=cfg.z_loss,
        )
    else:
        loss, metrics = layers.softmax_cross_entropy(
            logits, batch["targets"], batch["mask"], z_loss=cfg.z_loss,
        )
    total = (
        loss
        + tcfg.moe_lb_coef * aux["moe_lb_loss"]
        + tcfg.moe_z_coef * aux["moe_z_loss"]
    )
    metrics = dict(metrics)
    metrics.update(
        moe_lb_loss=aux["moe_lb_loss"], moe_dropped=aux["moe_dropped_frac"]
    )
    return total, metrics


def _accumulate_grads(params, cfg, batch, tcfg: TrainConfig, shard_moe):
    """Microbatch scan: mean of grads/metrics over tcfg.microbatches splits."""
    n = tcfg.microbatches
    if n == 1:
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, batch, tcfg=tcfg, shard_moe=shard_moe
        )
        return grads, loss, metrics

    def split(x):
        # Strided split: microbatch m = rows [m::n]. Keeps each microbatch
        # aligned with the contiguous batch sharding (every data shard
        # contributes rows to every microbatch); a plain reshape(n, B//n)
        # would give microbatch m to only B/(n*shard) devices and force XLA
        # to reshard the scan xs.
        b = x.shape[0]
        return x.reshape(b // n, n, *x.shape[1:]).swapaxes(0, 1)

    micro = jax.tree.map(split, batch)

    def body(carry, mb):
        g_acc, l_acc, m_acc = carry
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, cfg, mb, tcfg=tcfg, shard_moe=shard_moe
        )
        g_acc = jax.tree.map(lambda a, b_: a + b_.astype(a.dtype), g_acc, grads)
        m_acc = jax.tree.map(lambda a, b_: a + b_, m_acc, metrics)
        return (g_acc, l_acc + loss, m_acc), None

    zeros_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    zeros_m = {
        "loss": jnp.zeros((), jnp.float32),
        "accuracy": jnp.zeros((), jnp.float32),
        "tokens": jnp.zeros((), jnp.float32),
        "moe_lb_loss": jnp.zeros((), jnp.float32),
        "moe_dropped": jnp.zeros((), jnp.float32),
    }
    (g, loss, metrics), _ = jax.lax.scan(body, (zeros_g, jnp.zeros(()), zeros_m), micro)
    inv = 1.0 / n
    return (
        jax.tree.map(lambda x: x * inv, g),
        loss * inv,
        jax.tree.map(lambda x: x * inv, metrics),
    )


def make_train_step(
    cfg: ModelConfig,
    tcfg: TrainConfig,
    *,
    shard_moe=lambda t: t,
):
    """Returns train_step(state, batch) -> (state, metrics) ready for jit.

    state = {"params": ..., "opt": OptState, "ef": ErrorFeedback|None}
    """
    from repro.kernels import plan as plan_lib

    cfg = plan_lib.with_mapping(cfg, tcfg.attn_mapping)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        grads, loss, metrics = _accumulate_grads(params, cfg, batch, tcfg, shard_moe)
        ef = state.get("ef")
        if tcfg.grad_compression == "int8_ef" and ef is not None:
            grads, ef = grad_compress.compress_with_feedback(grads, ef)
        new_params, new_opt, opt_metrics = adamw.update(
            tcfg.optimizer, params, grads, opt
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss_total"] = loss
        new_state = {"params": new_params, "opt": new_opt}
        if ef is not None:
            new_state["ef"] = ef
        return new_state, metrics

    return train_step


def init_train_state(key, cfg: ModelConfig, tcfg: TrainConfig):
    params = transformer.init_model(key, cfg)
    state = {"params": params, "opt": adamw.init(params, tcfg.optimizer)}
    if tcfg.grad_compression == "int8_ef":
        state["ef"] = grad_compress.init_error_feedback(params)
    return state
