"""Fault-tolerant training loop.

Responsibilities beyond calling train_step:
  * periodic async checkpoints (atomic; data position in meta),
  * auto-resume from the latest valid checkpoint, incl. after mid-step crash,
  * step retry with restore-on-failure (transient-fault recovery: a failed
    collective / preempted host raises; we reload the last checkpoint and
    replay — deterministic data makes the replay exact),
  * straggler watchdog: per-step wall time tracked with an EMA; steps
    exceeding ``deadline_factor``x the EMA are logged and counted (on a real
    pod the hook triggers replica exclusion / re-dispatch; see
    distributed/fault_tolerance.py),
  * throughput metrics (tokens/s, step time).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import checkpoint as ckpt_lib
from repro.distributed.fault_tolerance import StragglerWatchdog, StepFailure

log = logging.getLogger("repro.trainer")


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_async: bool = True
    keep_last: int = 3
    log_every: int = 10
    max_retries: int = 3
    deadline_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        step_fn: Callable,            # (state, batch) -> (state, metrics), jitted
        state: Any,
        pipeline,                     # data pipeline with .batch_at(step)
        cfg: TrainerConfig,
        *,
        put_batch: Callable = lambda b: b,   # host batch -> device arrays
    ):
        self.step_fn = step_fn
        self.state = state
        self.pipeline = pipeline
        self.cfg = cfg
        self.put_batch = put_batch
        self.step = 0
        self.watchdog = StragglerWatchdog(deadline_factor=cfg.deadline_factor)
        self.history: list = []

    # -- checkpoint plumbing ---------------------------------------------

    def try_resume(self) -> bool:
        if not self.cfg.ckpt_dir:
            return False
        latest = ckpt_lib.latest_step(self.cfg.ckpt_dir)
        if latest is None:
            return False
        self.state, meta, _ = ckpt_lib.restore(self.cfg.ckpt_dir, self.state)
        self.step = int(meta.get("data_step", latest))
        log.info("resumed from checkpoint step=%d", self.step)
        return True

    def _save(self):
        if not self.cfg.ckpt_dir:
            return
        ckpt_lib.save(
            self.cfg.ckpt_dir,
            self.step,
            self.state,
            meta={"data_step": self.step},
            async_write=self.cfg.ckpt_async,
            keep_last=self.cfg.keep_last,
        )

    # -- the loop ----------------------------------------------------------

    def run(self, inject_failure: Optional[Callable[[int], None]] = None
            ) -> Dict[str, float]:
        last_metrics: Dict[str, float] = {}
        while self.step < self.cfg.total_steps:
            batch = self.put_batch(self.pipeline.batch_at(self.step))
            retries = 0
            while True:
                t0 = time.time()
                try:
                    if inject_failure is not None:
                        inject_failure(self.step)
                    new_state, metrics = self.step_fn(self.state, batch)
                    jax.block_until_ready(jax.tree.leaves(metrics)[0])
                    break
                except (StepFailure, RuntimeError, jax.errors.JaxRuntimeError) as e:
                    retries += 1
                    log.warning("step %d failed (%s); retry %d", self.step, e, retries)
                    if retries > self.cfg.max_retries:
                        raise
                    # transient-fault recovery: reload last good state
                    if self.cfg.ckpt_dir and ckpt_lib.latest_step(self.cfg.ckpt_dir) is not None:
                        self.state, meta, _ = ckpt_lib.restore(
                            self.cfg.ckpt_dir, self.state
                        )
                        self.step = int(meta.get("data_step", self.step))
                        batch = self.put_batch(self.pipeline.batch_at(self.step))
            dt = time.time() - t0
            self.watchdog.observe(self.step, dt)
            self.state = new_state
            self.step += 1

            last_metrics = {
                k: float(np.asarray(v)) for k, v in metrics.items()
            }
            last_metrics["step_time_s"] = dt
            tokens = last_metrics.get("tokens", 0.0)
            if tokens:
                last_metrics["tokens_per_s"] = tokens / dt
            self.history.append({"step": self.step, **last_metrics})
            if self.step % self.cfg.log_every == 0:
                log.info(
                    "step %d loss=%.4f acc=%.3f %.0f tok/s stragglers=%d",
                    self.step,
                    last_metrics.get("loss", float("nan")),
                    last_metrics.get("accuracy", 0.0),
                    last_metrics.get("tokens_per_s", 0.0),
                    self.watchdog.straggler_count,
                )
            if self.cfg.ckpt_dir and self.step % self.cfg.ckpt_every == 0:
                self._save()
        if self.cfg.ckpt_dir:
            self._save()
        return last_metrics
