"""repro subpackage."""
