"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — cross-attention image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision]

The vision tower is a STUB per the assignment: ``input_specs()`` provides
precomputed patch embeddings (B, 1600, 7680) which a learned projection maps
to d_model; cross-attention layers (positions 3, 8, ... = every 5th) attend
them with a zero-init tanh gate.
"""

from repro.configs.base import (
    DECODE_32K, PREFILL_32K, TRAIN_4K, LayerSpec, ModelConfig,
)

_SELF = LayerSpec(kind="attn", ffn="mlp", rope_theta=500000.0)
_CROSS = LayerSpec(kind="attn", ffn="mlp", rope_theta=500000.0, cross_attn=True)

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    d_model=4096,
    n_layers=40,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    layer_pattern=(_SELF, _SELF, _SELF, _CROSS, _SELF),
    rope_theta=500000.0,
    vision_tokens=1600,
    vision_dim=7680,
    tie_embeddings=False,
    max_seq_len=131072,
)

SMOKE = ModelConfig(
    name="llama-vision-smoke",
    d_model=64,
    n_layers=5,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=(
        LayerSpec(kind="attn", ffn="mlp"),
        LayerSpec(kind="attn", ffn="mlp", cross_attn=True),
    ),
    vision_tokens=16,
    vision_dim=32,
    tie_embeddings=False,
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K)  # full attention: no long_500k
