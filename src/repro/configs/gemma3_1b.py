"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.

5:1 local:global pattern (window 512), 128k-class context, qk-norm, dual
rope thetas (10k local / 1M global). [hf:google/gemma-3-1b-pt]
"""

from repro.configs.base import (
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K, LayerSpec, ModelConfig,
)

_LOCAL = LayerSpec(kind="attn", ffn="mlp", window=512, rope_theta=10000.0)
_GLOBAL = LayerSpec(kind="attn", ffn="mlp", window=None, rope_theta=1000000.0)

CONFIG = ModelConfig(
    name="gemma3-1b",
    d_model=1152,
    n_layers=26,                      # 4 periods of 6 + 2 remainder (local)
    n_heads=4,
    n_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab=262144,
    layer_pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    qk_norm=True,
    embed_scale=True,
    tie_embeddings=True,
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    name="gemma3-1b-smoke",
    d_model=64,
    n_layers=8,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=(
        LayerSpec(kind="attn", ffn="mlp", window=64),
        LayerSpec(kind="attn", ffn="mlp", window=64),
        LayerSpec(kind="attn", ffn="mlp", rope_theta=1000000.0),
    ),
    qk_norm=True,
    embed_scale=True,
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
