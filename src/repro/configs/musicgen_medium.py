"""musicgen-medium [audio]: 48L d_model=1536 24H (kv=24 MHA) d_ff=6144
vocab=2048, decoder-only over 4 EnCodec codebooks (delay pattern).

The EnCodec frontend is a STUB per the assignment: inputs are the discrete
codebook tokens (B, S, K=4); embeddings are summed across codebooks and the
LM emits K parallel heads. [arXiv:2306.05284; hf]
"""

from repro.configs.base import (
    DECODE_32K, PREFILL_32K, TRAIN_4K, LayerSpec, ModelConfig,
)

CONFIG = ModelConfig(
    name="musicgen-medium",
    d_model=1536,
    n_layers=48,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab=2048,
    num_codebooks=4,
    layer_pattern=(LayerSpec(kind="attn", ffn="mlp"),),
    tie_embeddings=True,
    max_seq_len=65536,
)

SMOKE = ModelConfig(
    name="musicgen-smoke",
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=256,
    num_codebooks=4,
    layer_pattern=(LayerSpec(kind="attn", ffn="mlp"),),
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K)  # full attention: no long_500k
