"""Architecture registry: ``--arch <id>`` -> (ModelConfig, shapes).

Each arch module defines:
  CONFIG  — the exact published configuration (full scale),
  SMOKE   — a reduced same-family config for CPU tests,
  SHAPES  — its assigned InputShape cells (long_500k omitted for pure
            full-attention archs; see DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import importlib
from typing import Dict, List, Tuple

from repro.configs.base import InputShape, ModelConfig

ARCH_IDS = (
    "mamba2-1.3b",
    "hymba-1.5b",
    "llama-3.2-vision-11b",
    "gemma3-1b",
    "llama3-405b",
    "llama3-8b",
    "gemma2-2b",
    "mixtral-8x7b",
    "moonshot-v1-16b-a3b",
    "musicgen-medium",
)

_MODULES = {a: "repro.configs." + a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def _mod(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list(_MODULES)}")
    return importlib.import_module(_MODULES[arch])


def get_config(arch: str) -> ModelConfig:
    return _mod(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _mod(arch).SMOKE


def get_shapes(arch: str) -> Tuple[InputShape, ...]:
    return _mod(arch).SHAPES


def all_cells() -> List[Tuple[str, InputShape]]:
    """Every assigned (arch x shape) dry-run cell."""
    return [(a, s) for a in ARCH_IDS for s in get_shapes(a)]
