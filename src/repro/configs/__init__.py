"""repro subpackage."""
