"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

GQA with 8 KV heads — the paper's own Fig. 14 subject (Llama-3 8B row).
[arXiv:2407.21783]
"""

from repro.configs.base import (
    ALL_SHAPES, DECODE_32K, PREFILL_32K, TRAIN_4K, LayerSpec, ModelConfig,
)

CONFIG = ModelConfig(
    name="llama3-8b",
    d_model=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=128256,
    layer_pattern=(LayerSpec(kind="attn", ffn="mlp", rope_theta=500000.0),),
    rope_theta=500000.0,
    tie_embeddings=False,
    max_seq_len=131072,
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke",
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=(LayerSpec(kind="attn", ffn="mlp", rope_theta=500000.0),),
    tie_embeddings=False,
    max_seq_len=1024,
    compute_dtype="float32",
)

# Pure full attention: long_500k skipped (DESIGN.md §Arch-applicability).
SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K)
