"""mamba2-1.3b [ssm]: 48L d_model=2048, attention-free (SSD), d_ff=0,
vocab=50280, ssm_state=128. [arXiv:2405.21060]

The paper's attention-scheduling technique is inapplicable (no K/V ACCs);
implemented without it — see DESIGN.md §Arch-applicability. Decode is O(1)
per step (constant-size recurrent state), so long_500k runs.
"""

from repro.configs.base import (
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    LayerSpec, ModelConfig, SSMConfig,
)

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    d_model=2048,
    n_layers=48,
    n_heads=1,            # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab=50280,
    layer_pattern=(LayerSpec(kind="mamba", ffn="none"),),
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256, num_groups=1),
    tie_embeddings=True,
    max_seq_len=1048576,
)

SMOKE = ModelConfig(
    name="mamba2-smoke",
    d_model=64,
    n_layers=2,
    n_heads=1,
    n_kv_heads=1,
    head_dim=16,
    d_ff=0,
    vocab=512,
    layer_pattern=(LayerSpec(kind="mamba", ffn="none"),),
    ssm=SSMConfig(state_dim=16, head_dim=8, expand=2, conv_width=4,
                  chunk=32, num_groups=1),
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
