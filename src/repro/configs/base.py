"""Model / run configuration schema.

A ``ModelConfig`` fully determines parameter shapes and the layer stack. The
stack is a repeated ``layer_pattern`` (a tuple of LayerSpec): scan-over-
periods compiles one period body regardless of depth (126-layer llama3-405b
compiles one layer). Patterns express the assigned archs' heterogeneity:
gemma3's 5:1 local:global, gemma2's 1:1 alternation, llama-3.2-vision's
every-5th cross-attention layer, hymba's uniform hybrid blocks.

``InputShape`` describes one dry-run cell (seq_len x global_batch x step
kind); each arch config lists its four assigned shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One position within the repeating layer pattern."""

    kind: str = "attn"          # "attn" | "mamba" | "hybrid"
    ffn: str = "mlp"            # "mlp" | "moe" | "none"
    window: Optional[int] = None  # sliding-window size; None = global
    cross_attn: bool = False     # cross-attend to encoder states (VLM)
    rope_theta: float = 10000.0


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff: int = 0                # per-expert hidden dim
    capacity_factor: float = 1.25
    num_shared_experts: int = 0
    router_z_loss: float = 1e-3


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128         # N
    head_dim: int = 64           # P
    num_heads: int = 0           # 0 => derived: expand*d_model // head_dim
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256
    num_groups: int = 1          # B/C groups (GVA)
    impl: str = "auto"           # "auto" | "xla" | "pallas" (kernels/ssd.py)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    layer_pattern: Tuple[LayerSpec, ...] = (LayerSpec(),)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # attention extras
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    qk_norm: bool = False
    # io / modality
    tie_embeddings: bool = True
    num_codebooks: int = 1       # >1: musicgen-style multi-codebook LM
    vision_tokens: int = 0       # >0: VLM with stub patch-embedding frontend
    vision_dim: int = 0
    embed_scale: bool = False    # gemma-style sqrt(d) embedding scale
    # runtime
    max_seq_len: int = 131072
    compute_dtype: str = "bfloat16"
    attn_impl: str = "auto"      # kernels/ops.py dispatch
    mapping_name: str = "auto"   # "auto": kernels/ops.py resolve_mapping
                                 # picks per shape; or a PAPER_MAPPINGS name
                                 # for the fixed A/B configurations
    scan_unroll: int = 1         # lax.scan unroll for the layer stack
    attn_chunk_unroll: bool = False  # unroll the xla_flash KV-chunk scan
                                  # (cost probes: inner scans also count once)
    remat_policy: str = "nothing"  # "nothing" | "dots" — activation ckpt policy
    # Mesh-level head placement (the paper's technique at pod scale):
    # "acc_aligned" keeps whole KV groups per model shard (zero KV motion);
    # "striped" reproduces the naive round-robin baseline for A/B runs.
    head_placement: str = "acc_aligned"
    placement_shards: int = 16
    # training
    z_loss: float = 1e-4

    def pattern_for_depth(self) -> Tuple[Tuple[LayerSpec, ...], Tuple[LayerSpec, ...]]:
        """(scanned periods pattern, remainder layers)."""
        p = len(self.layer_pattern)
        n_periods = self.n_layers // p
        rem = self.n_layers - n_periods * p
        return self.layer_pattern, self.layer_pattern[:rem]

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding included once if tied)."""
        d, h, hkv, hd, dff = (
            self.d_model, self.n_heads, self.n_kv_heads, self.head_dim, self.d_ff
        )
        n_attn = n_mlp = n_moe = n_ssm = n_cross = 0
        pattern, rem = self.pattern_for_depth()
        layers = list(pattern) * self.n_periods + list(rem)
        attn_p = d * hd * (h + 2 * hkv) + h * hd * d
        mlp_p = 3 * d * dff if dff else 0
        ssm_p = 0
        if self.ssm:
            s = self.ssm
            d_in = s.expand * d
            nh = s.num_heads or d_in // s.head_dim
            # in_proj (z,x,B,C,dt) + conv + A,D + norm + out_proj
            ssm_p = d * (2 * d_in + 2 * s.num_groups * s.state_dim + nh)
            ssm_p += (d_in + 2 * s.num_groups * s.state_dim) * s.conv_width
            ssm_p += 2 * nh + d_in + d_in * d
        moe_p = 0
        if self.moe:
            m = self.moe
            moe_p = d * m.num_experts + (m.num_experts + m.num_shared_experts) * 3 * d * m.d_ff
        total = 0
        for spec in layers:
            if spec.kind in ("attn", "hybrid"):
                total += attn_p
            if spec.kind in ("mamba", "hybrid"):
                total += ssm_p
            if spec.cross_attn:
                total += attn_p + d  # cross block + its norm
            if spec.ffn == "mlp":
                total += mlp_p
            elif spec.ffn == "moe":
                total += moe_p
            total += 2 * d  # norms
        total += self.vocab * d * self.num_codebooks  # embed (tied head)
        if not self.tie_embeddings:
            total += self.vocab * d * self.num_codebooks
        if self.vision_tokens:
            total += self.vision_dim * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if not self.moe:
            return self.param_count()
        m = self.moe
        full_moe = (m.num_experts + m.num_shared_experts) * 3 * self.d_model * m.d_ff
        act_moe = (m.top_k + m.num_shared_experts) * 3 * self.d_model * m.d_ff
        n_moe_layers = sum(
            1 for s in (list(self.layer_pattern) * self.n_periods
                        + list(self.pattern_for_depth()[1]))
            if s.ffn == "moe"
        )
        return self.param_count() - n_moe_layers * (full_moe - act_moe)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (arch x shape) dry-run cell."""

    name: str               # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    step: str               # "train" | "prefill" | "decode"

    @property
    def is_decode(self) -> bool:
        return self.step == "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
