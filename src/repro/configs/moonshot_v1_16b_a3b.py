"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [moe]: 48L d_model=2048 16H
(GQA kv=16) vocab=163840, MoE 64 experts top-6 (expert d_ff=1408).
[hf:moonshotai/Moonlight-16B-A3B]
"""

from repro.configs.base import (
    DECODE_32K, PREFILL_32K, TRAIN_4K, LayerSpec, MoEConfig, ModelConfig,
)

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    d_model=2048,
    n_layers=48,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=163840,
    layer_pattern=(LayerSpec(kind="attn", ffn="moe"),),
    moe=MoEConfig(num_experts=64, top_k=6, d_ff=1408, capacity_factor=1.25),
    rope_theta=50000.0,
    tie_embeddings=False,
    max_seq_len=131072,
)

SMOKE = ModelConfig(
    name="moonshot-smoke",
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab=512,
    layer_pattern=(LayerSpec(kind="attn", ffn="moe"),),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=2.0,
                  num_shared_experts=1),
    tie_embeddings=False,
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K)  # full attention: no long_500k
