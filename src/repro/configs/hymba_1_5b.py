"""hymba-1.5b [hybrid]: 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention + mamba heads per layer.
[arXiv:2411.13676; hf]

Pattern: 1 global + 7 sliding-window hybrid layers per period (Hymba keeps
a few full-attention layers among mostly-SWA ones; meta-tokens are omitted —
noted in DESIGN.md). head_dim = 1600/25 = 64.
"""

from repro.configs.base import (
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    LayerSpec, ModelConfig, SSMConfig,
)

_GLOBAL = LayerSpec(kind="hybrid", ffn="mlp", window=None)
_LOCAL = LayerSpec(kind="hybrid", ffn="mlp", window=1024)

CONFIG = ModelConfig(
    name="hymba-1.5b",
    d_model=1600,
    n_layers=32,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab=32001,
    layer_pattern=(_GLOBAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, conv_width=4,
                  chunk=256, num_groups=1),
    tie_embeddings=True,
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    name="hymba-smoke",
    d_model=64,
    n_layers=4,
    n_heads=5,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=(
        LayerSpec(kind="hybrid", ffn="mlp"),
        LayerSpec(kind="hybrid", ffn="mlp", window=64),
    ),
    ssm=SSMConfig(state_dim=8, head_dim=8, expand=2, conv_width=4,
                  chunk=32, num_groups=1),
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
