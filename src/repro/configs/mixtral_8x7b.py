"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) vocab=32000.

8 experts top-2 (expert d_ff=14336), sliding-window attention (4096).
[arXiv:2401.04088; hf]
"""

from repro.configs.base import (
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K,
    LayerSpec, MoEConfig, ModelConfig,
)

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    d_model=4096,
    n_layers=32,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=32000,
    layer_pattern=(LayerSpec(kind="attn", ffn="moe", window=4096),),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=14336, capacity_factor=1.25),
    rope_theta=1000000.0,
    tie_embeddings=False,
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke",
    d_model=64,
    n_layers=2,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=(LayerSpec(kind="attn", ffn="moe", window=64),),
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=64, capacity_factor=2.0),
    tie_embeddings=False,
    max_seq_len=1024,
    compute_dtype="float32",
)

# SWA(4096) bounds the decode working window -> long_500k runs.
SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
