"""llama3-405b [dense]: 126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.

The paper's Fig. 14 H_Q=128 row. 126 layers compile as one scanned body.
[arXiv:2407.21783]
"""

from repro.configs.base import (
    DECODE_32K, PREFILL_32K, TRAIN_4K, LayerSpec, ModelConfig,
)

CONFIG = ModelConfig(
    name="llama3-405b",
    d_model=16384,
    n_layers=126,
    n_heads=128,
    n_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab=128256,
    layer_pattern=(LayerSpec(kind="attn", ffn="mlp", rope_theta=500000.0),),
    rope_theta=500000.0,
    tie_embeddings=False,
    max_seq_len=131072,
)

SMOKE = ModelConfig(
    name="llama3-405b-smoke",
    d_model=64,
    n_layers=3,          # exercises the scan (3 periods of 1)
    n_heads=8,
    n_kv_heads=2,
    head_dim=8,
    d_ff=128,
    vocab=512,
    layer_pattern=(LayerSpec(kind="attn", ffn="mlp", rope_theta=500000.0),),
    tie_embeddings=False,
    max_seq_len=1024,
    compute_dtype="float32",
)

SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K)  # pure full attention: no long_500k
