"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000.

Alternating local (window 4096) / global layers, attention and final logit
softcaps, sqrt(d) embedding scale. [arXiv:2408.00118; hf]
"""

from repro.configs.base import (
    DECODE_32K, LONG_500K, PREFILL_32K, TRAIN_4K, LayerSpec, ModelConfig,
)

_LOCAL = LayerSpec(kind="attn", ffn="mlp", window=4096)
_GLOBAL = LayerSpec(kind="attn", ffn="mlp", window=None)

CONFIG = ModelConfig(
    name="gemma2-2b",
    d_model=2304,
    n_layers=26,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab=256000,
    layer_pattern=(_LOCAL, _GLOBAL),
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    tie_embeddings=True,
    max_seq_len=524288,
)

SMOKE = ModelConfig(
    name="gemma2-2b-smoke",
    d_model=64,
    n_layers=4,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=(
        LayerSpec(kind="attn", ffn="mlp", window=64),
        LayerSpec(kind="attn", ffn="mlp"),
    ),
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    max_seq_len=1024,
    compute_dtype="float32",
)

# Local layers bound the per-step window; global layers are linear-per-step
# over sharded KV -> long_500k runs (decode only).
SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
